#!/usr/bin/env bash
# Builder verification for the Enoki reproduction.
#
#   scripts/verify.sh --fast   tier0 subset (<60 s) + 2-node server smoke
#   scripts/verify.sh          full tier-1 suite (~8 min) + server smoke
#
# tier0 is the pre-commit signal: the fast, low-jit tests covering the
# store, CRDTs, sharding rules, the window flusher, router sessions and
# the concurrent dispatch pipeline.  The full suite is still the gate.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [ "${1:-}" = "--fast" ]; then
    python -m pytest -q -m tier0
else
    python -m pytest -x -q
fi

# 2-node FaasServer smoke: the wall-clock serving loop end to end with the
# parallel pump — threads + asyncio clients against two store nodes.
python - <<'EOF'
import asyncio
import numpy as np
from repro.core import Cluster, enoki_function, get_function
from repro.launch.faas_server import FaasServer, serve_closed_loop_async

@enoki_function(name="vy_acc", keygroups=["vykg"], codec_width=8)
def vy_acc(kv, x):
    cur, found = kv.get("total")
    kv.set("total", cur + x)
    return cur[:1] + x[:1]

c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
            measure_compute=False)
c.deploy(get_function("vy_acc"), ["edge", "edge2"])
x = np.ones(8, np.float32)
for b in (1, 8, 64):
    c.invoke_batch("vy_acc", "edge", [x] * b)       # warm jit buckets
c.flush_replication()

with FaasServer(c, window_ms=5.0, time_scale=200.0, workers=2) as srv:
    futs = [srv.submit("vy_acc", x, session_id="smoke") for _ in range(16)]
    outs = [f.result(timeout=30.0) for f in futs]
    more = asyncio.run(serve_closed_loop_async(
        srv, "vy_acc", lambda i: x, n_requests=16, concurrency=4))
assert len(outs) == len(more) == 16
assert srv.stats.served == 32 and srv.stats.lost == 0
print(f"server smoke OK: {srv.stats.served} served "
      f"({srv.stats.pumps} pumps, workers=2, thread + asyncio clients)")
EOF

# Crash-recovery smoke: kill one node of a 2-node FaasServer mid-serving —
# the drain completes (nothing hangs), rerouted work lands at the
# survivor, and any dropped ticket raises RequestLost (at-most-once).
python - <<'EOF'
import numpy as np
from repro.core import Cluster, enoki_function, get_function
from repro.launch.faas_server import FaasServer, RequestLost
from repro.runtime import ElasticMembership, FailureInjector

@enoki_function(name="vy_crash_acc", keygroups=["vycrkg"], codec_width=8)
def vy_crash_acc(kv, x):
    cur, found = kv.get("total")
    kv.set("total", cur + x)
    return cur[:1] + x[:1]

c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
            measure_compute=False)
c.deploy(get_function("vy_crash_acc"), ["edge", "edge2"])
m = ElasticMembership(c)
inj = FailureInjector(c, membership=m)
x = np.ones(8, np.float32)
for b in (1, 8, 64):
    c.invoke_batch("vy_crash_acc", "edge", [x] * b)  # warm jit buckets
c.flush_replication()

with FaasServer(c, window_ms=5.0, time_scale=200.0, membership=m) as srv:
    futs = [srv.submit("vy_crash_acc", x) for _ in range(16)]
    inj.kill_node("edge2")              # mid-serving crash
    served = lost = 0
    for f in futs:
        try:
            f.result(timeout=30.0)      # bounded: drain must complete
            served += 1
        except RequestLost:
            lost += 1
assert served + lost == 16, (served, lost)
assert srv.stats.served == served and srv.stats.lost == lost
assert m.state["edge2"] == "dead" and m.stats.crashes == 1
assert not srv._futures and not srv._orphans
print(f"crash smoke OK: {served} served, {lost} failed fast "
      f"(edge2 killed mid-serving, survivor absorbed the rest)")
EOF
echo "verify OK"
