#!/usr/bin/env bash
# Builder verification for the Enoki reproduction.
#
#   scripts/verify.sh --fast   tier0 subset (<60 s) + 2-node server smoke
#   scripts/verify.sh          full tier-1 suite (~8 min) + server smoke
#
# tier0 is the pre-commit signal: the fast, low-jit tests covering the
# store, CRDTs, sharding rules, the window flusher, router sessions and
# the concurrent dispatch pipeline.  The full suite is still the gate.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# static concurrency-contract checks first (both modes, <10 s): the
# lock-discipline lint + the generated-docs drift check
scripts/lint.sh

if [ "${1:-}" = "--fast" ]; then
    python -m pytest -q -m tier0
else
    python -m pytest -x -q
fi

# 2-node FaasServer smoke: the wall-clock serving loop end to end with the
# parallel pump — threads + asyncio clients against two store nodes.
python - <<'EOF'
import asyncio
import numpy as np
from repro.core import Cluster, enoki_function, get_function
from repro.launch.faas_server import FaasServer, serve_closed_loop_async

@enoki_function(name="vy_acc", keygroups=["vykg"], codec_width=8)
def vy_acc(kv, x):
    cur, found = kv.get("total")
    kv.set("total", cur + x)
    return cur[:1] + x[:1]

c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
            measure_compute=False)
c.deploy(get_function("vy_acc"), ["edge", "edge2"])
x = np.ones(8, np.float32)
for b in (1, 8, 64):
    c.invoke_batch("vy_acc", "edge", [x] * b)       # warm jit buckets
c.flush_replication()

with FaasServer(c, window_ms=5.0, time_scale=200.0, workers=2) as srv:
    futs = [srv.submit("vy_acc", x, session_id="smoke") for _ in range(16)]
    outs = [f.result(timeout=30.0) for f in futs]
    more = asyncio.run(serve_closed_loop_async(
        srv, "vy_acc", lambda i: x, n_requests=16, concurrency=4))
assert len(outs) == len(more) == 16
assert srv.stats.served == 32 and srv.stats.lost == 0
print(f"server smoke OK: {srv.stats.served} served "
      f"({srv.stats.pumps} pumps, workers=2, thread + asyncio clients)")
EOF

# Crash-recovery smoke: kill one node of a 2-node FaasServer mid-serving —
# the drain completes (nothing hangs), rerouted work lands at the
# survivor, and any dropped ticket raises RequestLost (at-most-once).
python - <<'EOF'
import numpy as np
from repro.core import Cluster, enoki_function, get_function
from repro.launch.faas_server import FaasServer, RequestLost
from repro.runtime import ElasticMembership, FailureInjector

@enoki_function(name="vy_crash_acc", keygroups=["vycrkg"], codec_width=8)
def vy_crash_acc(kv, x):
    cur, found = kv.get("total")
    kv.set("total", cur + x)
    return cur[:1] + x[:1]

c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
            measure_compute=False)
c.deploy(get_function("vy_crash_acc"), ["edge", "edge2"])
m = ElasticMembership(c)
inj = FailureInjector(c, membership=m)
x = np.ones(8, np.float32)
for b in (1, 8, 64):
    c.invoke_batch("vy_crash_acc", "edge", [x] * b)  # warm jit buckets
c.flush_replication()

with FaasServer(c, window_ms=5.0, time_scale=200.0, membership=m) as srv:
    futs = [srv.submit("vy_crash_acc", x) for _ in range(16)]
    inj.kill_node("edge2")              # mid-serving crash
    served = lost = 0
    for f in futs:
        try:
            f.result(timeout=30.0)      # bounded: drain must complete
            served += 1
        except RequestLost:
            lost += 1
assert served + lost == 16, (served, lost)
assert srv.stats.served == served and srv.stats.lost == lost
assert m.state["edge2"] == "dead" and m.stats.crashes == 1
assert not srv._futures and not srv._orphans
print(f"crash smoke OK: {served} served, {lost} failed fast "
      f"(edge2 killed mid-serving, survivor absorbed the rest)")
EOF

# Dataflow-scheduler smoke: straggler topology (one store node wall-clock
# slow), workers=4 — fast nodes' windows must stream out mid-cycle (no
# stall behind the straggler) and the ticket→result map must be
# bit-identical to the serial workers=1 run.  Budget: well under 10 s.
python - <<'EOF'
import time
import numpy as np
from repro.core import Cluster, enoki_function, get_function

@enoki_function(name="vy_dfs_acc", keygroups=["vydfskg"], codec_width=8)
def vy_dfs_acc(kv, x):
    cur, found = kv.get("total")
    kv.set("total", cur + x)
    return cur[:1] + x[:1]

NODES = ["edge", "edge2", "edge3"]
def build():
    c = Cluster({n: "edge" for n in NODES}, measure_compute=False)
    c.deploy(get_function("vy_dfs_acc"), NODES)
    x = np.ones(8, np.float32)
    for n in NODES:
        c.invoke("vy_dfs_acc", n, x)        # warm the singleton bucket
    return c

t0 = time.perf_counter()
outs, states = {}, {}
for workers in (1, 4):
    c = build()
    eng = c.engine
    eng.configure(window_ms=5.0)
    streamed, stamps, slow_done = {}, {}, [None]
    if workers > 1:
        eng.use_workers(workers)
        eng.min_parallel_requests = 1
        # wall-clock straggler: wrap edge3's batched handler in a sleep
        nd = c.nodes["edge3"]
        orig = nd.batched_handlers["vy_dfs_acc"]
        def slow(*a, __orig=orig, **kw):
            time.sleep(0.2)
            out = __orig(*a, **kw)
            slow_done[0] = time.perf_counter()
            return out
        nd.batched_handlers["vy_dfs_acc"] = slow
        def on_ready(res):
            streamed.update(res)
            stamps.update(dict.fromkeys(res, time.perf_counter()))
        eng.on_ready = on_ready
    tks = {n: eng.submit("vy_dfs_acc", n, np.ones(8, np.float32))
           for n in NODES}
    res = eng.pump(1e9)
    if workers > 1:
        assert res == {}, "mid-cycle delivery left leftovers in pump return"
        res = streamed
        # no-stall: both fast nodes delivered BEFORE the straggler finished
        for n in ("edge", "edge2"):
            assert stamps[tks[n]] < slow_done[0], f"{n} stalled behind edge3"
    outs[workers] = {n: np.asarray(res[tks[n]].output) for n in NODES}
    states[workers] = {n: int(c.nodes[n].clock) for n in NODES}
for n in NODES:
    np.testing.assert_array_equal(outs[1][n], outs[4][n], err_msg=n)
    assert states[1][n] == states[4][n], n
dt = time.perf_counter() - t0
assert dt < 10.0, f"dataflow smoke too slow: {dt:.1f}s"
print(f"dataflow smoke OK: fast lanes streamed past the straggler, "
      f"workers=4 results == workers=1 ({dt:.1f}s)")
EOF

# Merge-path smoke: the device-resident delivery merge end to end — deploy
# pre-assigns canonical slots, K pending snapshots fold in ONE fused
# slot-aligned dispatch, and the merged replica is byte-identical
# (version vectors included) to the sequential per-snapshot baseline.
# Budget: well under 10 s.
python - <<'EOF'
import time
import numpy as np
from repro.core import Cluster, enoki_function, get_function
from repro.core.store import arena_clone, merge_stores_jit, stores_equal

@enoki_function(name="vy_merge_acc", keygroups=["vymkg"], codec_width=8)
def vy_merge_acc(kv, x):
    cur, found = kv.get("total")
    kv.set("total", cur + x)
    return cur[:1] + x[:1]

t0 = time.perf_counter()
c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
            measure_compute=False)
c.deploy(get_function("vy_merge_acc"), ["edge", "edge2"])
assert c._aligned.get("vymkg") is True, "deploy must pre-assign slots"
x = np.ones(8, np.float32)
K = 5
for i in range(K):
    c.invoke("vy_merge_acc", "edge", x, t_send=i * 10.0)

with c._queues["edge2"].lock:
    pending = sorted(c._queues["edge2"].heap, key=lambda e: (e[0], e[1]))
assert len(pending) == K, len(pending)
baseline = arena_clone(c.nodes["edge2"].stores["vymkg"])
for _, _, kg, snap, _, _ in pending:
    baseline = merge_stores_jit(baseline, snap)

d0, a0 = c.stats.merge_dispatches, c.stats.merge_aligned
c.flush_replication()
assert c.stats.merge_dispatches - d0 == 1, "K snapshots != one dispatch"
assert c.stats.merge_aligned - a0 == 1, "fallback merge on an aligned kg"
assert stores_equal(c.nodes["edge2"].stores["vymkg"], baseline)
dt = time.perf_counter() - t0
assert dt < 10.0, f"merge-path smoke too slow: {dt:.1f}s"
print(f"merge-path smoke OK: {K} snapshots in one aligned dispatch, "
      f"byte-identical to sequential ({dt:.1f}s)")
EOF

# Partition smoke: cut the edge<->edge2 link mid-stream through the fault
# plane, keep writing across the cut (entries park in the outbox, nothing
# strands at arrival=inf), heal, drain — the accounting must balance and
# the replicas must converge byte-identically.  Budget: well under 10 s.
python - <<'EOF'
import time
import numpy as np
from repro.core import Cluster, enoki_function, get_function
from repro.core.store import stores_equal
from repro.runtime import ElasticMembership, FailureInjector

@enoki_function(name="vy_part_acc", keygroups=["vypkg"], codec_width=8)
def vy_part_acc(kv, x):
    cur, found = kv.get("total")
    kv.set("total", cur + x)
    return cur[:1] + x[:1]

t0 = time.perf_counter()
c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
            measure_compute=False, fault_seed=11)
c.deploy(get_function("vy_part_acc"), ["edge", "edge2"])
m = ElasticMembership(c)
inj = FailureInjector(c, membership=m)
x = np.ones(8, np.float32)

c.invoke("vy_part_acc", "edge", x, t_send=0.0)      # pre-cut write
c.drain_transport(100.0)
inj.partition("edge", "edge2")                      # sever the link
for i in range(4):                                  # write across the cut
    c.invoke("vy_part_acc", "edge", x, t_send=200.0 + i * 10.0)
c.drain_transport(400.0)                            # parked, not stranded
parked = c.pending_replication("edge2")
assert parked, "cut entries must stay visible in the outbox, not vanish"
assert all(np.isfinite(t) for t, _, _ in parked), \
    "parked entries must keep a finite retry horizon (never arrival=inf)"
assert not stores_equal(c.store_of("vypkg", "edge"),
                        c.store_of("vypkg", "edge2"))
inj.heal("edge", "edge2")                           # backlog re-armed
c.drain_transport(1000.0)
assert c.transport_idle(), "healed transport must drain to idle"
assert stores_equal(c.store_of("vypkg", "edge"),
                    c.store_of("vypkg", "edge2")), \
    "replicas must converge byte-identically after the heal"
assert m.stats.crashes == 0, "a partition must never be treated as a crash"
final = float(np.asarray(c.store_of("vypkg", "edge").values)[0][0])
assert final == 5.0, f"every write must survive the cut: {final}"
dt = time.perf_counter() - t0
assert dt < 10.0, f"partition smoke too slow: {dt:.1f}s"
print(f"partition smoke OK: 4 writes parked across the cut, delivered "
      f"after heal, byte-identical replicas ({dt:.1f}s)")
EOF
echo "verify OK"
