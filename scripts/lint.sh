#!/usr/bin/env bash
# Static concurrency-contract checks (<10 s) — the pre-commit signal.
#
#   scripts/lint.sh
#
# 1. lockcheck: AST lock-discipline lint over src/ against the LOCK_ORDER
#    declaration (out-of-order acquisitions, dispatch under _qlock, raw
#    stats +=, blocking calls under non-leaf locks).
# 2. lock_order --check: the docs/batched_engine.md hierarchy block must
#    match the in-code spec (regenerate with `--write`).
#
# See docs/concurrency_checks.md.  scripts/verify.sh runs this first in
# both modes.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m repro.analysis.lockcheck src/
python -m repro.analysis.lock_order --check
