"""Quickstart: the paper's Listing 1 on a three-node Enoki cluster.

Deploys a stateful function to two edge nodes with a replicated keygroup,
invokes it through the router, and prints what the paper is about: local
access latency vs the cloud alternative, and the staleness you pay.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster, Router, enoki_function
from repro.core.faas import get_function
from repro.core.network import paper_topology


# Listing 1 — "import kv" becomes the kv handle; keys are plain strings.
@enoki_function(name="hello", keygroups=["greetings"], codec_width=16)
def call(kv, i):
    curr, found = kv.get("current")
    count = jnp.where(found, curr[0] + 1.0, 1.0)       # "Hello World!\n" += 1
    kv.set("current", jnp.concatenate([jnp.stack([count]), jnp.zeros((15,))]))
    return jnp.stack([count])


def main():
    cluster = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                      net=paper_topology())
    print("deploying 'hello' to edge+edge2 (keygroup replicated, Enoki)…")
    cluster.deploy(get_function("hello"), ["edge", "edge2"],
                   policy=ReplicationPolicy.REPLICATED,
                   example_input=jnp.zeros((1,)))
    router = Router(cluster, client="client")

    t = 0.0
    for i in range(5):
        res = router.invoke("hello", jnp.zeros((1,)), t_send=t,
                            session_id="alice")
        print(f"  call {i}: node={res.node:6s} count="
              f"{float(np.asarray(res.output)[0]):.0f} "
              f"latency={res.response_ms:6.1f} ms "
              f"(kv ops: {[k for k, _ in res.kv_ops]})")
        t = res.t_received + 100.0

    # the counter lives in the keygroup, replicated to both edges
    cluster.flush_replication()
    for node in ("edge", "edge2"):
        store = cluster.store_of("greetings", node)
        from repro.core.store import kv_get
        from repro.core.versioning import fnv1a
        val, _, _, _ = kv_get(store, fnv1a("current"))
        print(f"replica on {node:6s}: current = {float(val[0]):.0f}")

    # same function, store forced to the cloud (the paper's baseline)
    cluster2 = Cluster({"edge": "edge", "cloud": "cloud"},
                       net=paper_topology())
    cluster2.deploy(get_function("hello"), ["edge"],
                    policy=ReplicationPolicy.CLOUD_CENTRAL, owner="cloud",
                    example_input=jnp.zeros((1,)))
    res = cluster2.invoke("hello", "edge", jnp.zeros((1,)))
    print(f"\nsame call with the store in the cloud: {res.response_ms:6.1f} ms"
          f"  (every kv op pays the 50 ms RTT — Fig 3)")


if __name__ == "__main__":
    main()
