"""The paper's §5 demonstration: the 8-function BeFaaS smart-city app on
Enoki, data store at the edge vs in the cloud.

    PYTHONPATH=src python examples/smart_city.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster
from repro.core.network import paper_topology

from smart_city_app import deploy_app


def main():
    rng = np.random.default_rng(0)
    for policy, label in [(ReplicationPolicy.REPLICATED, "edge (Enoki)"),
                          (ReplicationPolicy.CLOUD_CENTRAL, "cloud store")]:
        c = Cluster({"edge": "edge", "cloud": "cloud"}, net=paper_topology())
        deploy_app(c, policy)
        lat = {}
        for i in range(60):
            t = i * 200.0
            u = rng.random()
            name = ("traffic_sensor_filter" if u < 0.45 else
                    "object_recognition" if u < 0.9 else
                    "weather_sensor_filter")
            x = jnp.asarray([rng.random() * 2 - 1, 0.0])
            res = c.invoke(name, "edge", x, t_send=t)
            lat.setdefault(name, []).append(res.response_ms)
        print(f"\nstore = {label}:")
        for name, xs in sorted(lat.items()):
            print(f"  {name:24s} p50={np.percentile(xs, 50):7.1f} ms "
                  f"p90={np.percentile(xs, 90):7.1f} ms (n={len(xs)})")
    print("\n(paper Fig 8: weather endpoint unaffected by placement; "
          "traffic/object chains pay the store RTTs via movement_plan)")


if __name__ == "__main__":
    main()
