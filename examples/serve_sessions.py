"""Serving driver: decode sessions as replicated Enoki keygroups.

Two logical pods serve separate session batches; every R tokens the session
keygroups anti-entropy to the peer pod (ring backup).  Pod 0 then "fails";
its sessions resume on pod 1 from the backup with ≤R tokens of staleness —
the serving analogue of the paper's §4.3 measurement.

    PYTHONPATH=src python examples/serve_sessions.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES_BY_NAME, get_arch, reduced
from repro.models import model_zoo as zoo


def main():
    arch = reduced(get_arch("internlm2-1.8b"))
    n_pods, batch, max_len, R = 2, 2, 64, 4
    params = zoo.init_params(arch, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    sparams = jax.tree.map(lambda l: jnp.stack([l] * n_pods), params)

    step = jax.jit(jax.vmap(
        lambda p, c, t: zoo.decode_step(arch, p, c, t)))
    live = jax.tree.map(lambda l: jnp.stack([l] * n_pods),
                        zoo.init_cache(arch, batch, max_len))
    backup = live
    replicate = jax.jit(lambda c: jax.tree.map(
        lambda x: jnp.roll(x, 1, axis=0), c))

    token = jnp.ones((n_pods, batch, 1), jnp.int32)
    print(f"decoding on {n_pods} pods × {batch} sessions, backup every "
          f"{R} tokens")
    generated = [[] for _ in range(n_pods)]
    for t in range(10):
        logits, live = step(sparams, live, token)
        token = jnp.argmax(logits[..., -1, :], axis=-1)[..., None] \
            .astype(jnp.int32)
        for p in range(n_pods):
            generated[p].append(int(token[p, 0, 0]))
        if (t + 1) % R == 0:
            backup = replicate(live)
            print(f"  t={t+1}: anti-entropy -> peer backup "
                  f"(session length {int(live['length'][0])})")

    print(f"generated (pod0 session0): {generated[0]}")
    # ---- pod 0 dies; its sessions live on in pod 1's backup slot ----------
    lost_len = int(live["length"][0])
    dead = jnp.asarray([True, False])
    migrate = jax.jit(lambda l, b: jax.tree.map(
        lambda x, y: jnp.where(dead.reshape((n_pods,) + (1,) * (x.ndim - 1)),
                               y, x), l, b))
    restored = migrate(live, backup)
    staleness = lost_len - int(restored["length"][0])
    print(f"pod0 failed at token {lost_len}; restored session is at token "
          f"{int(restored['length'][0])} -> staleness = {staleness} tokens "
          f"(bound: R={R})")
    assert staleness <= R
    # continue decoding the restored sessions
    logits, restored = step(sparams, restored, token)
    print("restored sessions decode onward: OK")


if __name__ == "__main__":
    main()
