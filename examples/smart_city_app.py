"""The BeFaaS smart-city application (paper §5, Fig 7) as Enoki functions.

Eight functions across the edge-cloud continuum; three persist state in
keygroups.  Call graph (sync unless noted):

  traffic_sensor_filter (edge)  --50%-->  movement_plan (edge, stateful)
  object_recognition   (edge)  --50%-->  movement_plan
  weather_sensor_filter(edge)  --async-> road_condition (cloud, stateful)
  movement_plan                --sync--> light_phase_calculation (edge, stateful)
                               --async-> traffic_statistics (cloud)
  emergency_detection  (edge)  <-sync--  object_recognition

Filter convention (core/cluster.py): a handler whose output's first element
is < 0 suppresses its synchronous downstream calls.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import enoki_function


@enoki_function(name="traffic_sensor_filter", keygroups=[],
                calls=["movement_plan"], codec_width=4)
def traffic_sensor_filter(kv, x):
    # pass the event through when the measurement exceeds the threshold
    return jnp.where(x[0] > 0.0, jnp.stack([x[0], 0.0]),
                     jnp.stack([-1.0, 0.0]))


@enoki_function(name="object_recognition", keygroups=[],
                calls=["movement_plan", "emergency_detection"], codec_width=4)
def object_recognition(kv, x):
    # "recognise" an object: a cheap deterministic feature score
    score = jnp.tanh(x[0] * 3.0)
    return jnp.where(x[0] > 0.0, jnp.stack([score, 1.0]),
                     jnp.stack([-1.0, 1.0]))


@enoki_function(name="weather_sensor_filter", keygroups=[],
                async_calls=["road_condition"], codec_width=4)
def weather_sensor_filter(kv, x):
    return jnp.where(x[0] > 0.0, jnp.stack([x[0], 2.0]),
                     jnp.stack([-1.0, 2.0]))


@enoki_function(name="movement_plan", keygroups=["plans"],
                calls=["light_phase_calculation"],
                async_calls=["traffic_statistics"], codec_width=16)
def movement_plan(kv, x):
    """Stateful: reads the current plan, folds the event in, writes back
    (multiple kv accesses per invocation — the paper's hot path)."""
    plan, found = kv.get("plan")
    count, _ = kv.get("count")
    new_count = jnp.where(found, count[0] + 1.0, 1.0)
    new_plan = jnp.where(found, plan[0] * 0.9 + x[0] * 0.1, x[0])
    kv.set("plan", jnp.concatenate([jnp.stack([new_plan]), jnp.zeros((15,))]))
    kv.set("count", jnp.concatenate([jnp.stack([new_count]),
                                     jnp.zeros((15,))]))
    return jnp.stack([new_plan, new_count])


@enoki_function(name="light_phase_calculation", keygroups=["lights"],
                codec_width=8)
def light_phase_calculation(kv, x):
    phase, found = kv.get("phase")
    new = jnp.where(found, (phase[0] + 1.0) % 4.0, 0.0)
    kv.set("phase", jnp.concatenate([jnp.stack([new]), jnp.zeros((7,))]))
    return jnp.stack([new])


@enoki_function(name="traffic_statistics", keygroups=["stats"],
                codec_width=8)
def traffic_statistics(kv, x):
    total, found = kv.get("total")
    new = jnp.where(found, total[0] + x[0], x[0])
    kv.set("total", jnp.concatenate([jnp.stack([new]), jnp.zeros((7,))]))
    return jnp.stack([new])


@enoki_function(name="road_condition", keygroups=["roads"], codec_width=8)
def road_condition(kv, x):
    worst, found = kv.get("worst")
    new = jnp.where(found, jnp.maximum(worst[0], x[0]), x[0])
    kv.set("worst", jnp.concatenate([jnp.stack([new]), jnp.zeros((7,))]))
    return jnp.stack([new])


@enoki_function(name="emergency_detection", keygroups=[], codec_width=4)
def emergency_detection(kv, x):
    return jnp.stack([jnp.where(x[0] > 0.95, 1.0, 0.0)])


STATEFUL = {"movement_plan": "plans", "light_phase_calculation": "lights",
            "traffic_statistics": "stats", "road_condition": "roads"}

EDGE_FNS = ["traffic_sensor_filter", "object_recognition",
            "weather_sensor_filter", "movement_plan",
            "light_phase_calculation", "emergency_detection"]
CLOUD_FNS = ["traffic_statistics", "road_condition"]


def deploy_app(cluster, data_policy, edge_nodes=("edge",),
               cloud_node="cloud"):
    """Deploy the eight functions; stateful keygroups follow data_policy."""
    from repro.core.faas import get_function

    for fn in EDGE_FNS:
        cluster.deploy(get_function(fn), list(edge_nodes), policy=data_policy,
                       owner=cloud_node, example_input=jnp.zeros((2,)))
    for fn in CLOUD_FNS:
        cluster.deploy(get_function(fn), [cloud_node], policy=data_policy,
                       owner=cloud_node, example_input=jnp.zeros((2,)))
