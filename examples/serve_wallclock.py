"""Wall-clock serving demo: the batched engine hosted as an actual server.

Until now every engine demo drove virtual time by hand (`pump(until_t)`).
Here `FaasServer` maps REAL arrival times onto the virtual timeline:

  1. client threads submit stateful requests whenever they like; the
     serving thread sleeps exactly until the next window close
     (`router.next_deadline()`) instead of polling;
  2. a closed-loop run (each client fires its next request on completion)
     shows emergent batching under feedback;
  3. a STRAGGLER topology (the nearest replica serves slowly) shows the
     windowed hedge: read-only requests whose window outlives the hedge
     deadline are duplicated at the second replica, and the earlier
     completion wins (the duplicate goes to the lowest-latency-EWMA
     replica once samples exist);
  4. the CONCURRENT dispatch pipeline: `workers=2` runs the two store
     nodes' groups of each flush cycle on per-node executors, and an
     asyncio closed loop hosts 16 LOGICAL clients on one event loop —
     no thread per client.

Run:  PYTHONPATH=src python examples/serve_wallclock.py
"""
import asyncio
import time

import jax.numpy as jnp
import numpy as np

from repro.core import Cluster, enoki_function, get_function, percentiles
from repro.core.network import paper_topology
from repro.launch.faas_server import (FaasServer, serve_closed_loop,
                                      serve_closed_loop_async)


@enoki_function(name="wc_acc", keygroups=["wc_kg"], codec_width=16)
def wc_acc(kv, x):
    cur, found = kv.get("total")
    kv.set("total", cur + x)
    return cur[:1] + x[:1]


@enoki_function(name="wc_read", keygroups=["wc_kg"], codec_width=16)
def wc_read(kv, x):
    cur, found = kv.get("total")
    return cur[:1]


def fresh_cluster():
    cluster = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                      net=paper_topology(), measure_compute=False)
    cluster.deploy(get_function("wc_acc"), ["edge", "edge2"])
    cluster.deploy(get_function("wc_read"), ["edge", "edge2"])
    x = np.ones(16, np.float32)
    for node in ("edge", "edge2"):          # warm jit buckets + seed state
        for b in (1, 8, 64):
            cluster.invoke_batch("wc_acc", node, [x] * b)
            cluster.invoke_batch("wc_read", node, [x] * b)
    cluster.flush_replication()
    return cluster, x


def main():
    # -- 1. open-loop wall-clock serving ------------------------------------
    cluster, x = fresh_cluster()
    t0 = time.perf_counter()
    with FaasServer(cluster, window_ms=8.0, time_scale=100.0) as srv:
        futs = [srv.submit("wc_acc", x, session_id="demo")
                for _ in range(128)]
        outs = [f.result(timeout=30.0) for f in futs]
    wall = time.perf_counter() - t0
    pct = percentiles(srv.response_ms)
    print(f"open loop: {len(outs)} requests in {wall*1e3:.0f} ms wall "
          f"({len(outs)/wall:.0f} ops/s), {srv.stats.pumps} pumps")
    print(f"  virtual latency p50/p99: {pct[50]:.1f}/{pct[99]:.1f} ms "
          f"(window 8 ms)")

    # -- 2. closed loop: 8 clients, next request on completion --------------
    cluster, x = fresh_cluster()
    t0 = time.perf_counter()
    with FaasServer(cluster, window_ms=4.0, time_scale=100.0) as srv:
        rs = serve_closed_loop(srv, "wc_acc", lambda i: x,
                               n_requests=128, concurrency=8)
    wall = time.perf_counter() - t0
    print(f"closed loop: {len(rs)} requests, {srv.stats.pumps} pumps, "
          f"{len(rs)/wall:.0f} ops/s wall")

    # -- 3. windowed hedging on a straggler topology ------------------------
    for hedged in (False, True):
        cluster, x = fresh_cluster()
        cluster.set_compute_ms("edge", "wc_read", 60.0)     # straggler
        with FaasServer(cluster, window_ms=16.0, time_scale=100.0,
                        hedge_after_ms=4.0 if hedged else None) as srv:
            futs = [srv.submit("wc_read", x) for _ in range(64)]
            [f.result(timeout=30.0) for f in futs]
        pct = percentiles(srv.response_ms)
        extra = (f", hedges fired/won: {srv.router.stats.hedges_fired}/"
                 f"{srv.router.stats.hedge_wins}" if hedged else "")
        print(f"straggler {'with' if hedged else 'no  '} hedge: "
              f"p50/p99 = {pct[50]:.1f}/{pct[99]:.1f} ms{extra}")

    # -- 4. parallel pump + asyncio clients: one process, many logical
    #       clients, per-store-node executors -------------------------------
    cluster, x = fresh_cluster()
    t0 = time.perf_counter()
    with FaasServer(cluster, window_ms=4.0, time_scale=100.0,
                    workers=2) as srv:
        rs = asyncio.run(serve_closed_loop_async(
            srv, "wc_acc", lambda i: x, n_requests=128, concurrency=16))
    wall = time.perf_counter() - t0
    print(f"asyncio closed loop (16 logical clients, workers=2): "
          f"{len(rs)} requests, {len(rs)/wall:.0f} ops/s wall, "
          f"{srv.stats.pumps} pumps")


if __name__ == "__main__":
    main()
