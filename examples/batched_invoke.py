"""Batched invocation engine demo: serve a burst of concurrent stateful
requests with one device dispatch.

Deploys the paper's Listing-1-style counter/accumulator to an edge node,
then compares:

  1. 256 sequential ``Cluster.invoke`` calls (one Python round-trip + one
     device dispatch each — the §4.2 bottleneck), vs
  2. one ``Cluster.invoke_batch`` of the same 256 requests (scan-folded
     store update, per-request emulated network), vs
  3. the ``submit``/``flush`` coalescing API that independent callers use,
  4. the background flusher: ``window_ms`` arrival-time windows drained by
     ``pump`` across TWO nodes in one flush cycle (cross-node fan-out).

Run:  PYTHONPATH=src python examples/batched_invoke.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import Cluster, enoki_function, get_function
from repro.core.network import paper_topology


@enoki_function(name="accumulate", keygroups=["acc_kg"], codec_width=16)
def accumulate(kv, x):
    cur, found = kv.get("total")
    kv.set("total", cur + x)
    return cur[:1] + x[:1]


def main():
    cluster = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                      net=paper_topology(), measure_compute=False)
    cluster.deploy(get_function("accumulate"), ["edge", "edge2"])

    burst = [np.full(16, 1.0, np.float32) for _ in range(256)]
    t_sends = [i * 0.1 for i in range(256)]   # 10k rps arrival process

    # -- sequential baseline (first pass warms the jit caches) --------------
    [cluster.invoke("accumulate", "edge", x, t_send=t)
     for x, t in zip(burst, t_sends)]
    t0 = time.perf_counter()
    seq = [cluster.invoke("accumulate", "edge", x, t_send=t)
           for x, t in zip(burst, t_sends)]
    np.asarray(seq[-1].output)
    seq_s = time.perf_counter() - t0

    # -- batched (same double-pass so totals line up) -----------------------
    cluster2 = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                       net=paper_topology(), measure_compute=False)
    cluster2.deploy(get_function("accumulate"), ["edge", "edge2"])
    cluster2.invoke_batch("accumulate", "edge", burst, t_sends=t_sends)
    t0 = time.perf_counter()
    bat = cluster2.invoke_batch("accumulate", "edge", burst, t_sends=t_sends)
    bat_s = time.perf_counter() - t0

    print(f"sequential: {len(seq) / seq_s:8.0f} ops/s")
    print(f"batched:    {len(bat) / bat_s:8.0f} ops/s "
          f"({seq_s / bat_s:.1f}x)")
    # identical final state: last response carries the full fold either way
    print("last output sequential:", float(np.asarray(seq[-1].output)[0]))
    print("last output batched:   ", float(np.asarray(bat[-1].output)[0]))
    # per-request latency is still the emulated network's, not the batch's
    print(f"response_ms (same for all requests): {bat[0].response_ms:.2f}")

    # -- coalescing API -----------------------------------------------------
    cluster3 = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                       net=paper_topology(), measure_compute=False)
    cluster3.deploy(get_function("accumulate"), ["edge", "edge2"])
    tickets = [cluster3.engine.submit("accumulate", "edge",
                                      np.full(16, 1.0, np.float32),
                                      t_send=float(i)) for i in range(32)]
    results = cluster3.engine.flush()    # one batch per (fn, node) group
    print(f"flush() served {len(results)} queued requests; "
          f"last total = {float(np.asarray(results[tickets[-1]].output)[0])}")

    # -- background flusher: windows + pump, fanned out across two nodes ----
    engine = cluster3.engine.configure(window_ms=8.0, max_batch=64)
    tickets = [engine.submit("accumulate", "edge" if i % 2 == 0 else "edge2",
                             np.full(16, 1.0, np.float32), t_send=i * 0.5)
               for i in range(64)]          # 2 req/ms split across 2 nodes
    before = engine.stats.windows_flushed
    served = engine.pump(100.0)             # drains every due window
    st = engine.stats
    print(f"pump() served {len(served)} requests in "
          f"{st.windows_flushed - before} windows across 2 nodes "
          f"(deadline flushes: {st.deadline_flushes})")
    # a windowed request waits at most window_ms past its solo latency
    print(f"windowed response_ms: {served[tickets[0]].response_ms:.2f} "
          f"(window 8.0 ms)")


if __name__ == "__main__":
    main()
