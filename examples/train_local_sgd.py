"""End-to-end training driver: Enoki-replicated (DiLoCo-style) local SGD.

Trains a small LM on this host with TWO logical pods (the pod axis is
emulated with a stacked leading dim on a 1-device mesh — the same code path
the 512-chip dry-run lowers), demonstrating the full production loop:

  data pipeline (sharded, cursor keygroup) -> pod-local train steps ->
  periodic anti-entropy (delta exchange + outer Nesterov) ->
  async checkpointing -> crash -> restore -> continue.

Default config is laptop-sized (~9M params, 60 steps, a few minutes on one
core).  ``--params-100m --steps 300`` gives the full-size run on real
hardware.

    PYTHONPATH=src python examples/train_local_sgd.py [--steps N]
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import (EnokiConfig, ParallelConfig, ReplicationPolicy,
                           SHAPES_BY_NAME, TrainConfig, get_arch)
from repro.configs.base import ArchConfig, ShapeConfig, StepKind
from repro.data import synthetic_batch
from repro.launch import train as train_mod
from repro.optim import diloco_init
from repro.runtime import HealthMonitor


def small_arch(big: bool) -> ArchConfig:
    base = get_arch("internlm2-1.8b")
    if big:   # ~100M params
        return dataclasses.replace(base, num_layers=12, d_model=768,
                                   num_heads=12, num_kv_heads=4, d_ff=2048,
                                   vocab_size=32768)
    return dataclasses.replace(base, num_layers=6, d_model=256, num_heads=4,
                               num_kv_heads=2, d_ff=1024, vocab_size=4096)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--params-100m", action="store_true")
    ap.add_argument("--replication-period", type=int, default=5)
    ap.add_argument("--ckpt-dir", default="/tmp/enoki_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a failure at this step and restore")
    args = ap.parse_args()

    arch = small_arch(args.params_100m)
    shape = ShapeConfig("local", seq_len=128, global_batch=8,
                        step=StepKind.TRAIN)
    n_pods = 2
    par = ParallelConfig(fsdp=False, remat="none", optimizer="adamw")
    cfg = TrainConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    enoki = EnokiConfig(policy=ReplicationPolicy.REPLICATED,
                        replication_period=args.replication_period)
    print(f"arch: {arch.param_count()/1e6:.1f}M params, "
          f"{n_pods} logical pods, anti-entropy every "
          f"{enoki.replication_period} steps")

    step_fn = train_mod.make_step_fn(arch, par, cfg)
    vstep = jax.jit(jax.vmap(step_fn))

    single = train_mod.init_state(arch, jax.random.PRNGKey(0), par)
    state = jax.tree.map(lambda l: jnp.stack([l] * n_pods), single)
    outer = diloco_init(single["params"])
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    health = HealthMonitor()

    def replicate(state, outer):
        local = state["params"]
        deltas = jax.tree.map(
            lambda o, l: (o[None] - l.astype(jnp.float32)).mean(0),
            outer["outer_params"], local)
        from repro.optim import diloco_outer_update
        new_outer_params, outer = diloco_outer_update(
            outer, deltas, enoki.outer_lr, enoki.outer_momentum)
        state = dict(state)
        state["params"] = jax.tree.map(
            lambda no, l: jnp.broadcast_to(no.astype(l.dtype)[None], l.shape),
            new_outer_params, local)
        return state, outer

    rep_jit = jax.jit(replicate)

    def batch_for(step_i):
        shards = [synthetic_batch(arch, shape, 0, step_i, shard=p,
                                  num_shards=n_pods) for p in range(n_pods)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *shards)

    t0 = time.time()
    mgr.save(0, {"state": state, "outer": outer}, blocking=True)  # step-0 base
    start = 0
    for i in range(start, args.steps):
        if args.crash_at is not None and i == args.crash_at:
            print(f"-- simulated crash at step {i}; restoring from "
                  f"checkpoint --")
            mgr.wait()
            restored = mgr.restore({"state": state, "outer": outer})
            state, outer = restored["state"], restored["outer"]
            args.crash_at = None
        state, metrics = vstep(state, batch_for(i))
        for p in range(n_pods):
            health.beat(f"pod{p}", i)
        if (i + 1) % enoki.replication_period == 0:
            state, outer = rep_jit(state, outer)
            tag = " +anti-entropy"
        else:
            tag = ""
        if i % 5 == 0 or i == args.steps - 1:
            loss = [float(metrics["loss"][p]) for p in range(n_pods)]
            print(f"step {i:4d}  loss/pod={['%.3f' % l for l in loss]} "
                  f"lr={float(metrics['lr'][0]):.2e} "
                  f"{time.time()-t0:6.1f}s{tag}")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i, {"state": state, "outer": outer})
    mgr.wait()
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s; "
          f"checkpoints at {args.ckpt_dir}: steps {mgr.steps()}")
    print(f"stragglers seen: {health.stragglers() or 'none'}")


if __name__ == "__main__":
    main()
