"""Deterministic, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) via ``fold_in`` — no
filesystem, no host state, which gives three properties production loaders
sweat for: (i) exact restart from a checkpointed cursor, (ii) disjoint
shards per data-parallel host, (iii) identical data under re-sharding (the
cursor is global; hosts slice it).  The cursor is an Enoki keygroup
(merge='max': a restarted host converges to the highest step seen — a
grow-only CRDT), so the paper's replication machinery is also the data
pipeline's fault-tolerance story.

Token stream: Zipf-ish distribution over the vocab with a deterministic
"grammar" (next-token depends on previous token) so the LM loss actually
falls during the example runs — pure-uniform tokens would leave nothing to
learn.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.keygroup import TensorKeygroup


def _zipf_tokens(key, shape, vocab: int) -> jnp.ndarray:
    """Zipf-distributed tokens: id ~ floor(exp(u * log(V))) biases mass to
    small ids like natural text."""
    u = jax.random.uniform(key, shape)
    ids = jnp.exp(u * jnp.log(float(vocab))).astype(jnp.int32) - 1
    return jnp.clip(ids, 0, vocab - 1)


def synthetic_batch(arch: ArchConfig, shape: ShapeConfig, seed: int,
                    step: int, shard: int = 0, num_shards: int = 1,
                    batch_override: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """One (possibly sharded) batch for `step`.  Deterministic."""
    b = (batch_override or shape.global_batch) // num_shards
    s = shape.seq_len
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), shard)
    k1, k2, k3 = jax.random.split(key, 3)
    base = _zipf_tokens(k1, (b, s + 1), arch.vocab_size)
    # learnable structure: with p=0.5 the next token = (prev*7+1) mod V
    follow = jax.random.bernoulli(k2, 0.5, (b, s + 1))
    rolled = (jnp.roll(base, 1, axis=1) * 7 + 1) % arch.vocab_size
    stream = jnp.where(follow, rolled, base)
    batch = {
        "tokens": stream[:, :-1],
        "labels": stream[:, 1:],
        "loss_mask": jnp.ones((b, s), jnp.float32),
    }
    if arch.frontend_stub == "clip_patches":
        batch["patch_embeds"] = jax.random.normal(
            k3, (b, arch.num_patches, arch.d_model)) * 0.02
        batch["loss_mask"] = batch["loss_mask"].at[:, :arch.num_patches].set(0)
    if arch.frontend_stub == "audio_frames":
        batch["frame_embeds"] = jax.random.normal(
            k3, (b, arch.num_patches, arch.d_model)) * 0.02
    return batch


@dataclasses.dataclass
class DataPipeline:
    """Host-side iterator with a replicable cursor keygroup."""

    arch: ArchConfig
    shape: ShapeConfig
    seed: int = 0
    shard: int = 0
    num_shards: int = 1
    batch_override: Optional[int] = None

    def __post_init__(self):
        self.cursor = TensorKeygroup.create(
            {"step": jnp.zeros((), jnp.int32)}, merge="max")

    @property
    def step(self) -> int:
        return int(self.cursor.tree["step"])

    def next(self) -> Dict[str, jnp.ndarray]:
        batch = synthetic_batch(self.arch, self.shape, self.seed, self.step,
                                self.shard, self.num_shards,
                                self.batch_override)
        self.cursor = self.cursor.write(
            {"step": self.cursor.tree["step"] + 1})
        return batch

    def restore(self, cursor: TensorKeygroup) -> None:
        """Adopt a replicated/checkpointed cursor (max-merge: never rewind)."""
        self.cursor = self.cursor.merged_with(cursor)
