"""gemma-7b [dense]: 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.

GeGLU activation, head_dim=256 (so q_dim = 16*256 = 4096 != d_model, explicit
o-proj 4096->3072).  arXiv:2403.08295.
"""
from repro.configs.base import Activation, ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    activation=Activation.GEGLU,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
