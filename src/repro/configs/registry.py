"""Architecture / shape / cell registry.

``get_arch("--arch id")`` resolves an assigned architecture; ``cells()``
enumerates the (arch x shape) grid with applicability filtering (long_500k
only runs for sub-quadratic archs, per DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

from repro.configs.base import (
    ArchConfig,
    MoEConfig,
    SHAPES,
    SHAPES_BY_NAME,
    ShapeConfig,
    SSMConfig,
    StepKind,
    XLSTMConfig,
)

# ---------------------------------------------------------------------------
# Registry construction
# ---------------------------------------------------------------------------

_ARCH_MODULES = {
    "xlstm-350m": "repro.configs.xlstm_350m",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_4p2b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "gemma-7b": "repro.configs.gemma_7b",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "internlm2-1.8b": "repro.configs.internlm2_1p8b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}

ARCH_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)

_cache: Dict[str, ArchConfig] = {}


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; choose from {sorted(_ARCH_MODULES)}")
    if arch_id not in _cache:
        import importlib

        mod = importlib.import_module(_ARCH_MODULES[arch_id])
        _cache[arch_id] = mod.CONFIG
    return _cache[arch_id]


def get_shape(shape_id: str) -> ShapeConfig:
    return SHAPES_BY_NAME[shape_id]


# ---------------------------------------------------------------------------
# Applicability (DESIGN.md §5)
# ---------------------------------------------------------------------------

# Sub-quadratic archs run long_500k; pure full-attention archs skip it.
SUBQUADRATIC = {"xlstm-350m", "zamba2-7b"}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Return (runs, reason-if-skipped)."""
    if shape.name == "long_500k" and arch.name not in SUBQUADRATIC:
        return False, ("pure full-attention arch; 500k-token full-cache decode "
                       "excluded per spec (needs sub-quadratic attention)")
    return True, ""


def cells(include_skipped: bool = False) -> Iterator[Tuple[ArchConfig, ShapeConfig, str]]:
    """All 40 (arch x shape) cells; yields (arch, shape, skip_reason)."""
    for arch_id in ARCH_IDS:
        arch = get_arch(arch_id)
        for shape in SHAPES:
            ok, reason = shape_applicable(arch, shape)
            if ok or include_skipped:
                yield arch, shape, reason


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced(arch: ArchConfig) -> ArchConfig:
    """Shrink an arch config to CPU-smoke size, preserving family structure.

    Keeps: block pattern (moe/ssm/xlstm/shared-attn/enc-dec), GQA ratio,
    activation, biases.  Shrinks: layers, widths, experts, vocab.
    """
    updates: dict = dict(
        num_layers=min(arch.num_layers, 4),
        d_model=128,
        vocab_size=512,
        max_seq_len=512,
    )
    # preserve the GQA ratio at reduced head counts
    ratio = max(1, arch.num_heads // max(arch.num_kv_heads, 1))
    heads = 4
    updates["num_heads"] = heads
    updates["num_kv_heads"] = max(1, heads // ratio)
    updates["head_dim"] = 32 if arch.head_dim else None
    updates["d_ff"] = 256 if arch.d_ff else 0
    if arch.moe is not None:
        updates["moe"] = MoEConfig(
            num_experts=min(arch.moe.num_experts, 8),
            top_k=min(arch.moe.top_k, 2),
            d_expert=128,
            shared_expert=arch.moe.shared_expert,
        )
    if arch.ssm is not None:
        updates["ssm"] = SSMConfig(state_dim=16, conv_width=4, expand=2,
                                   head_dim=32, chunk_size=32)
    if arch.xlstm is not None:
        updates["xlstm"] = XLSTMConfig(slstm_every=arch.xlstm.slstm_every,
                                       num_heads=2, chunk_size=16)
        updates["num_layers"] = 8 if arch.xlstm.slstm_every <= 8 else 4
    if arch.shared_attn_every:
        updates["shared_attn_every"] = 2
        updates["num_layers"] = 5
    if arch.is_encoder_decoder:
        updates["encoder_layers"] = 2
        updates["num_layers"] = 2
    if arch.num_patches:
        updates["num_patches"] = 8
    if arch.sliding_window:
        updates["sliding_window"] = 64
    return dataclasses.replace(arch, **updates)


def reduced_shape(shape: ShapeConfig) -> ShapeConfig:
    """Smoke-test shape: tiny batch and sequence, same step kind."""
    return dataclasses.replace(
        shape, seq_len=64 if shape.step is StepKind.TRAIN else 128,
        global_batch=2)
