"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840.

Trillion-parameter MoE: 384 experts, top-8 routing, per-expert hidden 2048,
plus one shared expert (paper-table, arXiv:2501.kimi2).  Active params ≈32B.
head_dim = 7168/64 = 112 (kept exact per the assigned table).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048, shared_expert=True),
    rope_theta=50_000.0,
)
