"""xlstm-350m [ssm]: 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.

sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM per arXiv:2405.04517).  d_ff=0 means
there is no separate MLP block — the up/down projections live inside the
xLSTM blocks themselves (post-up-projection structure).
"""
from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=8, num_heads=4, proj_factor_mlstm=2.0,
                      proj_factor_slstm=1.333, chunk_size=64),
    tie_embeddings=True,
    max_seq_len=1_048_576,  # O(1) recurrent state: no context limit in principle
)
