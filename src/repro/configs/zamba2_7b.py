"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000.

Mamba2 backbone with a single weight-SHARED attention+MLP block applied every
6 layers (arXiv:2411.15242).  ssm_state=64.  The shared block's d_ff=14336 and
32 heads come from the assigned table; Mamba2 blocks use expand=2, head_dim=64.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, head_dim=64, chunk_size=128),
    shared_attn_every=6,
    sliding_window=4096,        # used by the shared attn block in long_500k mode
    max_seq_len=1_048_576,
)
