from repro.configs.base import (
    Activation,
    ArchConfig,
    AttnImpl,
    BlockKind,
    EnokiConfig,
    MeshConfig,
    MoEConfig,
    MULTI_POD_MESH,
    ParallelConfig,
    ReplicationPolicy,
    SHAPES,
    SHAPES_BY_NAME,
    SINGLE_POD_MESH,
    SSMConfig,
    ShapeConfig,
    StepKind,
    TrainConfig,
    XLSTMConfig,
)
from repro.configs.registry import (
    ARCH_IDS,
    cells,
    get_arch,
    get_shape,
    reduced,
    reduced_shape,
    shape_applicable,
)

__all__ = [
    "Activation", "ArchConfig", "AttnImpl", "BlockKind", "EnokiConfig",
    "MeshConfig", "MoEConfig", "MULTI_POD_MESH", "ParallelConfig",
    "ReplicationPolicy", "SHAPES", "SHAPES_BY_NAME", "SINGLE_POD_MESH",
    "SSMConfig", "ShapeConfig", "StepKind", "TrainConfig", "XLSTMConfig",
    "ARCH_IDS", "cells", "get_arch", "get_shape", "reduced", "reduced_shape",
    "shape_applicable",
]
