"""whisper-tiny [audio]: 4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865.

Encoder-decoder; the conv frontend is a STUB per spec — ``input_specs()``
provides precomputed frame embeddings for the encoder (arXiv:2212.04356).
"""
from repro.configs.base import Activation, ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,              # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    activation=Activation.GELU,
    is_encoder_decoder=True,
    encoder_layers=4,
    frontend_stub="audio_frames",
    num_patches=1500,          # encoder frame positions (30s at 50Hz)
    rope_theta=0.0,            # whisper uses learned/sinusoidal abs positions
    max_seq_len=32_768,        # assigned stress shapes exceed nominal 448
)
