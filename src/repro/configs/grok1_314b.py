"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.

MoE with 8 experts, top-2 routing (hf:xai-org/grok-1).  d_ff is the per-expert
hidden dim.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32768),
    rope_theta=10_000.0,
)
