"""Configuration dataclasses for the repro framework.

Every architecture in the assigned pool is expressed as an ``ArchConfig``;
every input-shape cell as a ``ShapeConfig``; the distribution setup as a
``MeshConfig``; and the paper's technique (Enoki state management) as an
``EnokiConfig``.  Configs are plain frozen dataclasses so they can be hashed
into jit static args and printed into EXPERIMENTS.md verbatim.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class BlockKind(str, enum.Enum):
    """Kinds of residual blocks a layer stack can be built from."""

    ATTN = "attn"              # full (GQA/MQA/MHA) attention
    MOE = "moe"                # mixture-of-experts MLP
    MLP = "mlp"                # dense MLP (SwiGLU/GeGLU/GELU)
    MAMBA2 = "mamba2"          # SSD state-space block
    MLSTM = "mlstm"            # xLSTM matrix-memory block
    SLSTM = "slstm"            # xLSTM scalar-memory block (sequential)
    SHARED_ATTN = "shared_attn"  # zamba2-style weight-shared attention


class Activation(str, enum.Enum):
    SWIGLU = "swiglu"
    GEGLU = "geglu"
    GELU = "gelu"
    RELU = "relu"


class AttnImpl(str, enum.Enum):
    """Which attention implementation the model uses."""

    REFERENCE = "reference"    # kv-block online-softmax scan (pure jnp)
    FLASH = "flash"            # Pallas flash-attention kernel (interpret on CPU)
    QSCAN = "qscan"            # q-block scan, full-row softmax (no carried acc)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int              # per-expert hidden dim
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    shared_expert: bool = False  # kimi-k2 has a shared expert alongside routed ones
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64        # N (per-head state size)
    conv_width: int = 4
    expand: int = 2            # d_inner = expand * d_model
    head_dim: int = 64         # Mamba2 head dim (d_inner / n_heads)
    chunk_size: int = 128      # SSD chunk length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8       # 1-in-8 layers are sLSTM (7:1 per paper)
    num_heads: int = 4
    proj_factor_mlstm: float = 2.0   # mLSTM up-projection factor
    proj_factor_slstm: float = 1.333  # sLSTM ffn factor
    chunk_size: int = 64


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture.  Field values are the exact assigned numbers."""

    name: str
    family: str                # ssm | vlm | moe | hybrid | dense | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # block pattern; "auto" derives from family
    activation: Activation = Activation.SWIGLU
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # zamba2: one shared attention block applied every `shared_attn_every` layers
    shared_attn_every: int = 0
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    # vlm / audio frontends are stubs: inputs arrive as precomputed embeddings
    frontend_stub: Optional[str] = None   # "clip_patches" | "audio_frames" | None
    num_patches: int = 0       # vlm: patch tokens prepended to text
    sliding_window: int = 0    # >0 enables sliding-window attention in long mode
    max_seq_len: int = 131_072

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + blocks + head)."""
        from repro.models.model_zoo import analytic_param_count

        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model_zoo import analytic_param_count

        return analytic_param_count(self, active_only=True)


class StepKind(str, enum.Enum):
    TRAIN = "train"            # full fwd+bwd+optimizer step
    PREFILL = "prefill"        # forward over full sequence, builds KV cache
    DECODE = "decode"          # one new token against an existing KV cache


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: StepKind

    @property
    def is_serving(self) -> bool:
        return self.step is not StepKind.TRAIN


# The four assigned LM shapes (identical across archs; applicability filtered
# in registry.cells()).
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4_096, global_batch=256, step=StepKind.TRAIN),
    ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, step=StepKind.PREFILL),
    ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, step=StepKind.DECODE),
    ShapeConfig("long_500k", seq_len=524_288, global_batch=1, step=StepKind.DECODE),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


class ReplicationPolicy(str, enum.Enum):
    """The three data placements evaluated in the paper (§4.3 / Fig 5)."""

    CLOUD_CENTRAL = "cloud_central"  # state on one node; every access remote
    PEER_FETCH = "peer_fetch"        # state on owner node; reads fetch on demand (SyncMesh)
    REPLICATED = "replicated"        # Enoki: local replica everywhere, async anti-entropy


@dataclasses.dataclass(frozen=True)
class EnokiConfig:
    """Paper-technique knobs, threaded through train/serve steps."""

    policy: ReplicationPolicy = ReplicationPolicy.REPLICATED
    replication_period: int = 8      # anti-entropy every R steps (staleness bound)
    compress_deltas: bool = False    # int8-quantise anti-entropy payloads
    outer_lr: float = 0.7            # DiLoCo outer Nesterov LR (training keygroups)
    outer_momentum: float = 0.9
    store_slots: int = 64            # KV arena capacity (keys per keygroup)
    value_bytes: int = 1024          # max value payload per slot (microbench arena)


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def is_multi_pod(self) -> bool:
        return "pod" in self.axes


SINGLE_POD_MESH = MeshConfig(shape=(16, 16), axes=("data", "model"))
MULTI_POD_MESH = MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How a given (arch, shape, mesh) cell is sharded."""

    fsdp: bool = False           # shard params over "data" (ZeRO-3 style)
    zero1: bool = True           # shard optimizer state over "data"
    seq_shard: bool = False      # shard sequence dim over "data" (prefill SP)
    remat: str = "none"          # none | block | full — activation checkpointing
    use_scan: bool = True        # scan over layers (keeps HLO small)
    optimizer: str = "adamw"     # adamw | adafactor
    moe_impl: str = "auto"       # auto (XLA propagation) | ep (shard_map)
    flash_decode: bool = False   # shard_map partial-softmax decode attention
    attn_impl: str = "reference"  # reference | qscan | flash


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
