"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.

phi3-mini backbone + CLIP vision frontend.  Per spec the frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings (num_patches tokens of
width d_model) that are concatenated ahead of the text tokens.
"""
from repro.configs.base import Activation, ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    activation=Activation.SWIGLU,
    frontend_stub="clip_patches",
    num_patches=576,           # 24x24 CLIP-L/14 at 336px
    rope_theta=10_000.0,
    max_seq_len=131_072,
)
