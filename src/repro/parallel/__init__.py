from repro.parallel.sharding import (batch_specs, cache_partition_specs,
                                     named, opt_state_specs,
                                     param_partition_specs)

__all__ = ["batch_specs", "cache_partition_specs", "named",
           "opt_state_specs", "param_partition_specs"]
