"""Sharding rules: parameter/optimizer/cache pytrees → PartitionSpecs.

Rules are leaf-name based (megatron-style tensor parallelism over the
``model`` axis, FSDP/ZeRO over ``data``), with divisibility guards: an
assignment that does not divide evenly falls back to replication instead of
failing at lowering (e.g. whisper's vocab 51865 % 16 ≠ 0 → replicated
embedding).  Stacked leading layer dims are never sharded (they are scanned).

  column-parallel (output dim over model):  wq wk wv w_gate w_up w_z w_x
                                            w_q w_k w_v lm_head ...
  row-parallel (input dim over model):      wo w_down w_out w_ff_down
  expert-parallel (experts over model):     moe w_gate/w_up/w_down when
                                            E % model_shards == 0, else the
                                            experts fall back to column/row TP
  vocab-parallel:                           embed (dim 0)

The ``pod`` axis is NEVER assigned to parameters here: parameter replicas
per pod are Enoki keygroups, reconciled by replication.py off the hot path.
(CLOUD_CENTRAL/sync-DP instead folds ``pod`` into the gradient reduction —
see launch/train.py.)
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ParallelConfig, ShapeConfig, StepKind

COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "w_ff_gate", "w_ff_up",
                "w_z", "w_x", "w_q", "w_k", "w_v", "lm_head", "patch_proj",
                "frame_proj"}
ROW_PARALLEL = {"wo", "w_down", "w_out", "w_ff_down"}
VOCAB_PARALLEL = {"embed"}


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs, *,
                     check_vma: bool = False, axis_names=None):
    """Version-tolerant shard_map.

    Newer jax exposes ``jax.shard_map(..., check_vma=, axis_names=)``; older
    releases only have ``jax.experimental.shard_map.shard_map`` where the
    replication check is spelled ``check_rep`` and everything runs
    full-manual (no ``axis_names``; unsharded inputs are replicated per
    device, which is what this repo's partial-manual call sites rely on).
    Kwargs are selected by signature inspection so real TypeErrors from the
    wrapped call surface unchanged."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        try:
            params = inspect.signature(sm).parameters
        except (TypeError, ValueError):
            params = {}
        if "check_vma" in params:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in params:
            kwargs["check_rep"] = check_vma
        if "axis_names" in params and axis_names is not None:
            kwargs["axis_names"] = axis_names
        return sm(f, **kwargs)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _in_moe(path) -> bool:
    return any(getattr(e, "key", None) == "moe" for e in path)


def _spec_for(path, leaf, arch: ArchConfig, mesh: Mesh,
              parallel: ParallelConfig) -> P:
    name = _leaf_name(path)
    shape = leaf.shape
    nd = len(shape)
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")
    assign: list = [None] * nd

    def try_assign(dim: int, axis: str, size: int) -> bool:
        if size > 1 and shape[dim] % size == 0 and assign[dim] is None:
            assign[dim] = axis
            return True
        return False

    if nd >= 2:
        moe_expert_weight = (_in_moe(path)
                             and name in ("w_gate", "w_up", "w_down")
                             and nd >= 3)
        if moe_expert_weight and shape[-3] % model == 0:
            try_assign(nd - 3, "model", model)            # expert-parallel
        elif name in COL_PARALLEL:
            try_assign(nd - 1, "model", model)
        elif name in ROW_PARALLEL:
            try_assign(nd - 2, "model", model)
        elif name in VOCAB_PARALLEL:
            try_assign(0, "model", model)
        # FSDP: shard the largest remaining dim over data
        if parallel.fsdp:
            free = [d for d in range(nd) if assign[d] is None]
            for d in sorted(free, key=lambda d: -shape[d]):
                if try_assign(d, "data", data):
                    break
    return P(*assign)


def param_partition_specs(params: Any, arch: ArchConfig, mesh: Mesh,
                          parallel: ParallelConfig) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, arch, mesh, parallel), params)


def opt_state_specs(params: Any, arch: ArchConfig, mesh: Mesh,
                    parallel: ParallelConfig) -> Any:
    """Specs for one params-shaped moment tree.  ZeRO-1: moments additionally
    sharded over ``data`` even when parameters are not (fsdp=False)."""
    if parallel.fsdp or not parallel.zero1:
        return param_partition_specs(params, arch, mesh, parallel)
    import dataclasses
    zp = dataclasses.replace(parallel, fsdp=True)   # data-shard the moments
    return param_partition_specs(params, arch, mesh, zp)


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------

def batch_specs(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                parallel: ParallelConfig) -> Any:
    """PartitionSpecs for the input batch dict (matches input_specs keys)."""
    data = _axis_size(mesh, "data")
    bdim = "data" if shape.global_batch % max(data, 1) == 0 and data > 1 else None
    seq = None
    if parallel.seq_shard and shape.step is StepKind.PREFILL:
        seq = "model"
    if shape.step in (StepKind.TRAIN, StepKind.PREFILL):
        specs = {"tokens": P(bdim, seq)}
        if shape.step is StepKind.TRAIN:
            specs["labels"] = P(bdim, seq)
            specs["loss_mask"] = P(bdim, seq)
        if arch.frontend_stub == "clip_patches":
            specs["patch_embeds"] = P(bdim, None, None)
        if arch.frontend_stub == "audio_frames":
            specs["frame_embeds"] = P(bdim, None, None)
        return specs
    return {"token": P(bdim, None)}


def cache_partition_specs(cache: Any, arch: ArchConfig, mesh: Mesh,
                          batch: int, prefer_seq: bool = False) -> Any:
    """KV/state cache specs: batch over ``data``; one trailing dim over
    ``model``.  ``prefer_seq=True`` shards the SEQUENCE dim (the one right
    after batch) — required by the flash-decode partial-softmax path, which
    owns the cross-shard softmax combine (§Perf hillclimb B).  Cache trees
    are stacked (L, B, ...) or nested-stacked (G, n, B, ...); the batch dim
    is located by size match."""
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")

    def spec(path, leaf) -> P:
        name = _leaf_name(path)
        shape = leaf.shape
        nd = len(shape)
        if nd == 0 or name == "length":
            return P()
        assign = [None] * nd
        # find the batch dim: first dim equal to `batch` after the stack dims
        bdim = None
        for d, s in enumerate(shape):
            if s == batch:
                bdim = d
                break
        if bdim is not None and data > 1 and batch % data == 0:
            assign[bdim] = "data"
        if model > 1 and nd >= 2:
            placed = False
            if prefer_seq and bdim is not None and bdim + 1 < nd \
                    and shape[bdim + 1] % model == 0 \
                    and shape[bdim + 1] >= model:
                assign[bdim + 1] = "model"      # the sequence dim
                placed = True
            if not placed:
                for d in sorted(range(nd - 1, max(nd - 3, -1), -1),
                                key=lambda d: -shape[d]):
                    if d != bdim and assign[d] is None \
                            and shape[d] % model == 0 and shape[d] >= model:
                        assign[d] = "model"
                        break
        return P(*assign)

    return jax.tree_util.tree_map_with_path(spec, cache)


def named(mesh: Mesh, tree_of_specs: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
