"""Flash attention forward, Pallas TPU.

Grid (B, H, num_q_blocks, num_kv_blocks); the kv dimension is the innermost
("arbitrary") axis so the (m, l, acc) running state lives in VMEM scratch
across kv iterations.  GQA is handled in the K/V index_maps (kv head =
h // group) — K/V are never materialised per query head.  Causal blocks
strictly above the diagonal skip both DMA-compute via ``pl.when`` (the ~2×
win over the masked XLA reference; see §Perf).

Block shapes are (bq × d) / (bk × d) VMEM tiles; defaults 256/512 keep the
working set ≈ (256+512)·d·2B + 256·512·4B ≈ 1.2 MB at d=128, well under the
~16 MB v5e VMEM budget, with MXU-aligned (≥128) matmul dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, causal: bool, window: int, scale: float,
                  num_kv_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip fully-masked blocks (strictly above the causal diagonal)
    run = True
    if causal:
        run = kj * bk <= qi * bq + (bq - 1)

    @pl.when(run)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0]                                    # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         bq: int = 256, bk: int = 512,
                         interpret: bool = False):
    """q (B,H,Sq,d); k,v (B,KV,Skv,d) -> (B,H,Sq,d).  H = KV·G."""
    B, H, Sq, d = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    from jax.experimental.pallas import tpu as pltpu
    # jax renamed TPUCompilerParams -> CompilerParams across versions
    params_cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        scale=d ** -0.5, num_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=params_cls(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
