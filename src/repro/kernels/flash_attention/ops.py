"""Jit'd wrapper: model layout (B,S,H,Dh) <-> kernel layout (B,H,S,Dh).

On CPU (tests, this container) the kernel runs in interpret mode; on TPU it
lowers to Mosaic.  The wrapper is a drop-in replacement for
``models.attention.blockwise_attention`` via ``AttnImpl.FLASH``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 256, bk: int = 512, interpret: bool = None):
    """q (B,S,H,Dh); k,v (B,S,KV,Dh) -> (B,S,H,Dh)."""
    interpret = _on_cpu() if interpret is None else interpret
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
