"""Pure-jnp oracle for the flash attention kernel (O(S²) memory)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import reference_attention


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B,S,H,Dh); k,v (B,S,KV,Dh) -> (B,S,H,Dh)."""
    B, Sq = q.shape[:2]
    Skv = k.shape[1]
    pos_q = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    pos_k = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32), (B, Skv))
    return reference_attention(q, k, v, pos_q, pos_k, causal=causal,
                               window=window)
