"""Enoki versioned merge kernel, Pallas TPU — the paper-specific hot spot.

Anti-entropy over multi-GB replicated state (session KV caches, pod
parameter replicas) reduces to one elementwise-ish primitive: *versioned
last-writer-wins select* over (value, version) pairs, slot-aligned:

    out_val[i]  = b_val[i]  if b_ver[i] > a_ver[i] else a_val[i]
    out_ver[i]  = max(a_ver[i], b_ver[i])

where one version guards a row of V payload elements (the arena layout of
core/store.py, and a (slot, feature-row) view of tensor keygroups).  The op
is purely bandwidth-bound; the kernel's job on TPU is streaming both
replicas through VMEM in (rows × V) tiles with zero intermediate
materialisation — XLA's generic select would materialise the broadcasted
predicate at full payload width in HBM.

Rows tile defaults to 256 slots × the full payload width (payloads are
padded to a 128 multiple by the caller).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_kernel(av_ref, aver_ref, bv_ref, bver_ref, ov_ref, over_ref):
    a_ver = aver_ref[...]                     # (rows,)
    b_ver = bver_ref[...]
    take_b = b_ver > a_ver
    ov_ref[...] = jnp.where(take_b[:, None], bv_ref[...], av_ref[...])
    over_ref[...] = jnp.maximum(a_ver, b_ver)


def enoki_merge_rows(a_val, a_ver, b_val, b_ver, *, rows_tile: int = 256,
                     interpret: bool = False):
    """a_val/b_val (R, V); a_ver/b_ver (R,) int32 packed versions.
    Returns (merged_val (R, V), merged_ver (R,))."""
    R, V = a_val.shape
    rt = min(rows_tile, R)
    assert R % rt == 0, (R, rt)
    grid = (R // rt,)
    val_spec = pl.BlockSpec((rt, V), lambda i: (i, 0))
    ver_spec = pl.BlockSpec((rt,), lambda i: (i,))
    from jax.experimental.pallas import tpu as pltpu

    # jax renamed TPUCompilerParams -> CompilerParams across versions
    params_cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[val_spec, ver_spec, val_spec, ver_spec],
        out_specs=[val_spec, ver_spec],
        out_shape=[jax.ShapeDtypeStruct((R, V), a_val.dtype),
                   jax.ShapeDtypeStruct((R,), a_ver.dtype)],
        compiler_params=params_cls(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(a_val, a_ver, b_val, b_ver)
