"""Pure-jnp oracle for the versioned LWW merge."""
from __future__ import annotations

import jax.numpy as jnp


def enoki_merge_ref(a_val, a_ver, b_val, b_ver):
    take_b = b_ver > a_ver
    val = jnp.where(take_b[:, None], b_val, a_val)
    ver = jnp.maximum(a_ver, b_ver)
    return val, ver
