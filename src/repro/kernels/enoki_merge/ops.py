"""Jit'd wrapper: versioned merge over arenas and flat tensor keygroups."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.enoki_merge.kernel import enoki_merge_rows


@functools.partial(jax.jit, static_argnames=("rows_tile", "interpret"))
def enoki_merge(a_val, a_ver, b_val, b_ver, *, rows_tile: int = 256,
                interpret: bool = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return enoki_merge_rows(a_val, a_ver, b_val, b_ver,
                            rows_tile=rows_tile, interpret=interpret)


def merge_flat_keygroup(a_flat: jnp.ndarray, a_ver: jnp.ndarray,
                        b_flat: jnp.ndarray, b_ver: jnp.ndarray,
                        row_width: int = 1024, interpret: bool = None):
    """LWW-merge two flat replicas (N,) with per-row versions (N/row_width,).
    Used by replication.py for large tensor keygroups where per-element
    versions would double the state size."""
    n = a_flat.shape[0]
    rows = n // row_width
    va, vb = (a_flat[:rows * row_width].reshape(rows, row_width),
              b_flat[:rows * row_width].reshape(rows, row_width))
    mv, mver = enoki_merge(va, a_ver, vb, b_ver, interpret=interpret)
    out = mv.reshape(-1)
    if rows * row_width < n:   # ragged tail: jnp fallback
        tail_take_b = b_ver[-1] > a_ver[-1]
        tail = jnp.where(tail_take_b, b_flat[rows * row_width:],
                         a_flat[rows * row_width:])
        out = jnp.concatenate([out, tail])
    return out, mver
