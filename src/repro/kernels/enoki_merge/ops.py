"""Jit'd wrapper: versioned merge over arenas and flat tensor keygroups."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.enoki_merge.kernel import enoki_merge_rows


@functools.partial(jax.jit, static_argnames=("rows_tile", "interpret"))
def enoki_merge(a_val, a_ver, b_val, b_ver, *, rows_tile: int = 256,
                interpret: bool = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return enoki_merge_rows(a_val, a_ver, b_val, b_ver,
                            rows_tile=rows_tile, interpret=interpret)


def merge_flat_keygroup(a_flat: jnp.ndarray, a_ver: jnp.ndarray,
                        b_flat: jnp.ndarray, b_ver: jnp.ndarray,
                        row_width: int = 1024, interpret: bool = None):
    """LWW-merge two flat replicas (N,) with per-row versions.

    Row-granularity contract: versions guard ``row_width`` payload
    elements each, so a replica of N elements carries
    ``ceil(N / row_width)`` version entries — the LAST one owning the
    ragged tail when ``row_width`` does not divide N.  Used for large
    tensor keygroups where per-element versions would double the state
    size.  Returns ``(merged (N,), merged versions (ceil(N/row_width),))``
    — the tail's version entry is merged (elementwise max of the winning
    compare) exactly like the full rows', not dropped.
    """
    n = a_flat.shape[0]
    rows = n // row_width
    full = rows * row_width
    assert a_ver.shape[0] == b_ver.shape[0] == rows + (1 if full < n else 0), \
        (a_ver.shape, b_ver.shape, n, row_width)
    if rows:
        va, vb = (a_flat[:full].reshape(rows, row_width),
                  b_flat[:full].reshape(rows, row_width))
        out, mver = enoki_merge(va, a_ver[:rows], vb, b_ver[:rows],
                                interpret=interpret)
        out = out.reshape(-1)
    else:
        out, mver = a_flat[:0], a_ver[:0]
    if full < n:   # ragged tail: one versioned row, jnp fallback
        tail_take_b = b_ver[rows] > a_ver[rows]
        tail = jnp.where(tail_take_b, b_flat[full:], a_flat[full:])
        out = jnp.concatenate([out, tail])
        mver = jnp.concatenate(
            [mver, jnp.maximum(a_ver[rows:], b_ver[rows:])])
    return out, mver
