"""Jit'd wrapper for the mLSTM chunk kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_bhsd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, log_i, log_f, *, chunk: int = 64,
                interpret: bool = None):
    """Model layout: q/k/v (B,S,H,d); gates (B,S,H) -> (B,S,H,d)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t = lambda x: x.transpose(0, 2, 1, 3)
    g = lambda x: x.transpose(0, 2, 1)
    h = mlstm_chunk_bhsd(t(q), t(k), t(v), g(log_i), g(log_f), chunk=chunk,
                         interpret=interpret)
    return t(h)
