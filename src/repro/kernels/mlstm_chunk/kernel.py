"""mLSTM chunkwise kernel, Pallas TPU (xLSTM matrix memory).

Grid (B, H, num_chunks); chunk axis innermost/"arbitrary" with the
(dqk × dv) matrix state C, normaliser n (dqk,) and stabiliser m ()
in VMEM scratch, carried across chunk iterations.

Stabilised log-space math identical to models/xlstm._mlstm_chunk_parallel
(the oracle): intra-chunk decay matrix D from cumulative log-f + log-i,
running-max stabiliser, |denominator| ≥ exp(−m) guard.

VMEM per step ≈ l·(2dqk+dv) + l² + dqk·dv floats; defaults (l=64,
dqk=dv=512) ≈ 1.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, y_ref,
                  c_ref, n_ref, m_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0, 0].astype(jnp.float32)       # (l, dqk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)       # (l, dv)
    log_i = i_ref[0, 0].astype(jnp.float32)   # (l,)
    log_f = f_ref[0, 0].astype(jnp.float32)
    scale = q.shape[-1] ** -0.5
    m_prev = m_ref[0]
    C_prev = c_ref[...]
    n_prev = n_ref[...]

    b = jnp.cumsum(log_f)                     # (l,)
    D = b[:, None] - b[None, :] + log_i[None, :]
    l_ = q.shape[0]
    tril = jax.lax.broadcasted_iota(jnp.int32, (l_, l_), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (l_, l_), 1)
    D = jnp.where(tril, D, NEG_INF)
    m_intra = D.max(axis=1)
    m_inter = b + m_prev
    m_tot = jnp.maximum(m_intra, m_inter)

    S = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    W = S * jnp.exp(D - m_tot[:, None])
    h_intra = jax.lax.dot_general(W, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    dec_in = jnp.exp(m_inter - m_tot)
    qs = q * scale
    h_inter = jax.lax.dot_general(qs, C_prev, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32) \
        * dec_in[:, None]
    norm = W.sum(axis=1) + (qs @ n_prev) * dec_in
    denom = jnp.maximum(jnp.abs(norm), jnp.exp(-m_tot))
    y_ref[0, 0] = ((h_intra + h_inter) / denom[:, None]).astype(y_ref.dtype)

    # carry to end of chunk
    m_next = jnp.maximum(b[-1] + m_prev, (b[-1] - b + log_i).max())
    dec_c = jnp.exp(b[-1] + m_prev - m_next)
    w_kv = jnp.exp(b[-1] - b + log_i - m_next)          # (l,)
    kw = k * w_kv[:, None]
    c_ref[...] = C_prev * dec_c + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_ref[...] = n_prev * dec_c + kw.sum(axis=0)
    m_ref[0] = m_next


def mlstm_chunk_bhsd(q, k, v, log_i, log_f, *, chunk: int = 64,
                     interpret: bool = False):
    """q/k/v (B,H,S,d); log_i/log_f (B,H,S) -> h (B,H,S,d)."""
    B, H, S, d = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    from jax.experimental.pallas import tpu as pltpu
    # jax renamed TPUCompilerParams -> CompilerParams across versions
    params_cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    spec4 = pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, i: (b_, h_, i, 0))
    spec3 = pl.BlockSpec((1, 1, chunk), lambda b_, h_, i: (b_, h_, i))
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[spec4, spec4, spec4, spec3, spec3],
        out_specs=spec4,
        out_shape=jax.ShapeDtypeStruct((B, H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, d), jnp.float32),
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=params_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, log_i, log_f)
