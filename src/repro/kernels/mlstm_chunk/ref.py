"""Pure-jnp oracle: models.xlstm.mlstm_cell_seq (validated against the
step recurrence by tests/test_models_parity.py)."""
from __future__ import annotations

from repro.models.xlstm import mlstm_cell_seq


def mlstm_chunk_ref(q, k, v, log_i, log_f, *, chunk: int = 64):
    """Kernel layout (B,H,S,d) / (B,H,S) -> (B,H,S,d)."""
    t = lambda x: x.transpose(0, 2, 1, 3)
    g = lambda x: x.transpose(0, 2, 1)
    h, _ = mlstm_cell_seq(t(q), t(k), t(v), g(log_i), g(log_f), chunk)
    return t(h)
