"""Jit'd wrapper for the SSD chunk kernel (model layout <-> kernel layout)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk.kernel import ssd_chunk_bhcp


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk(x, a_dt, b, c, dt, *, chunk: int = 128,
              interpret: bool = None):
    """Model layout: x (B,S,H,P); a_dt/dt (B,S,H); b,c (B,S,N).
    Returns y (B,S,H,P) (without the D skip — caller adds it)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    xw = (x * dt[..., None]).transpose(0, 2, 1, 3)
    a = a_dt.transpose(0, 2, 1)
    b4 = b[:, None] if b.ndim == 3 else b          # (B,1,S,N)
    c4 = c[:, None] if c.ndim == 3 else c
    y = ssd_chunk_bhcp(xw, a, b4, c4, chunk=chunk, interpret=interpret)
    return y.transpose(0, 2, 1, 3)
