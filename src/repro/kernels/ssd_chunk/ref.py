"""Pure-jnp oracle: the models.ssm chunked scan (itself validated against
step-by-step recurrence in tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_scan


def ssd_chunk_ref(x, a_dt, b, c, *, chunk: int = 128):
    """Same layout as the kernel: x (B,H,S,P) dt-weighted; a_dt (B,H,S);
    b,c (B,1,S,N)."""
    B, H, S, P = x.shape
    xs = x.transpose(0, 2, 1, 3)                      # (B,S,H,P)
    a = a_dt.transpose(0, 2, 1)                       # (B,S,H)
    # ssd_scan expects x un-dt-weighted with dt separate; pass dt=1 and feed
    # the dt-weighted input directly (identical algebra).
    ones = jnp.ones_like(a)
    y, _ = ssd_scan(xs, a, b[:, 0], c[:, 0], ones, chunk)
    return y.transpose(0, 2, 1, 3)
