"""Mamba-2 SSD chunk kernel, Pallas TPU.

One grid step = one (batch, head, chunk) cell.  The chunk dimension is the
innermost, "arbitrary" axis: the (P × N) recurrent state lives in VMEM
scratch and flows across chunk iterations — the inter-chunk recurrence is
sequential per (b, h), exactly the dependency structure of the SSD
algorithm, while (b, h) parallelise across cores.

Per chunk (l = chunk length, p = head dim, n = state dim):
  intra:  Y_diag = ((C Bᵀ) ⊙ L) · (dt·X)         two (l×n)(n×l) + (l×l)(l×p)
  inter:  Y_off  = (C · state) ⊙ exp(A_cum)
  state' = state·exp(A_sum) + (B ⊙ decay)ᵀ (dt·X)

VMEM working set ≈ l·(2n + 2p) + l² + p·n floats; defaults (l=128, p=64,
n=64) ≈ 200 kB.  All matmul dims are 64/128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, adt_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (l, p)  already dt-weighted
    a = adt_ref[0, 0].astype(jnp.float32)        # (l,)
    b = b_ref[0, 0].astype(jnp.float32)          # (l, n)
    c = c_ref[0, 0].astype(jnp.float32)          # (l, n)

    a_cum = jnp.cumsum(a)                        # (l,)
    # intra-chunk: L[i,j] = exp(a_cum[i] - a_cum[j]) for j <= i
    seg = a_cum[:, None] - a_cum[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tril, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(scores * L, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                       # (p, n)
    y_off = jax.lax.dot_general(c, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_diag + y_off * jnp.exp(a_cum)[:, None]).astype(
        y_ref.dtype)

    # state update
    decay_to_end = jnp.exp(a_cum[-1] - a_cum)    # (l,)
    bw = b * decay_to_end[:, None]               # (l, n)
    new = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (p, n)
    state_ref[...] = state * jnp.exp(a_cum[-1]) + new


def ssd_chunk_bhcp(x, a_dt, b, c, *, chunk: int = 128,
                   interpret: bool = False):
    """x (B,H,S,P) dt-weighted input; a_dt (B,H,S); b,c (B,1,S,N) shared
    across heads (n_groups=1) -> y (B,H,S,P)."""
    B, H, S, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    from jax.experimental.pallas import tpu as pltpu
    # jax renamed TPUCompilerParams -> CompilerParams across versions
    params_cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bb, h, i: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bb, h, i: (bb, h, i)),
            pl.BlockSpec((1, 1, chunk, N), lambda bb, h, i: (bb, 0, i, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bb, h, i: (bb, 0, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P),
                               lambda bb, h, i: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=params_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, a_dt, b, c)
