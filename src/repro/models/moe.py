"""Mixture-of-Experts layer: top-k routing with sort-based dispatch.

Dispatch never materialises a (tokens × experts) tensor: assignments are
sorted by expert id, positions-within-expert computed from per-expert offsets,
and tokens scattered into a fixed-capacity (E, C, D) bucket tensor (capacity
overflow drops, as in Switch/GShard).  This is the shape EP sharding wants:
bucket/expert tensors are sharded on E over the ``model`` axis (kimi-k2,
384 experts → 24/shard) and XLA inserts the all-to-all at the scatter/gather.
Few-big-expert models (grok-1, 8 experts < 16 shards) instead shard each
expert's FFN dim over ``model`` (tensor-parallel experts, E replicated) —
``expert_sharding_strategy`` picks per arch×mesh.

The router runs in float32; an auxiliary load-balancing loss (Switch-style
fraction·probability product) is returned for the training objective.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import Activation, ArchConfig, MoEConfig
from repro.models.layers import dense_init, gated_mlp


def moe_init(key, arch: ArchConfig, dtype=jnp.float32) -> dict:
    cfg = arch.moe
    d, f, e = arch.d_model, cfg.d_expert, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), scale=d ** -0.5, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, f), dtype=dtype),
        "w_down": dense_init(ks[3], (e, f, d), dtype=dtype),
    }
    if cfg.shared_expert:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], d, f, Activation.SWIGLU, dtype=dtype)
    return p


def capacity(tokens: int, cfg: MoEConfig, multiple: int = 128) -> int:
    """Static per-expert bucket capacity, padded to ``multiple`` (128 = MXU
    tile for sequence mode; decode uses 8 to avoid padding FLOPs at tiny
    per-expert batch)."""
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(multiple, ((c + multiple - 1) // multiple) * multiple)


def route(router_w: jnp.ndarray, x: jnp.ndarray, cfg: MoEConfig
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing.  x (T, D) -> (expert_idx (T,k), weight (T,k), aux_loss)."""
    logits = x.astype(jnp.float32) @ router_w                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weight, expert_idx = jax.lax.top_k(probs, cfg.top_k)       # (T, k)
    weight = weight / jnp.maximum(weight.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * Σ_e fraction_e * mean_prob_e
    e = cfg.num_experts
    fraction = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0) / (x.shape[0] * cfg.top_k)
    mean_prob = probs.mean(axis=0)
    aux = e * jnp.sum(fraction * mean_prob)
    return expert_idx, weight.astype(x.dtype), aux


def dispatch_indices(expert_idx: jnp.ndarray, num_experts: int, cap: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bucket slot for each (token,k) assignment via sort-based ranking.

    Returns (slot (A,), kept (A,)) where A = T*k and slot = e*cap + rank of
    the assignment within expert e (rank >= cap -> dropped).
    """
    flat = expert_idx.reshape(-1)                              # (A,)
    a = flat.shape[0]
    order = jnp.argsort(flat, stable=True)                     # tokens grouped by expert
    counts = jnp.zeros((num_experts,), jnp.int32).at[flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    sorted_e = flat[order]
    rank_sorted = jnp.arange(a, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((a,), jnp.int32).at[order].set(rank_sorted)
    kept = rank < cap
    slot = jnp.where(kept, flat * cap + rank, num_experts * cap)  # OOB == drop
    return slot, kept


def moe_apply(params: dict, x: jnp.ndarray, arch: ArchConfig,
              cap_multiple: int = 128) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (y (B, S, D), aux_loss).  Pure; pjit-shardable."""
    cfg = arch.moe
    B, S, D = x.shape
    t = B * S
    xt = x.reshape(t, D)
    expert_idx, weight, aux = route(params["router"], xt, cfg)
    cap = capacity(t, cfg, cap_multiple)
    slot, kept = dispatch_indices(expert_idx, cfg.num_experts, cap)

    # scatter tokens (duplicated per k) into buckets; drops fall off the end
    a = t * cfg.top_k
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), cfg.top_k)
    buckets = jnp.zeros((cfg.num_experts * cap, D), x.dtype)
    buckets = buckets.at[slot].set(xt[token_of], mode="drop")
    buckets = buckets.reshape(cfg.num_experts, cap, D)

    # expert FFN: grouped einsum over the expert dim
    h_gate = jnp.einsum("ecd,edf->ecf", buckets, params["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", buckets, params["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    y_buckets = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    # gather back and combine with routing weights
    y_flat = y_buckets.reshape(cfg.num_experts * cap, D)
    gathered = jnp.where(kept[:, None], y_flat.at[slot].get(mode="fill",
                                                            fill_value=0), 0)
    contrib = gathered * weight.reshape(a, 1).astype(gathered.dtype)
    y = jnp.zeros((t, D), x.dtype).at[token_of].add(contrib.astype(x.dtype))

    if cfg.shared_expert:
        y = y + gated_mlp(params["shared"], xt, Activation.SWIGLU)
    return y.reshape(B, S, D), aux * cfg.aux_loss_weight


def expert_sharding_strategy(cfg: MoEConfig, model_shards: int) -> str:
    """'ep' — shard E over model (E % shards == 0); 'tp' — shard d_expert."""
    if cfg.num_experts % model_shards == 0:
        return "ep"
    return "tp"


# ---------------------------------------------------------------------------
# Explicit expert-parallel dispatch (shard_map) — the §Perf MoE fix
# ---------------------------------------------------------------------------
#
# The pjit/auto path above leaves dispatch locality to XLA's SPMD propagation,
# which all-gathers the full token array to every expert shard (measured:
# the dominant collective AND memory term for grok/kimi — EXPERIMENTS §Perf).
# Here the structure is explicit: routing is computed globally (cheap), then
# inside a manual ("data","model") shard_map each model column selects ONLY
# the assignments that hit its local experts from its data shard's tokens,
# computes them, and the columns combine with one psum — the same wire cost
# as a dense TP MLP layer.

def moe_apply_ep(params: dict, x: jnp.ndarray, arch: ArchConfig, mesh,
                 cap_multiple: int = 128) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE.  Requires E % mesh['model'] == 0 and
    (B·S) % mesh['data'] == 0; callers fall back to ``moe_apply`` otherwise.
    """
    from jax.sharding import PartitionSpec as P

    cfg = arch.moe
    B, S, D = x.shape
    n_model = mesh.shape["model"]
    n_data = mesh.shape["data"]
    e_local = cfg.num_experts // n_model
    t = B * S
    t_local = t // n_data
    cap = capacity(t_local, cfg, cap_multiple)

    xt = x.reshape(t, D)
    expert_idx, weight, aux = route(params["router"], xt, cfg)

    def body(xt_l, eidx_l, wgt_l, wg, wu, wd):
        col = jax.lax.axis_index("model")
        lo = col * e_local
        rel = eidx_l - lo
        valid = (rel >= 0) & (rel < e_local)
        eff = jnp.where(valid, rel, e_local).reshape(-1)     # trash bucket
        slot, kept = dispatch_indices(eff, e_local + 1, cap)
        kept &= valid.reshape(-1)
        a = t_local * cfg.top_k
        token_of = jnp.repeat(jnp.arange(t_local, dtype=jnp.int32),
                              cfg.top_k)
        buckets = jnp.zeros((e_local * cap, D), xt_l.dtype)
        buckets = buckets.at[slot].set(xt_l[token_of], mode="drop")
        buckets = buckets.reshape(e_local, cap, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buckets, wg)) \
            * jnp.einsum("ecd,edf->ecf", buckets, wu)
        yb = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_local * cap, D)
        # combine in the bucket domain: one scatter-add from (E·C, D), no
        # (T·k, D) intermediate (§Perf iteration 3)
        nslots = e_local * cap
        token_by_slot = jnp.full((nslots,), t_local, jnp.int32).at[slot].set(
            token_of, mode="drop")                       # OOB rows drop below
        w_by_slot = jnp.zeros((nslots,), yb.dtype).at[slot].set(
            (wgt_l.reshape(a) * kept).astype(yb.dtype), mode="drop")
        y = jnp.zeros((t_local, D), xt_l.dtype).at[token_by_slot].add(
            (yb * w_by_slot[:, None]).astype(xt_l.dtype), mode="drop")
        return jax.lax.psum(y, "model")

    from repro.parallel.sharding import shard_map_compat
    y = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P("data", None), P("data", None), P("data", None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P("data", None), check_vma=False,
        axis_names={"data", "model"},
    )(xt, expert_idx, weight, params["w_gate"], params["w_up"],
      params["w_down"])

    if cfg.shared_expert:
        y = y + gated_mlp(params["shared"], xt, Activation.SWIGLU)
    return y.reshape(B, S, D), aux * cfg.aux_loss_weight
