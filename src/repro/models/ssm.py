"""Mamba-2 (SSD) block: chunked-parallel scan for sequence mode, O(1)
recurrent state for decode — this is what makes zamba2 runnable at 500k
context where full attention is excluded.

Sequence mode implements the SSD chunked algorithm (intra-chunk quadratic +
inter-chunk low-rank recurrence) in pure jnp; the Pallas kernel
(kernels/ssd_chunk) replaces the intra-chunk part 1:1 on TPU.

Projections are kept *separate* (w_z/w_x/w_B/w_C/w_dt rather than one fused
in_proj) so the d_inner dim shards cleanly over the ``model`` axis while the
small B/C/dt heads stay replicated.  Same FLOPs; fusing them back is a layout
optimization XLA performs anyway.

Conventions: n_groups=1 (B, C shared across heads), A scalar per head.
    x          (B, S, D)
    x_inner    (B, S, H, P)     P = head_dim, H = expand*D / P
    B_, C_     (B, S, N)        N = state_dim
    state      (B, H, P, N)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import dense_init, rmsnorm


def ssm_dims(arch: ArchConfig) -> Tuple[int, int, int]:
    cfg = arch.ssm
    d_inner = cfg.expand * arch.d_model
    n_heads = d_inner // cfg.head_dim
    return d_inner, n_heads, cfg.state_dim


def mamba2_init(key, arch: ArchConfig, dtype=jnp.float32) -> dict:
    cfg = arch.ssm
    d = arch.d_model
    di, h, n = ssm_dims(arch)
    ks = jax.random.split(key, 9)
    return {
        "w_z": dense_init(ks[0], (d, di), dtype=dtype),
        "w_x": dense_init(ks[1], (d, di), dtype=dtype),
        "w_B": dense_init(ks[2], (d, n), dtype=dtype),
        "w_C": dense_init(ks[3], (d, n), dtype=dtype),
        "w_dt": dense_init(ks[4], (d, h), dtype=dtype),
        "conv_x": dense_init(ks[5], (cfg.conv_width, di), scale=0.5, dtype=dtype),
        "conv_B": dense_init(ks[6], (cfg.conv_width, n), scale=0.5, dtype=dtype),
        "conv_C": dense_init(ks[7], (cfg.conv_width, n), scale=0.5, dtype=dtype),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus(-2) ≈ 0.13
        "norm": jnp.zeros((di,), dtype),
        "w_out": dense_init(ks[8], (di, d), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv (width W) as shifted adds — TPU-friendly, no conv op
# ---------------------------------------------------------------------------

def causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x (B,S,C), w (W,C): y[t] = Σ_i w[i]·x[t-W+1+i]."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + pad[:, i:i + x.shape[1], :] * w[i]
    return y


def conv_step(x1: jnp.ndarray, conv_state: jnp.ndarray, w: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token conv.  x1 (B,C); conv_state (B,W-1,C) holds prior inputs."""
    window = jnp.concatenate([conv_state, x1[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", window, w)
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# SSD chunked scan (sequence mode)
# ---------------------------------------------------------------------------

def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a (..., L) -> (..., L, L) with out[i,j] = Σ_{k=j+1..i} a[k], -inf above
    the diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(x: jnp.ndarray, a_dt: jnp.ndarray, B_: jnp.ndarray,
             C_: jnp.ndarray, dt: jnp.ndarray, chunk: int,
             init_state: jnp.ndarray = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.  x (B,S,H,P); a_dt (B,S,H) = A·dt (negative);
    B_/C_ (B,S,N); dt (B,S,H).  Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:                 # largest divisor of S <= chunk
        chunk -= 1
    nc = S // chunk
    xc = x.reshape(Bb, nc, chunk, H, P)
    ac = a_dt.reshape(Bb, nc, chunk, H).transpose(0, 3, 1, 2)   # (B,H,c,l)
    Bc = B_.reshape(Bb, nc, chunk, N)
    Cc = C_.reshape(Bb, nc, chunk, N)
    dtc = dt.reshape(Bb, nc, chunk, H)
    xdt = xc * dtc[..., None]                                    # dt-weighted input

    # intra-chunk (quadratic in chunk length)
    L = jnp.exp(_segsum(ac))                                     # (B,H,c,l,l)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)               # (B,c,l,s)
    y_diag = jnp.einsum("bcls,bhcls,bcshp->bclhp", scores, L, xdt)

    # per-chunk final states
    a_cum = jnp.cumsum(ac, axis=-1)                              # (B,H,c,l)
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)              # (B,H,c,l)
    chunk_states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", Bc, decay_to_end, xdt)

    # inter-chunk recurrence over c
    chunk_decay = jnp.exp(a_cum[..., -1])                        # (B,H,c)
    if init_state is None:
        init_state = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(state, xs):
        dec, new = xs                                            # (B,H), (B,H,P,N)
        prev = state
        state = state * dec[..., None, None] + new
        return state, prev

    states_seq = (chunk_decay.transpose(2, 0, 1),
                  chunk_states.transpose(1, 0, 2, 3, 4))
    final_state, prev_states = jax.lax.scan(step, init_state, states_seq)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # (B,c,H,P,N)

    # inter-chunk contribution to outputs
    state_decay = jnp.exp(a_cum)                                 # (B,H,c,l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bb, S, H, P)
    return y, final_state


def ssd_step(x1: jnp.ndarray, a_dt1: jnp.ndarray, B1: jnp.ndarray,
             C1: jnp.ndarray, dt1: jnp.ndarray, state: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrent step.  x1 (B,H,P); a_dt1/dt1 (B,H); B1/C1 (B,N);
    state (B,H,P,N)."""
    decay = jnp.exp(a_dt1)[..., None, None]                      # (B,H,1,1)
    inject = jnp.einsum("bhp,bn->bhpn", x1 * dt1[..., None], B1)
    state = state * decay + inject
    y = jnp.einsum("bhpn,bn->bhp", state, C1)
    return y, state


# ---------------------------------------------------------------------------
# Full block (sequence + decode modes)
# ---------------------------------------------------------------------------

def mamba2_seq(params: dict, x: jnp.ndarray, arch: ArchConfig,
               return_state: bool = False):
    cfg = arch.ssm
    di, h, n = ssm_dims(arch)
    Bb, S, _ = x.shape
    z = x @ params["w_z"]
    x_pre = x @ params["w_x"]
    b_pre = x @ params["w_B"]
    c_pre = x @ params["w_C"]
    xi = jax.nn.silu(causal_conv(x_pre, params["conv_x"]))
    B_ = jax.nn.silu(causal_conv(b_pre, params["conv_B"]))
    C_ = jax.nn.silu(causal_conv(c_pre, params["conv_C"]))
    dt = jax.nn.softplus((x @ params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])                    # (B,S,H)
    a = -jnp.exp(params["A_log"])                                # (H,)
    xi_h = xi.reshape(Bb, S, h, cfg.head_dim).astype(jnp.float32)
    y, final_state = ssd_scan(xi_h, a * dt, B_.astype(jnp.float32),
                              C_.astype(jnp.float32), dt, cfg.chunk_size)
    y = y + xi_h * params["D"][:, None]
    y = y.reshape(Bb, S, di).astype(x.dtype)
    y = rmsnorm(y, params["norm"]) * jax.nn.silu(z)
    out = y @ params["w_out"]
    if not return_state:
        return out
    w = cfg.conv_width - 1
    cache = {"conv_x": x_pre[:, -w:, :], "conv_B": b_pre[:, -w:, :],
             "conv_C": c_pre[:, -w:, :],
             "state": final_state.astype(jnp.float32)}
    return out, cache


def mamba2_cache_init(arch: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    cfg = arch.ssm
    di, h, n = ssm_dims(arch)
    w = cfg.conv_width - 1
    return {
        "conv_x": jnp.zeros((batch, w, di), dtype),
        "conv_B": jnp.zeros((batch, w, n), dtype),
        "conv_C": jnp.zeros((batch, w, n), dtype),
        "state": jnp.zeros((batch, h, cfg.head_dim, n), jnp.float32),
    }


def mamba2_decode(params: dict, x1: jnp.ndarray, cache: dict,
                  arch: ArchConfig) -> Tuple[jnp.ndarray, dict]:
    """x1 (B, 1, D) -> (y (B, 1, D), cache')."""
    cfg = arch.ssm
    di, h, n = ssm_dims(arch)
    xq = x1[:, 0, :]
    z = xq @ params["w_z"]
    xi, conv_x = conv_step(xq @ params["w_x"], cache["conv_x"], params["conv_x"])
    xi = jax.nn.silu(xi)
    B_, conv_B = conv_step(xq @ params["w_B"], cache["conv_B"], params["conv_B"])
    C_, conv_C = conv_step(xq @ params["w_C"], cache["conv_C"], params["conv_C"])
    B_, C_ = jax.nn.silu(B_), jax.nn.silu(C_)
    dt = jax.nn.softplus((xq @ params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])                    # (B,H)
    a = -jnp.exp(params["A_log"])
    xi_h = xi.reshape(-1, h, cfg.head_dim).astype(jnp.float32)
    y, state = ssd_step(xi_h, a * dt, B_.astype(jnp.float32),
                        C_.astype(jnp.float32), dt, cache["state"])
    y = y + xi_h * params["D"][:, None]
    y = y.reshape(-1, di).astype(x1.dtype)
    y = rmsnorm(y, params["norm"]) * jax.nn.silu(z)
    y = y @ params["w_out"]
    return y[:, None, :], {"conv_x": conv_x, "conv_B": conv_B,
                           "conv_C": conv_C, "state": state}
