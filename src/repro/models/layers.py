"""Shared neural-net building blocks (pure jnp, pytree params)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import Activation


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dtype)


def groupnorm_heads(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    """Per-head groupnorm over the feature dim.  x: (..., H, Dh)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    if theta <= 0:
        return x
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, dim: int) -> jnp.ndarray:
    """Whisper-style absolute sinusoidal embeddings (max_len, dim)."""
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    idx = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * idx / max(dim // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def _act(x: jnp.ndarray, kind: Activation) -> jnp.ndarray:
    if kind == Activation.SWIGLU or kind == Activation.GEGLU:
        raise ValueError("gated activations handled in gated_mlp")
    if kind == Activation.GELU:
        return jax.nn.gelu(x)
    return jax.nn.relu(x)


def gated_mlp(params: dict, x: jnp.ndarray, kind: Activation) -> jnp.ndarray:
    """SwiGLU / GeGLU: down( act(x@gate) * (x@up) )."""
    gate = x @ params["w_gate"]
    up = x @ params["w_up"]
    if kind == Activation.GEGLU:
        h = jax.nn.gelu(gate) * up
    else:
        h = jax.nn.silu(gate) * up
    return h @ params["w_down"]


def plain_mlp(params: dict, x: jnp.ndarray, kind: Activation) -> jnp.ndarray:
    h = x @ params["w_up"]
    if "b_up" in params:
        h = h + params["b_up"].astype(h.dtype)
    h = _act(h, kind)
    out = h @ params["w_down"]
    if "b_down" in params:
        out = out + params["b_down"].astype(out.dtype)
    return out


def mlp_apply(params: dict, x: jnp.ndarray, kind: Activation) -> jnp.ndarray:
    if kind in (Activation.SWIGLU, Activation.GEGLU):
        return gated_mlp(params, x, kind)
    return plain_mlp(params, x, kind)


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def mlp_init(key, d_model: int, d_ff: int, kind: Activation,
             dtype=jnp.float32, bias: bool = False) -> dict:
    ks = jax.random.split(key, 3)
    if kind in (Activation.SWIGLU, Activation.GEGLU):
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy.  logits (B,S,V) f32/bf16; labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
