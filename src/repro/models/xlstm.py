"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory with hidden-state-recurrent gates, strictly
sequential ``lax.scan`` over time — the paper's own constraint).

mLSTM sequence mode uses the stabilised chunkwise form (log-space gates,
running max ``m``): within a chunk the contribution is quadratic (like flash
attention with a decay mask), across chunks a (dqk × dv) matrix state is
carried.  Decode is a single recurrent update — O(1) state, which is why
xlstm-350m runs the 500k-token shape.

Block structure (pre-LN residual):
  mLSTM block: x → up(2D)‖gate(2D) → conv4 → q,k,v → cell → groupnorm·silu(gate) → down
  sLSTM block: x → cell (block-diag recurrent gates/head) → groupnorm → GeGLU FFN(4/3)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, groupnorm_heads, rmsnorm
from repro.models.ssm import causal_conv, conv_step


def mlstm_dims(arch: ArchConfig) -> Tuple[int, int, int]:
    cfg = arch.xlstm
    di = int(cfg.proj_factor_mlstm * arch.d_model)
    h = cfg.num_heads
    return di, h, di // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, arch: ArchConfig, dtype=jnp.float32) -> dict:
    d = arch.d_model
    di, h, dh = mlstm_dims(arch)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, di), dtype=dtype),
        "w_gate": dense_init(ks[1], (d, di), dtype=dtype),
        "conv": dense_init(ks[2], (4, di), scale=0.5, dtype=dtype),
        "w_q": dense_init(ks[3], (di, di), dtype=dtype),
        "w_k": dense_init(ks[4], (di, di), dtype=dtype),
        "w_v": dense_init(ks[5], (di, di), dtype=dtype),
        "w_if": dense_init(ks[6], (di, 2 * h), scale=di ** -0.5, dtype=jnp.float32),
        "b_i": jnp.full((h,), -3.0, jnp.float32),   # sparse writes at init
        "b_f": jnp.full((h,), 3.0, jnp.float32),    # long memory at init
        "norm": jnp.zeros((h, dh), dtype),
        "w_down": dense_init(ks[7], (di, d), dtype=dtype),
    }


def _mlstm_chunk_parallel(q, k, v, log_i, log_f, carry):
    """One chunk, all heads.  q/k/v (B,H,L,dh) f32; log_i/f (B,H,L);
    carry = (C (B,H,dh,dh), n (B,H,dh), m (B,H))."""
    C, n, m = carry
    L = q.shape[2]
    b = jnp.cumsum(log_f, axis=-1)                            # (B,H,L)
    # intra-chunk decay: D[i,j] = b[i] - b[j] + log_i[j], j <= i
    D = b[..., :, None] - b[..., None, :] + log_i[..., None, :]
    D = jnp.where(jnp.tril(jnp.ones((L, L), bool)), D, -jnp.inf)
    m_intra = D.max(axis=-1)                                  # (B,H,L)
    m_inter = b + m[..., None]                                # (B,H,L)
    m_tot = jnp.maximum(m_intra, m_inter)
    scale = q.shape[-1] ** -0.5

    S = jnp.einsum("bhld,bhsd->bhls", q, k) * scale
    W = S * jnp.exp(D - m_tot[..., None])                     # weights
    h_intra = jnp.einsum("bhls,bhsd->bhld", W, v)
    dec_in = jnp.exp(m_inter - m_tot)                         # (B,H,L)
    h_inter = jnp.einsum("bhld,bhde->bhle", q * scale, C) * dec_in[..., None]

    norm_intra = W.sum(axis=-1)
    norm_inter = jnp.einsum("bhld,bhd->bhl", q * scale, n) * dec_in
    denom = jnp.maximum(jnp.abs(norm_intra + norm_inter), jnp.exp(-m_tot))
    h_out = (h_intra + h_inter) / denom[..., None]            # (B,H,L,dh)

    # carry to end of chunk
    m_next = jnp.maximum(b[..., -1] + m,
                         (b[..., -1:] - b + log_i).max(axis=-1))
    dec_C = jnp.exp(b[..., -1] + m - m_next)                  # (B,H)
    w_kv = jnp.exp(b[..., -1:] - b + log_i - m_next[..., None])  # (B,H,L)
    C_next = C * dec_C[..., None, None] + jnp.einsum(
        "bhl,bhld,bhle->bhde", w_kv, k, v)
    n_next = n * dec_C[..., None] + jnp.einsum("bhl,bhld->bhd", w_kv, k)
    return h_out, (C_next, n_next, m_next)


def mlstm_cell_seq(q, k, v, log_i, log_f, chunk: int, carry=None):
    """q/k/v (B,S,H,dh); gates (B,S,H).  Returns (h (B,S,H,dh), carry)."""
    B, S, H, dh = q.shape
    chunk = min(chunk, S)
    while S % chunk:                 # largest divisor of S <= chunk
        chunk -= 1
    nc = S // chunk
    r = lambda x: x.reshape(B, nc, chunk, H, dh).transpose(1, 0, 3, 2, 4)
    g = lambda x: x.reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)
    if carry is None:
        carry = (jnp.zeros((B, H, dh, dh), jnp.float32),
                 jnp.zeros((B, H, dh), jnp.float32),
                 jnp.zeros((B, H), jnp.float32))

    def step(c, xs):
        qc, kc, vc, ic, fc = xs
        h, c2 = _mlstm_chunk_parallel(qc, kc, vc, ic, fc, c)
        return c2, h

    carry, hs = jax.lax.scan(step, carry, (r(q), r(k), r(v), g(log_i), g(log_f)))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dh)
    return h, carry


def mlstm_cell_step(q1, k1, v1, log_i1, log_f1, carry):
    """One token.  q1/k1/v1 (B,H,dh); gates (B,H)."""
    C, n, m = carry
    m_new = jnp.maximum(log_f1 + m, log_i1)
    i_ = jnp.exp(log_i1 - m_new)
    f_ = jnp.exp(log_f1 + m - m_new)
    C = C * f_[..., None, None] + i_[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k1, v1)
    n = n * f_[..., None] + i_[..., None] * k1
    scale = q1.shape[-1] ** -0.5
    num = jnp.einsum("bhd,bhde->bhe", q1 * scale, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q1 * scale, n)),
                      jnp.exp(-m_new))
    return num / den[..., None], (C, n, m_new)


def _mlstm_qkv(params, x, arch):
    di, h, dh = mlstm_dims(arch)
    up = x @ params["w_up"]
    gate = x @ params["w_gate"]
    return up, gate


def mlstm_seq(params: dict, x: jnp.ndarray, arch: ArchConfig,
              return_state: bool = False):
    di, h, dh = mlstm_dims(arch)
    B, S, _ = x.shape
    up, gate = _mlstm_qkv(params, x, arch)
    u = jax.nn.silu(causal_conv(up, params["conv"]))
    q = (u @ params["w_q"]).reshape(B, S, h, dh).astype(jnp.float32)
    k = (u @ params["w_k"]).reshape(B, S, h, dh).astype(jnp.float32)
    v = (up @ params["w_v"]).reshape(B, S, h, dh).astype(jnp.float32)
    gates = u.astype(jnp.float32) @ params["w_if"]            # (B,S,2H)
    log_i = jax.nn.log_sigmoid(gates[..., :h] + params["b_i"])
    log_f = jax.nn.log_sigmoid(gates[..., h:] + params["b_f"])
    hcell, (C, n, m) = mlstm_cell_seq(q, k, v, log_i, log_f,
                                      arch.xlstm.chunk_size)
    hcell = groupnorm_heads(hcell.astype(x.dtype), params["norm"])
    out = hcell.reshape(B, S, di) * jax.nn.silu(gate)
    out = out @ params["w_down"]
    if not return_state:
        return out
    return out, {"conv": up[:, -3:, :], "C": C, "n": n, "m": m}


def mlstm_cache_init(arch: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    di, h, dh = mlstm_dims(arch)
    return {
        "conv": jnp.zeros((batch, 3, di), dtype),
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


def mlstm_decode(params: dict, x1: jnp.ndarray, cache: dict,
                 arch: ArchConfig) -> Tuple[jnp.ndarray, dict]:
    di, h, dh = mlstm_dims(arch)
    xq = x1[:, 0, :]
    up = xq @ params["w_up"]
    gate = xq @ params["w_gate"]
    u, conv = conv_step(up, cache["conv"], params["conv"])
    u = jax.nn.silu(u)
    q = (u @ params["w_q"]).reshape(-1, h, dh).astype(jnp.float32)
    k = (u @ params["w_k"]).reshape(-1, h, dh).astype(jnp.float32)
    v = (up @ params["w_v"]).reshape(-1, h, dh).astype(jnp.float32)
    gates = u.astype(jnp.float32) @ params["w_if"]
    log_i = jax.nn.log_sigmoid(gates[..., :h] + params["b_i"])
    log_f = jax.nn.log_sigmoid(gates[..., h:] + params["b_f"])
    hc, (C, n, m) = mlstm_cell_step(q, k, v, log_i, log_f,
                                    (cache["C"], cache["n"], cache["m"]))
    hc = groupnorm_heads(hc[:, None].astype(x1.dtype), params["norm"])[:, 0]
    out = (hc.reshape(-1, di) * jax.nn.silu(gate)) @ params["w_down"]
    return out[:, None, :], {"conv": conv, "C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM (sequential; 1-in-8 layers)
# ---------------------------------------------------------------------------

def slstm_init(key, arch: ArchConfig, dtype=jnp.float32) -> dict:
    d = arch.d_model
    h = arch.xlstm.num_heads
    dh = d // h
    dff = int(arch.xlstm.proj_factor_slstm * d)
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), dtype=dtype),       # z,i,f,o pre-acts
        "r": dense_init(ks[1], (4, h, dh, dh), scale=dh ** -0.5, dtype=dtype),
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              jnp.full((d,), 3.0, jnp.float32),   # forget bias
                              jnp.zeros((d,), jnp.float32)]),
        "norm": jnp.zeros((h, dh), dtype),
        "w_ff_gate": dense_init(ks[2], (d, dff), dtype=dtype),
        "w_ff_up": dense_init(ks[3], (d, dff), dtype=dtype),
        "w_ff_down": dense_init(ks[4], (dff, d), dtype=dtype),
    }


def slstm_cell_step(wx_t: jnp.ndarray, r: jnp.ndarray, b: jnp.ndarray,
                    carry, h_heads: int):
    """One timestep.  wx_t (B,4D) input pre-activations; r (4,H,dh,dh)
    recurrent block-diagonal weights; carry = (c,n,m,hid) each (B,H,dh)
    (m is (B,H))."""
    c, n, m, hid = carry
    B = wx_t.shape[0]
    d = wx_t.shape[1] // 4
    dh = d // h_heads
    rec = jnp.einsum("bhd,ghde->gbhe", hid, r.astype(hid.dtype))  # (4,B,H,dh)
    pre = wx_t.reshape(B, 4, h_heads, dh).transpose(1, 0, 2, 3) + \
        b.reshape(4, 1, h_heads, dh) + rec
    z = jnp.tanh(pre[0])
    i_t = pre[1].astype(jnp.float32)
    f_t = pre[2].astype(jnp.float32)
    o = jax.nn.sigmoid(pre[3])
    log_i = i_t                                                 # exp-input gate
    log_f = jax.nn.log_sigmoid(f_t)
    m_scalar = jnp.maximum(log_f + m[..., None], log_i)         # (B,H,dh) stab.
    i_ = jnp.exp(log_i - m_scalar)
    f_ = jnp.exp(log_f + m[..., None] - m_scalar)
    c = f_ * c + i_ * z.astype(jnp.float32)
    n = f_ * n + i_
    hid_new = (o.astype(jnp.float32) * c / jnp.maximum(n, 1e-6)).astype(hid.dtype)
    m_new = m_scalar.max(axis=-1)                               # per-head stabiliser
    return (c, n, m_new, hid_new), hid_new


def slstm_cache_init(arch: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    h = arch.xlstm.num_heads
    dh = arch.d_model // h
    return {
        "c": jnp.zeros((batch, h, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
        "h": jnp.zeros((batch, h, dh), dtype),
    }


def _slstm_cell(params, x, arch, carry):
    h = arch.xlstm.num_heads
    wx = x @ params["w_in"]                                     # (B,S,4D)

    def step(c, wx_t):
        return slstm_cell_step(wx_t, params["r"], params["b"], c, h)

    carry, hs = jax.lax.scan(step, carry, wx.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2, 3), carry                      # (B,S,H,dh)


def slstm_seq(params: dict, x: jnp.ndarray, arch: ArchConfig,
              return_state: bool = False):
    B, S, d = x.shape
    h = arch.xlstm.num_heads
    init = slstm_cache_init(arch, B, x.dtype)
    hs, carry = _slstm_cell(params, x, arch,
                            (init["c"], init["n"], init["m"], init["h"]))
    y = groupnorm_heads(hs.astype(x.dtype), params["norm"]).reshape(B, S, d)
    # GeGLU FFN (proj factor 4/3)
    g = jax.nn.gelu(y @ params["w_ff_gate"]) * (y @ params["w_ff_up"])
    out = g @ params["w_ff_down"]
    if not return_state:
        return out
    return out, {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}


def slstm_decode(params: dict, x1: jnp.ndarray, cache: dict,
                 arch: ArchConfig) -> Tuple[jnp.ndarray, dict]:
    B, _, d = x1.shape
    h = arch.xlstm.num_heads
    wx = (x1[:, 0, :] @ params["w_in"])
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    carry, hid = slstm_cell_step(wx, params["r"], params["b"], carry, h)
    y = groupnorm_heads(hid[:, None].astype(x1.dtype),
                        params["norm"]).reshape(B, 1, d)
    g = jax.nn.gelu(y @ params["w_ff_gate"]) * (y @ params["w_ff_up"])
    out = g @ params["w_ff_down"]
    return out, {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
