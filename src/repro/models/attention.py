"""Attention: GQA/MHA/MQA with a blockwise online-softmax reference path.

The sequence path (train/prefill) is *flash-structured* pure JAX: a
``lax.scan`` over KV blocks with online softmax, so peak memory is
O(S·block) instead of O(S²) while HLO FLOPs remain the true 2·S²·D cost.
On TPU the Pallas kernel (kernels/flash_attention) replaces it 1:1 via
``AttnImpl.FLASH``; on CPU (tests, dry-run) the reference path lowers.

Decode is a single-token gather-free einsum against the full cache — the
memory-bound op the roofline's memory term is dominated by.

Shapes (conventions used across the model zoo):
    x            (B, S, D)
    q            (B, S, H, Dh)
    k, v         (B, S, KV, Dh)
    cache k/v    (B, Smax, KV, Dh)  + scalar ``length`` (tokens filled)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, AttnImpl
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_init(key, arch: ArchConfig, d_in: Optional[int] = None,
              dtype=jnp.float32) -> dict:
    d = d_in or arch.d_model
    dh = arch.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, arch.num_heads * dh), dtype=dtype),
        "wk": dense_init(ks[1], (d, arch.num_kv_heads * dh), dtype=dtype),
        "wv": dense_init(ks[2], (d, arch.num_kv_heads * dh), dtype=dtype),
        "wo": dense_init(ks[3], (arch.num_heads * dh, arch.d_model), dtype=dtype),
    }
    if arch.qkv_bias:
        p["bq"] = jnp.zeros((arch.num_heads * dh,), dtype)
        p["bk"] = jnp.zeros((arch.num_kv_heads * dh,), dtype)
        p["bv"] = jnp.zeros((arch.num_kv_heads * dh,), dtype)
    return p


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def _project_qkv(params: dict, xq: jnp.ndarray, xkv: jnp.ndarray,
                 arch: ArchConfig):
    dh = arch.resolved_head_dim
    q = xq @ params["wq"]
    k = xkv @ params["wk"]
    v = xkv @ params["wv"]
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    B, Sq = xq.shape[:2]
    Skv = xkv.shape[1]
    q = q.reshape(B, Sq, arch.num_heads, dh)
    k = k.reshape(B, Skv, arch.num_kv_heads, dh)
    v = v.reshape(B, Skv, arch.num_kv_heads, dh)
    return q, k, v


# ---------------------------------------------------------------------------
# Blockwise online-softmax attention (the flash-structured reference)
# ---------------------------------------------------------------------------

def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        q_positions: jnp.ndarray, kv_positions: jnp.ndarray,
                        causal: bool = True, window: int = 0,
                        kv_block: int = 512) -> jnp.ndarray:
    """Online-softmax attention scanned over KV blocks.

    q (B,Sq,H,Dh); k,v (B,Skv,KV,Dh); positions (B,S) int32.
    GQA handled by grouping: H = KV * G, scores computed per (KV, G) pair so
    K/V are never materialised per query head.
    window > 0 restricts attention to the last ``window`` positions
    (sliding-window; used by zamba2's shared block in long mode).
    """
    B, Sq, H, Dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = Dh ** -0.5
    blk = min(kv_block, Skv)
    while Skv % blk:                      # static; shapes are powers of two here
        blk //= 2
    nblk = Skv // blk

    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, Sq, KV, G, Dh)
    kb = k.reshape(B, nblk, blk, KV, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, blk, KV, Dh).transpose(1, 0, 2, 3, 4)
    pb = kv_positions.reshape(B, nblk, blk).transpose(1, 0, 2)

    def step(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, p_blk = xs            # (B,blk,KV,Dh), (B,blk)
        # bf16 operands, f32 accumulation: no f32 copy of K/V is ever made
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k_blk,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((B, Sq, 1, 1, blk), bool)
        if causal:
            mask &= (q_positions[:, :, None, None, None]
                     >= p_blk[:, None, None, None, :])
        if window > 0:
            mask &= (q_positions[:, :, None, None, None]
                     - p_blk[:, None, None, None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def qscan_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    q_positions: jnp.ndarray, kv_positions: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    q_block: int = 512) -> jnp.ndarray:
    """Scan over QUERY blocks with a full-row one-pass softmax.

    Versus the kv-block scan, nothing f32 is carried across steps — the
    (B,S,H,Dh) f32 accumulator read-modify-writes disappear (§Perf iter 4).
    K/V stay resident (bf16, ~100 MB/device at assigned shapes); per-step
    live memory is one (B, bq, H, Skv) f32 score block."""
    B, Sq, H, Dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    blk = min(q_block, Sq)
    while Sq % blk:
        blk //= 2
    nblk = Sq // blk
    qg = (q * jnp.asarray(Dh ** -0.5, q.dtype)).reshape(B, nblk, blk, KV, G,
                                                        Dh).transpose(
        1, 0, 2, 3, 4, 5)
    pq = q_positions.reshape(B, nblk, blk).transpose(1, 0, 2)

    def step(_, xs):
        q_blk, p_blk = xs                    # (B,blk,KV,G,Dh), (B,blk)
        s = jnp.einsum("bqkgd,bskd->bqkgs", q_blk, k,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((B, blk, 1, 1, Skv), bool)
        if causal:
            mask &= (p_blk[:, :, None, None, None]
                     >= kv_positions[:, None, None, None, :])
        if window > 0:
            mask &= (p_blk[:, :, None, None, None]
                     - kv_positions[:, None, None, None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return 0, out.astype(q.dtype)

    _, outs = jax.lax.scan(step, 0, (qg, pq))      # (nblk,B,blk,KV,G,Dh)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, Dh)


def reference_attention(q, k, v, q_positions, kv_positions, causal=True,
                        window: int = 0) -> jnp.ndarray:
    """O(S²)-memory oracle used only by tests at tiny shapes."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(jnp.float32))
    s = s * (Dh ** -0.5)
    mask = jnp.ones((B, Sq, 1, 1, k.shape[1]), bool)
    if causal:
        mask &= (q_positions[:, :, None, None, None]
                 >= kv_positions[:, None, None, None, :])
    if window > 0:
        mask &= (q_positions[:, :, None, None, None]
                 - kv_positions[:, None, None, None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Sequence-mode self-attention (train / prefill)
# ---------------------------------------------------------------------------

def self_attention(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                   arch: ArchConfig, causal: bool = True, window: int = 0,
                   impl: AttnImpl = AttnImpl.REFERENCE,
                   kv_block: int = 512) -> jnp.ndarray:
    q, k, v = _project_qkv(params, x, x, arch)
    if arch.rope_theta > 0:
        q = apply_rope(q, positions, arch.rope_theta)
        k = apply_rope(k, positions, arch.rope_theta)
    if impl == AttnImpl.FLASH:
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal, window=window)
    elif impl == AttnImpl.QSCAN:
        out = qscan_attention(q, k, v, positions, positions, causal=causal,
                              window=window)
    else:
        out = blockwise_attention(q, k, v, positions, positions,
                                  causal=causal, window=window,
                                  kv_block=kv_block)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ params["wo"]


def cross_attention(params: dict, x: jnp.ndarray, kv_cache_k: jnp.ndarray,
                    kv_cache_v: jnp.ndarray, arch: ArchConfig) -> jnp.ndarray:
    """Decoder->encoder cross-attention against precomputed K/V (whisper)."""
    B, Sq = x.shape[:2]
    dh = arch.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, Sq, arch.num_heads, dh)
    if "bq" in params:
        q = q + params["bq"].reshape(arch.num_heads, dh).astype(q.dtype)
    Skv = kv_cache_k.shape[1]
    pos_q = jnp.zeros((B, Sq), jnp.int32)
    pos_kv = jnp.zeros((B, Skv), jnp.int32)
    out = blockwise_attention(q, kv_cache_k, kv_cache_v, pos_q, pos_kv,
                              causal=False)
    return out.reshape(B, Sq, -1) @ params["wo"]


def project_cross_kv(params: dict, enc_out: jnp.ndarray, arch: ArchConfig):
    """K/V of the encoder output, computed once at prefill (whisper)."""
    B, S = enc_out.shape[:2]
    dh = arch.resolved_head_dim
    k = (enc_out @ params["wk"]).reshape(B, S, arch.num_kv_heads, dh)
    v = (enc_out @ params["wv"]).reshape(B, S, arch.num_kv_heads, dh)
    if "bk" in params:
        k = k + params["bk"].reshape(arch.num_kv_heads, dh).astype(k.dtype)
        v = v + params["bv"].reshape(arch.num_kv_heads, dh).astype(v.dtype)
    return k, v


# ---------------------------------------------------------------------------
# Flash decode: partial softmax over the sequence-sharded cache (§Perf B)
# ---------------------------------------------------------------------------

def flash_decode_attention(q: jnp.ndarray, cache_k: jnp.ndarray,
                           cache_v: jnp.ndarray, length, mesh,
                           axis: str = "model") -> jnp.ndarray:
    """Decode attention with the cache sharded on the SEQUENCE dim.

    Baseline XLA propagation re-gathers the whole cache to softmax over the
    full sequence (the 'involuntary full rematerialization' warnings and the
    dominant decode memory+collective term).  Here each shard computes a
    partial softmax over its local S/n slice and the shards combine with
    three tiny collectives (pmax of the max, psum of the normaliser and of
    the weighted values) — flash-decode, expressed in shard_map.

    q (B,1,KV,G,Dh) f32-scaled not required; cache (B,S,KV,Dh) sharded on S.
    Returns (B,1,KV,G,Dh) f32, replicated over `axis`.
    """
    from jax.sharding import PartitionSpec as P

    n = mesh.shape[axis]
    S = cache_k.shape[1]
    s_local = S // n

    def body(qb, ck, cv, ln):
        shard = jax.lax.axis_index(axis)
        base = shard * s_local
        s = jnp.einsum("bqkgd,bskd->bqkgs", qb, ck,
                       preferred_element_type=jnp.float32)
        idx = base + jnp.arange(s_local)
        s = jnp.where((idx <= ln)[None, None, None, None, :], s, NEG_INF)
        m = s.max(axis=-1)                                   # (B,1,KV,G)
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        o = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(cv.dtype), cv,
                       preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axis)
        o_g = jax.lax.psum(o * corr[..., None], axis)
        return o_g / jnp.maximum(l_g, 1e-30)[..., None]

    from repro.parallel.sharding import shard_map_compat
    return shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None),
                  P()),
        out_specs=P(), check_vma=False, axis_names={axis},
    )(q, cache_k, cache_v, length)


def decode_self_attention_sharded(params: dict, x1: jnp.ndarray,
                                  cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                                  length, arch: ArchConfig, mesh
                                  ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                             jnp.ndarray]:
    """decode_self_attention with the flash-decode read path."""
    B = x1.shape[0]
    dh = arch.resolved_head_dim
    pos = jnp.broadcast_to(length, (B, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(params, x1, x1, arch)
    if arch.rope_theta > 0:
        q = apply_rope(q, pos, arch.rope_theta)
        k = apply_rope(k, pos, arch.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, length, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, length, 0, 0))
    KV = cache_k.shape[2]
    G = arch.num_heads // KV
    qg = (q * jnp.asarray(dh ** -0.5, q.dtype)).reshape(B, 1, KV, G, dh)
    out = flash_decode_attention(qg, cache_k, cache_v, length, mesh)
    out = out.reshape(B, 1, -1).astype(x1.dtype) @ params["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# Decode mode (one token, KV cache)
# ---------------------------------------------------------------------------

def decode_self_attention(params: dict, x1: jnp.ndarray, cache_k: jnp.ndarray,
                          cache_v: jnp.ndarray, length: jnp.ndarray,
                          arch: ArchConfig, window: int = 0
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step.  x1 (B,1,D); cache (B,Smax,KV,Dh); length scalar.

    Returns (attn_out (B,1,D), cache_k', cache_v').
    """
    B = x1.shape[0]
    dh = arch.resolved_head_dim
    pos = jnp.broadcast_to(length, (B, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(params, x1, x1, arch)
    if arch.rope_theta > 0:
        q = apply_rope(q, pos, arch.rope_theta)
        k = apply_rope(k, pos, arch.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, length, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, length, 0, 0))

    Smax, KV = cache_k.shape[1], cache_k.shape[2]
    G = arch.num_heads // KV
    qg = (q * jnp.asarray(dh ** -0.5, q.dtype)).reshape(B, 1, KV, G, dh)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, cache_k,
                   preferred_element_type=jnp.float32)
    idx = jnp.arange(Smax)
    valid = idx <= length
    if window > 0:
        valid &= idx > length - window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, -1).astype(x1.dtype) @ params["wo"]
    return out, cache_k, cache_v
