"""Model-zoo public API: params, caches, steps, analytic counts, input specs.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of a cell — the dry-run lowers against these (no allocation).
Modality frontends are stubs per spec: vlm cells receive precomputed CLIP
patch embeddings, audio cells precomputed frame embeddings.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig, StepKind
from repro.models import transformer
from repro.models.ssm import ssm_dims
from repro.models.xlstm import mlstm_dims

init_params = transformer.init_params
forward_seq = transformer.forward_seq
decode_step = transformer.decode_step
init_cache = transformer.init_cache
lm_loss = transformer.lm_loss


# ---------------------------------------------------------------------------
# Analytic parameter counts (roofline MODEL_FLOPS = 6·N·D)
# ---------------------------------------------------------------------------

def analytic_param_count(arch: ArchConfig, active_only: bool = False) -> int:
    d, dh = arch.d_model, arch.resolved_head_dim
    n = 0
    # embeddings (+ untied head)
    n += arch.vocab_size * d
    if not arch.tie_embeddings:
        n += d * arch.vocab_size

    def attn_params() -> int:
        a = d * arch.num_heads * dh + 2 * d * arch.num_kv_heads * dh \
            + arch.num_heads * dh * d
        if arch.qkv_bias:
            a += arch.num_heads * dh + 2 * arch.num_kv_heads * dh
        return a

    def mlp_params(dff: int) -> int:
        gated = arch.activation.value in ("swiglu", "geglu")
        return (3 if gated else 2) * d * dff

    if arch.family in ("dense", "vlm"):
        n += arch.num_layers * (attn_params() + mlp_params(arch.d_ff) + 2 * d)
    elif arch.family == "moe":
        cfg = arch.moe
        e = cfg.top_k if active_only else cfg.num_experts
        per = attn_params() + d * cfg.num_experts  # router always dense
        per += e * 3 * d * cfg.d_expert
        if cfg.shared_expert:
            per += 3 * d * cfg.d_expert
        n += arch.num_layers * (per + 2 * d)
    elif arch.family == "ssm":      # xlstm
        di, h, _ = mlstm_dims(arch)
        mlstm = 2 * d * di + 4 * di + 3 * di * di + di * 2 * h + 2 * h \
            + di + di * d
        dff = int(arch.xlstm.proj_factor_slstm * d)
        hh = arch.xlstm.num_heads
        slstm = d * 4 * d + 4 * hh * (d // hh) ** 2 + 4 * d + d + 3 * d * dff
        per = arch.xlstm.slstm_every
        groups = max(1, arch.num_layers // per)
        n += groups * ((per - 1) * (mlstm + d) + slstm + d)
    elif arch.family == "hybrid":   # zamba2
        di, h, ns = ssm_dims(arch)
        mamba = 2 * d * di + 2 * d * ns + d * h + 4 * (di + 2 * ns) \
            + 3 * h + di + di * d + d
        n += arch.num_layers * mamba
        n += attn_params() + mlp_params(arch.d_ff) + 2 * d  # ONE shared block
    elif arch.family == "audio":
        enc = attn_params() + mlp_params(arch.d_ff) + 2 * d
        dec = 2 * attn_params() + mlp_params(arch.d_ff) + 3 * d
        n += arch.encoder_layers * enc + arch.num_layers * dec + d * d + d
    return n


def model_flops(arch: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D per generated/processed token
    for inference (N = active params)."""
    n_active = analytic_param_count(arch, active_only=True)
    if shape.step is StepKind.TRAIN:
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.step is StepKind.PREFILL:
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.step is StepKind.TRAIN or shape.step is StepKind.PREFILL:
        text = S
        specs = {"tokens": sds((B, text), i32)}
        if shape.step is StepKind.TRAIN:
            specs["labels"] = sds((B, text), i32)
            specs["loss_mask"] = sds((B, text), jnp.float32)
        if arch.frontend_stub == "clip_patches":
            specs["patch_embeds"] = sds((B, arch.num_patches, arch.d_model),
                                        jnp.float32)
        if arch.frontend_stub == "audio_frames":
            specs["frame_embeds"] = sds((B, arch.num_patches, arch.d_model),
                                        jnp.float32)
        return specs
    # decode: one token + the populated cache built at S
    specs = {"token": sds((B, 1), i32)}
    return specs


def cache_specs(arch: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStructs matching init_cache (for decode dry-runs)."""
    cache = jax.eval_shape(
        lambda: transformer.init_cache(arch, shape.global_batch,
                                       shape.seq_len, dtype))
    return cache


def example_batch(arch: ArchConfig, shape: ShapeConfig, key) -> dict:
    """Materialised small batch for smoke tests (use reduced configs only)."""
    specs = input_specs(arch, shape)
    out = {}
    for name, s in specs.items():
        k, key = jax.random.split(key)
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(k, s.shape, 0,
                                           min(arch.vocab_size, 1000), s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, s.dtype) * 0.02
    if "loss_mask" in out:
        out["loss_mask"] = jnp.ones(out["loss_mask"].shape, jnp.float32)
        if arch.frontend_stub == "clip_patches":
            # no next-token loss on patch positions
            out["loss_mask"] = out["loss_mask"].at[:, :arch.num_patches].set(0)
    return out
