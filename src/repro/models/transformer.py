"""Layer-stack orchestrator for every assigned architecture.

A model is a sequence of *segments*; each segment is a homogeneous run of
layers whose parameters are stacked on a leading axis and executed with
``lax.scan`` (keeps the HLO size independent of depth — essential for the
80-layer dry-runs).  Heterogeneous stacks (zamba2's shared-attention groups,
xlstm's 7:1 mLSTM:sLSTM pattern) become nested scans over *groups*.

Segment plans (family → structure):
  dense / vlm        scan L × [attn + mlp]
  moe                scan L × [attn + moe]
  ssm (xlstm)        scan G × [scan 7 × mlstm; slstm]           (G=L/8)
  hybrid (zamba2)    scan G × [scan 6 × mamba2; SHARED attn+mlp] (+ tail)
  audio (whisper)    scan 4 × [enc attn + mlp]; scan 4 × [self + cross + mlp]

Every block type implements both modes:
  seq(params, x, positions)           -> y            (train / prefill)
  decode(params, x1, cache, length)   -> y, cache'    (one token)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import Activation, ArchConfig, AttnImpl
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (cross_entropy_loss, dense_init, mlp_apply,
                                 mlp_init, rmsnorm, sinusoidal_positions)


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------

def plan(arch: ArchConfig) -> Dict[str, Any]:
    """Static structure of the layer stack."""
    if arch.family in ("dense", "vlm"):
        return {"kind": "dense", "layers": arch.num_layers}
    if arch.family == "moe":
        return {"kind": "moe", "layers": arch.num_layers}
    if arch.family == "ssm":        # xlstm
        per = arch.xlstm.slstm_every
        groups = max(1, arch.num_layers // per)
        return {"kind": "xlstm", "groups": groups, "mlstm_per": per - 1}
    if arch.family == "hybrid":     # zamba2
        per = arch.shared_attn_every
        groups = arch.num_layers // per
        tail = arch.num_layers - groups * per
        return {"kind": "zamba", "groups": groups, "mamba_per": per,
                "tail": tail}
    if arch.family == "audio":
        return {"kind": "whisper", "enc": arch.encoder_layers,
                "dec": arch.num_layers}
    raise ValueError(arch.family)


# ---------------------------------------------------------------------------
# Per-block params
# ---------------------------------------------------------------------------

def _dense_layer_init(key, arch: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((arch.d_model,), dtype),
        "attn": attn.attn_init(k1, arch, dtype=dtype),
        "ln2": jnp.zeros((arch.d_model,), dtype),
        "mlp": mlp_init(k2, arch.d_model, arch.d_ff, arch.activation,
                        dtype=dtype),
    }


def _moe_layer_init(key, arch: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((arch.d_model,), dtype),
        "attn": attn.attn_init(k1, arch, dtype=dtype),
        "ln2": jnp.zeros((arch.d_model,), dtype),
        "moe": moe_mod.moe_init(k2, arch, dtype=dtype),
    }


def _mamba_layer_init(key, arch: ArchConfig, dtype) -> dict:
    return {
        "ln": jnp.zeros((arch.d_model,), dtype),
        "mamba": ssm_mod.mamba2_init(key, arch, dtype=dtype),
    }


def _whisper_enc_layer_init(key, arch: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((arch.d_model,), dtype),
        "attn": attn.attn_init(k1, arch, dtype=dtype),
        "ln2": jnp.zeros((arch.d_model,), dtype),
        "mlp": mlp_init(k2, arch.d_model, arch.d_ff, arch.activation,
                        dtype=dtype, bias=False),
    }


def _whisper_dec_layer_init(key, arch: ArchConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((arch.d_model,), dtype),
        "self_attn": attn.attn_init(k1, arch, dtype=dtype),
        "ln_x": jnp.zeros((arch.d_model,), dtype),
        "cross_attn": attn.attn_init(k2, arch, dtype=dtype),
        "ln2": jnp.zeros((arch.d_model,), dtype),
        "mlp": mlp_init(k3, arch.d_model, arch.d_ff, arch.activation,
                        dtype=dtype, bias=False),
    }


def _stack_init(layer_init, key, n: int, arch: ArchConfig, dtype):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_init(k, arch, dtype))(keys)


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init_params(arch: ArchConfig, key, dtype=jnp.float32) -> dict:
    p = plan(arch)
    ks = jax.random.split(key, 8)
    params: dict = {
        "embed": dense_init(ks[0], (arch.vocab_size, arch.d_model),
                            scale=1.0, dtype=dtype),
        "final_norm": jnp.zeros((arch.d_model,), dtype),
    }
    if not arch.tie_embeddings:
        params["lm_head"] = dense_init(
            ks[1], (arch.d_model, arch.vocab_size), dtype=dtype)

    if p["kind"] == "dense":
        params["blocks"] = _stack_init(_dense_layer_init, ks[2], p["layers"],
                                       arch, dtype)
    elif p["kind"] == "moe":
        params["blocks"] = _stack_init(_moe_layer_init, ks[2], p["layers"],
                                       arch, dtype)
    elif p["kind"] == "xlstm":
        def group_init(k, a, dt):
            k1, k2 = jax.random.split(k)
            return {
                "mlstm": _stack_init(
                    lambda kk, aa, dd: {
                        "ln": jnp.zeros((aa.d_model,), dd),
                        "cell": xlstm_mod.mlstm_init(kk, aa, dtype=dd)},
                    k1, p["mlstm_per"], a, dt),
                "slstm": {"ln": jnp.zeros((a.d_model,), dt),
                          "cell": xlstm_mod.slstm_init(k2, a, dtype=dt)},
            }
        params["blocks"] = _stack_init(group_init, ks[2], p["groups"],
                                       arch, dtype)
    elif p["kind"] == "zamba":
        params["blocks"] = _stack_init(
            lambda k, a, dt: _stack_init(_mamba_layer_init, k, p["mamba_per"],
                                         a, dt),
            ks[2], p["groups"], arch, dtype)
        if p["tail"]:
            params["tail"] = _stack_init(_mamba_layer_init, ks[3], p["tail"],
                                         arch, dtype)
        params["shared"] = _dense_layer_init(ks[4], arch, dtype)  # ONE copy
    elif p["kind"] == "whisper":
        params["enc_blocks"] = _stack_init(_whisper_enc_layer_init, ks[2],
                                           p["enc"], arch, dtype)
        params["dec_blocks"] = _stack_init(_whisper_dec_layer_init, ks[3],
                                           p["dec"], arch, dtype)
        params["enc_norm"] = jnp.zeros((arch.d_model,), dtype)
        # frontend stub adapter: frame embeddings -> d_model
        params["frame_proj"] = dense_init(ks[5], (arch.d_model, arch.d_model),
                                          dtype=dtype)
    if arch.frontend_stub == "clip_patches":
        params["patch_proj"] = dense_init(ks[6], (arch.d_model, arch.d_model),
                                          dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Block apply (sequence mode)
# ---------------------------------------------------------------------------

def _dense_block_seq(lp, x, positions, arch, impl, window=0, causal=True):
    x = x + attn.self_attention(lp["attn"], rmsnorm(x, lp["ln1"]), positions,
                                arch, causal=causal, window=window, impl=impl)
    x = x + mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"]), arch.activation)
    return x


def _moe_block_seq(lp, x, positions, arch, impl, mesh=None,
                   moe_impl="auto"):
    x = x + attn.self_attention(lp["attn"], rmsnorm(x, lp["ln1"]), positions,
                                arch, impl=impl)
    xn = rmsnorm(x, lp["ln2"])
    B, S, _ = xn.shape
    ep_ok = (mesh is not None
             and arch.moe.num_experts % mesh.shape["model"] == 0
             and (B * S) % mesh.shape["data"] == 0)
    if moe_impl == "ep" and ep_ok:
        y, aux = moe_mod.moe_apply_ep(lp["moe"], xn, arch, mesh)
    else:
        y, aux = moe_mod.moe_apply(lp["moe"], xn, arch)
    return x + y, aux


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "block":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def _scan(body, carry, xs, use_scan: bool = True):
    """lax.scan or an unrolled python loop over stacked xs (identical
    semantics).  The unrolled form exists for the roofline depth probes:
    XLA cost_analysis counts a while body once, so per-layer costs are
    extracted from small unrolled builds (launch/roofline.py)."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys) if ys else None
    return carry, stacked


# ---------------------------------------------------------------------------
# Sequence forward (train / prefill).  Returns (logits, aux_loss, cache|None)
# ---------------------------------------------------------------------------

def forward_seq(arch: ArchConfig, params: dict, tokens: jnp.ndarray,
                positions: Optional[jnp.ndarray] = None,
                extra: Optional[dict] = None,
                impl: AttnImpl = AttnImpl.REFERENCE,
                remat: str = "none",
                return_cache: bool = False,
                use_scan: bool = True,
                mesh=None, moe_impl: str = "auto",
                compute_dtype=jnp.bfloat16):
    p = plan(arch)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    x = x * jnp.asarray(arch.d_model ** 0.5, compute_dtype)

    if arch.frontend_stub == "clip_patches":
        patches = extra["patch_embeds"].astype(compute_dtype) @ \
            params["patch_proj"].astype(compute_dtype)
        x = jnp.concatenate([patches, x[:, :S - arch.num_patches]], axis=1)

    aux_total = jnp.zeros((), jnp.float32)
    cache = {} if return_cache else None
    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype)
                                  if a.dtype == jnp.float32 and a.ndim > 1
                                  else a, t)

    if p["kind"] == "whisper":
        x, cache, aux_total = _whisper_seq(arch, params, x, positions, extra,
                                           impl, remat, return_cache,
                                           use_scan, compute_dtype)
    elif p["kind"] == "dense":
        def body(x, lp):
            lp = cast(lp)
            y = _dense_block_seq(lp, x, positions, arch, impl)
            c = _layer_kv(lp, x, positions, arch) if return_cache else 0
            return y, c
        x, kv = _scan(_remat(body, remat), x, params["blocks"], use_scan)
        if return_cache:
            cache["k"], cache["v"] = kv
    elif p["kind"] == "moe":
        def body(x, lp):
            lp = cast(lp)
            y, aux = _moe_block_seq(lp, x, positions, arch, impl, mesh,
                                    moe_impl)
            c = _layer_kv(lp, x, positions, arch) if return_cache else 0
            return y, (aux, c)
        x, (auxs, kv) = _scan(_remat(body, remat), x, params["blocks"], use_scan)
        aux_total = aux_total + auxs.sum()
        if return_cache:
            cache["k"], cache["v"] = kv
    elif p["kind"] == "xlstm":
        def group(x, gp):
            gp = cast(gp)
            def mbody(x, lp):
                y = xlstm_mod.mlstm_seq(lp["cell"], rmsnorm(x, lp["ln"]),
                                        arch, return_state=return_cache)
                if return_cache:
                    y, mc = y
                    return x + y, mc
                return x + y, 0
            x, mcs = _scan(_remat(mbody, remat), x, gp["mlstm"], use_scan)
            y = xlstm_mod.slstm_seq(gp["slstm"]["cell"],
                                    rmsnorm(x, gp["slstm"]["ln"]), arch,
                                    return_state=return_cache)
            if return_cache:
                y, sc = y
                return x + y, (mcs, sc)
            return x + y, 0
        x, gcs = _scan(group, x, params["blocks"], use_scan)
        if return_cache:
            cache["mlstm"], cache["slstm"] = gcs
    elif p["kind"] == "zamba":
        shared = cast(params["shared"])
        win = arch.sliding_window if 0 < arch.sliding_window < S else S

        def mamba_body(x, lp):
            y = ssm_mod.mamba2_seq(lp["mamba"], rmsnorm(x, lp["ln"]), arch,
                                   return_state=return_cache)
            if return_cache:
                y, mc = y
                return x + y, mc
            return x + y, 0

        def group(x, gp):
            gp = cast(gp)
            x, mcs = _scan(_remat(mamba_body, remat), x, gp, use_scan)
            x_pre = x
            x = _dense_block_seq(shared, x, positions, arch, impl,
                                 window=arch.sliding_window)
            if return_cache:
                k, v = _layer_kv(shared, x_pre, positions, arch)
                # ring layout: position p -> slot p % win; the last `win`
                # positions land on slots (S-win+i) % win == i when win | S
                c = (mcs, k[:, -win:], v[:, -win:])
            else:
                c = 0
            return x, c
        x, kv = _scan(group, x, params["blocks"], use_scan)
        if return_cache:
            mcs, k, v = kv
            cache["mamba"] = mcs
            cache["shared_k"], cache["shared_v"] = k, v
            G = k.shape[0]
            pos = jnp.broadcast_to(
                jnp.arange(S - win, S, dtype=jnp.int32),
                (G, k.shape[1], win))
            cache["shared_pos"] = pos
        if p["tail"]:
            def tbody(x, lp):
                lp = cast(lp)
                return mamba_body(x, lp)
            x, tcs = _scan(_remat(tbody, remat), x, params["tail"], use_scan)
            if return_cache:
                cache["tail"] = tcs

    x = rmsnorm(x, params["final_norm"])
    head = (params["embed"].T if arch.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(compute_dtype)
    return logits, aux_total, cache


def _layer_kv(lp, x_in, positions, arch):
    """Recompute this layer's K/V for the prefill cache (cheap vs attention)."""
    xn = rmsnorm(x_in, lp["ln1"])
    dh = arch.resolved_head_dim
    B, S = xn.shape[:2]
    k = (xn @ lp["attn"]["wk"]).reshape(B, S, arch.num_kv_heads, dh)
    v = (xn @ lp["attn"]["wv"]).reshape(B, S, arch.num_kv_heads, dh)
    if "bk" in lp["attn"]:
        k = k + lp["attn"]["bk"].reshape(arch.num_kv_heads, dh).astype(k.dtype)
        v = v + lp["attn"]["bv"].reshape(arch.num_kv_heads, dh).astype(v.dtype)
    if arch.rope_theta > 0:
        k = attn.apply_rope(k, positions, arch.rope_theta)
    return k, v


def _whisper_seq(arch, params, x, positions, extra, impl, remat,
                 return_cache, use_scan, compute_dtype):
    """Encoder over frame embeddings, decoder over tokens.  x is the decoder
    token embedding; extra['frame_embeds'] is (B, F, D) from the stub."""
    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype)
                                  if a.dtype == jnp.float32 and a.ndim > 1
                                  else a, t)
    frames = extra["frame_embeds"].astype(compute_dtype)
    frames = frames @ params["frame_proj"].astype(compute_dtype)
    F = frames.shape[1]
    frames = frames + sinusoidal_positions(F, arch.d_model).astype(compute_dtype)
    enc_pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32),
                               (frames.shape[0], F))

    def enc_body(h, lp):
        lp = cast(lp)
        h = _dense_block_seq(lp, h, enc_pos, arch, impl, causal=False)
        return h, 0
    enc, _ = _scan(_remat(enc_body, remat), frames, params["enc_blocks"],
                   use_scan)
    enc = rmsnorm(enc, params["enc_norm"])

    S = x.shape[1]
    x = x + sinusoidal_positions(S, arch.d_model).astype(compute_dtype)

    def dec_body(h, lp):
        lp = cast(lp)
        h_pre = h
        h = h + attn.self_attention(lp["self_attn"], rmsnorm(h, lp["ln1"]),
                                    positions, arch, causal=True, impl=impl)
        ck, cv = attn.project_cross_kv(lp["cross_attn"], enc, arch)
        h = h + attn.cross_attention(lp["cross_attn"], rmsnorm(h, lp["ln_x"]),
                                     ck, cv, arch)
        h = h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln2"]), arch.activation)
        c = ((_layer_kv_whisper(lp, h_pre, positions, arch), (ck, cv))
             if return_cache else 0)
        return h, c
    x, kv = _scan(_remat(dec_body, remat), x, params["dec_blocks"], use_scan)
    cache = {}
    if return_cache:
        (sk, sv), (ck, cv) = kv
        cache = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}
    return x, cache, jnp.zeros((), jnp.float32)


def _layer_kv_whisper(lp, x_in, positions, arch):
    xn = rmsnorm(x_in, lp["ln1"])
    dh = arch.resolved_head_dim
    B, S = xn.shape[:2]
    k = (xn @ lp["self_attn"]["wk"]).reshape(B, S, arch.num_kv_heads, dh)
    v = (xn @ lp["self_attn"]["wv"]).reshape(B, S, arch.num_kv_heads, dh)
    return k, v


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_cache(arch: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    p = plan(arch)
    dh = arch.resolved_head_dim
    kv = arch.num_kv_heads

    def kv_pair(n, length):
        shape = (n, batch, length, kv, dh)
        return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)

    if p["kind"] in ("dense", "moe"):
        k, v = kv_pair(p["layers"], max_len)
        return {"k": k, "v": v, "length": jnp.zeros((), jnp.int32)}
    if p["kind"] == "xlstm":
        g, m = p["groups"], p["mlstm_per"]
        stack = lambda n, tree: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)
        return {
            "mlstm": stack(g, stack(m, xlstm_mod.mlstm_cache_init(
                arch, batch, dtype))),
            "slstm": stack(g, xlstm_mod.slstm_cache_init(arch, batch, dtype)),
            "length": jnp.zeros((), jnp.int32),
        }
    if p["kind"] == "zamba":
        g, m = p["groups"], p["mamba_per"]
        stack = lambda n, tree: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)
        win = arch.sliding_window
        ring = 0 < win < max_len
        length = win if ring else max_len
        k, v = kv_pair(g, length)
        out = {
            "mamba": stack(g, stack(m, ssm_mod.mamba2_cache_init(
                arch, batch, dtype))),
            "shared_k": k, "shared_v": v,
            "shared_pos": jnp.full((g, batch, length), -1, jnp.int32),
            "length": jnp.zeros((), jnp.int32),
        }
        if p["tail"]:
            out["tail"] = stack(p["tail"], ssm_mod.mamba2_cache_init(
                arch, batch, dtype))
        return out
    if p["kind"] == "whisper":
        sk, sv = kv_pair(p["dec"], max_len)
        ck, cv = kv_pair(p["dec"], arch.num_patches)
        return {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv,
                "length": jnp.zeros((), jnp.int32)}
    raise ValueError(p["kind"])


# ---------------------------------------------------------------------------
# Decode (one token)
# ---------------------------------------------------------------------------

def decode_step(arch: ArchConfig, params: dict, cache: dict,
                token: jnp.ndarray, impl: AttnImpl = AttnImpl.REFERENCE,
                use_scan: bool = True, mesh=None, flash_decode: bool = False,
                compute_dtype=jnp.bfloat16):
    """token (B, 1) int32 -> (logits (B, 1, V), cache')."""
    p = plan(arch)
    length = cache["length"]
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(compute_dtype)
    x = x * jnp.asarray(arch.d_model ** 0.5, compute_dtype)
    cast = lambda t: jax.tree.map(lambda a: a.astype(compute_dtype)
                                  if a.dtype == jnp.float32 and a.ndim > 1
                                  else a, t)
    new_cache = dict(cache)

    if p["kind"] in ("dense", "moe"):
        # the stacked (L, B, S, KV, Dh) caches ride in the CARRY and are
        # updated in place at the layer index: no per-iteration restack of
        # the multi-GB buffer (§Perf hillclimb B iteration 2)
        def body(carry, xs):
            x, k_all, v_all = carry
            lp, i = xs
            lp = cast(lp)
            ck = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
            xn = rmsnorm(x, lp["ln1"])
            if flash_decode and mesh is not None:
                y, ck, cv = attn.decode_self_attention_sharded(
                    lp["attn"], xn, ck, cv, length, arch, mesh)
            else:
                y, ck, cv = attn.decode_self_attention(lp["attn"], xn, ck, cv,
                                                       length, arch)
            x = x + y
            if p["kind"] == "moe":
                y2, _ = moe_mod.moe_apply(lp["moe"], rmsnorm(x, lp["ln2"]),
                                          arch, cap_multiple=8)
            else:
                y2 = mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"]),
                               arch.activation)
            k_all = jax.lax.dynamic_update_index_in_dim(k_all, ck, i, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(v_all, cv, i, 0)
            return (x + y2, k_all, v_all), 0
        (x, k, v), _ = _scan(body, (x, cache["k"], cache["v"]),
                             (params["blocks"],
                              jnp.arange(p["layers"], dtype=jnp.int32)),
                             use_scan)
        new_cache.update(k=k, v=v)
    elif p["kind"] == "xlstm":
        def group(x, xs):
            gp, mcache, scache = xs
            gp = cast(gp)
            def mbody(x, ys):
                lp, c = ys
                y, c2 = xlstm_mod.mlstm_decode(lp["cell"],
                                               rmsnorm(x, lp["ln"]), c, arch)
                return x + y, c2
            x, mcache2 = _scan(mbody, x, (gp["mlstm"], mcache), use_scan)
            y, scache2 = xlstm_mod.slstm_decode(
                gp["slstm"]["cell"], rmsnorm(x, gp["slstm"]["ln"]), scache,
                arch)
            return x + y, (mcache2, scache2)
        x, (mc, sc) = _scan(group, x, (params["blocks"], cache["mlstm"],
                                       cache["slstm"]), use_scan)
        new_cache.update(mlstm=mc, slstm=sc)
    elif p["kind"] == "zamba":
        shared = cast(params["shared"])
        win = cache["shared_k"].shape[2]
        slot = length % win

        def group(x, xs):
            gp, mcache, ck, cv, cpos = xs
            gp = cast(gp)
            def mbody(x, ys):
                lp, c = ys
                y, c2 = ssm_mod.mamba2_decode(lp["mamba"],
                                              rmsnorm(x, lp["ln"]), c, arch)
                return x + y, c2
            x, mcache2 = _scan(mbody, x, (gp, mcache), use_scan)
            xn = rmsnorm(x, shared["ln1"])
            y, ck, cv, cpos = _ring_decode_attn(shared["attn"], xn, ck, cv,
                                                cpos, length, slot, arch)
            x = x + y
            x = x + mlp_apply(shared["mlp"], rmsnorm(x, shared["ln2"]),
                              arch.activation)
            return x, (mcache2, ck, cv, cpos)
        x, (mc, ck, cv, cpos) = _scan(
            group, x, (params["blocks"], cache["mamba"], cache["shared_k"],
                       cache["shared_v"], cache["shared_pos"]), use_scan)
        new_cache.update(mamba=mc, shared_k=ck, shared_v=cv, shared_pos=cpos)
        if p["tail"]:
            def tbody(x, ys):
                lp, c = ys
                lp = cast(lp)
                y, c2 = ssm_mod.mamba2_decode(lp["mamba"],
                                              rmsnorm(x, lp["ln"]), c, arch)
                return x + y, c2
            x, tc = _scan(tbody, x, (params["tail"], cache["tail"]), use_scan)
            new_cache.update(tail=tc)
    elif p["kind"] == "whisper":
        x = x + sinusoidal_positions(
            int(cache["self_k"].shape[2]), arch.d_model
        ).astype(compute_dtype)[length][None, None, :]
        def body(x, xs):
            lp, sk, sv, ck, cv = xs
            lp = cast(lp)
            xn = rmsnorm(x, lp["ln1"])
            y, sk, sv = attn.decode_self_attention(lp["self_attn"], xn, sk,
                                                   sv, length, arch)
            x = x + y
            x = x + attn.cross_attention(lp["cross_attn"],
                                         rmsnorm(x, lp["ln_x"]), ck, cv, arch)
            x = x + mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"]),
                              arch.activation)
            return x, (sk, sv)
        x, (sk, sv) = _scan(body, x, (params["dec_blocks"], cache["self_k"],
                                      cache["self_v"], cache["cross_k"],
                                      cache["cross_v"]), use_scan)
        new_cache.update(self_k=sk, self_v=sv)

    x = rmsnorm(x, params["final_norm"])
    head = (params["embed"].T if arch.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(compute_dtype)
    new_cache["length"] = length + 1
    return logits, new_cache


def _ring_decode_attn(ap, x1, ck, cv, cpos, length, slot, arch):
    """Sliding-window decode with a ring cache.  ck/cv (B, W, KV, Dh);
    cpos (B, W) stores the absolute position held in each slot."""
    B = x1.shape[0]
    dh = arch.resolved_head_dim
    pos = jnp.broadcast_to(length, (B, 1)).astype(jnp.int32)
    q, k, v = attn._project_qkv(ap, x1, x1, arch)
    if arch.rope_theta > 0:
        q = attn.apply_rope(q, pos, arch.rope_theta)
        k = attn.apply_rope(k, pos, arch.rope_theta)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(
        cpos, jnp.broadcast_to(length, (B, 1)).astype(jnp.int32), (0, slot))
    KV = ck.shape[2]
    G = arch.num_heads // KV
    qg = (q * jnp.asarray(dh ** -0.5, q.dtype)).reshape(B, 1, KV, G, dh)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, ck,
                   preferred_element_type=jnp.float32)
    valid = (cpos >= 0) & (cpos <= length)
    s = jnp.where(valid[:, None, None, None, :], s, attn.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, -1).astype(x1.dtype) @ ap["wo"]
    return out, ck, cv, cpos


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(arch: ArchConfig, params: dict, batch: dict,
            impl: AttnImpl = AttnImpl.REFERENCE, remat: str = "none",
            mesh=None, moe_impl: str = "auto",
            compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, dict]:
    """Next-token CE (+ MoE aux).  batch: tokens, labels, [patch/frame]_embeds."""
    logits, aux, _ = forward_seq(arch, params, batch["tokens"],
                                 extra=batch, impl=impl, remat=remat,
                                 mesh=mesh, moe_impl=moe_impl,
                                 compute_dtype=compute_dtype)
    mask = batch.get("loss_mask")
    loss = cross_entropy_loss(logits, batch["labels"], mask)
    return loss + aux, {"ce": loss, "aux": aux}
