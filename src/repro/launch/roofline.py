"""Roofline extraction from compiled HLO.

``compiled.cost_analysis()`` on the CPU backend counts every while body
ONCE, which under-reports any scanned program (layers, KV blocks, SSD
chunks) by the trip count.  This module therefore walks the
post-optimization HLO text itself with a trip-count-aware cost model:

  flops   2·prod(result_dims)·prod(contracting_dims) per dot (matmuls are
          ≥99% of model FLOPs; elementwise ops are bandwidth-, not
          compute-bound and are captured by the bytes term)
  bytes   operands + result per top-level instruction; fusion internals are
          free (they never touch HBM); dynamic-update-slice counts the
          update region, not the aliased buffer
  colls   operand bytes of all-gather / all-reduce / reduce-scatter /
          all-to-all / collective-permute (+ ring-factor-adjusted wire
          bytes as a second column)
  while   body+condition costs × known_trip_count (nested loops multiply)

All numbers are PER DEVICE of the SPMD-partitioned module, so the roofline
terms divide by per-chip peaks directly:

  compute_s    = flops / 197e12      (TPU v5e bf16 peak per chip)
  memory_s     = bytes / 819e9       (HBM bandwidth per chip)
  collective_s = coll_bytes / 50e9   (ICI per link; DCN for the pod axis)
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "after-all", "iota", "partition-id", "replica-id",
            "opt-barrier", "custom-call"}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "s4": 1, "u4": 1, "token": 0}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_info(rhs: str) -> Tuple[int, List[Tuple[str, Tuple[int, ...]]], str]:
    """Parse the result type(s) prefix of an instruction RHS.  Returns
    (total_bytes, [(dtype, dims)], rest_after_types)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        types = rhs[1:i]
        rest = rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        types = rhs[:sp]
        rest = rhs[sp + 1:]
    total = 0
    shapes = []
    for m in _TYPE_RE.finditer(types):
        total += _type_bytes(m.group(1), m.group(2))
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        shapes.append((m.group(1), dims))
    return total, shapes, rest


def _operands(rest: str) -> Tuple[str, List[str], str, str]:
    """(opcode, operand names, attrs, raw inner) from 'opcode(…), attrs…'."""
    p = rest.find("(")
    opcode = rest[:p].strip()
    depth = 0
    for i in range(p, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    inner = rest[p + 1:i]
    attrs = rest[i + 1:]
    names = re.findall(r"%([\w\.\-]+)", inner)
    return opcode, names, attrs, inner


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str
    inner: str = ""


def parse_module(text: str) -> Tuple[Dict[str, List[Instr]], str]:
    comps: Dict[str, List[Instr]] = {}
    entry = None
    current: Optional[str] = None
    for line in text.splitlines():
        if line.endswith("{") and not line.lstrip().startswith("//"):
            m = _HEADER_RE.match(line.strip())
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
                continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _DEF_RE.match(line)
        if not m or "=" not in line or "(" not in line:
            continue
        name, rhs = m.group(1), m.group(2)
        try:
            rbytes, rshapes, rest = _result_info(rhs)
            opcode, ops, attrs, inner = _operands(rest)
        except Exception:
            continue
        comps[current].append(Instr(name, opcode, rbytes, rshapes, ops,
                                    attrs, inner))
    return comps, entry or next(iter(comps))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_wire_bytes: float = 0.0       # ring-factor adjusted
    coll_ops: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: int = 0
    unknown_trip_counts: int = 0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.coll_wire_bytes += other.coll_wire_bytes * mult
        self.coll_count += int(other.coll_count * mult)
        self.unknown_trip_counts += other.unknown_trip_counts
        for k, v in other.coll_ops.items():
            self.coll_ops[k] = self.coll_ops.get(k, 0.0) + v * mult


def _dot_flops(instr: Instr, table: Dict[str, "Instr"]) -> float:
    out_elems = 1
    for _, dims in instr.result_shapes:
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    contract = 1
    if instr.operands:
        lhs = table.get(instr.operands[0])
        if lhs is not None and lhs.result_shapes:
            ldims = lhs.result_shapes[0][1]
            for c in cdims:
                if c < len(ldims):
                    contract *= ldims[c]
    return 2.0 * out_elems * contract


def _ring_factor(instr: Instr) -> float:
    """Wire bytes per device relative to operand size for ring algorithms."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.attrs)
    n = int(m.group(2)) if m else 2
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if instr.opcode.startswith("all-reduce"):
        return 2.0 * frac
    if instr.opcode.startswith("collective-permute"):
        return 1.0
    return frac                       # all-gather / reduce-scatter / all-to-all


class CostWalker:
    def __init__(self, comps: Dict[str, List[Instr]]):
        self.comps = comps
        self.tables = {c: {i.name: i for i in instrs}
                       for c, instrs in comps.items()}
        self.memo: Dict[str, Cost] = {}
        self._charge_memo: Dict[str, Dict[int, float]] = {}
        self._pure_convert: set = set()
        self._normalize_converts()

    def _normalize_converts(self) -> None:
        """CPU-backend bf16 legalisation inserts whole-tensor widening
        converts (bf16 weights/caches -> f32) that do not exist on the TPU
        target, where bf16 is native.  Pure converts are made zero-cost and
        their result size is clamped to the narrower width so downstream
        consumers charge native-width reads.  Semantic converts fused with
        real compute are unaffected."""
        for comp, instrs in self.comps.items():
            table = self.tables[comp]
            # fusion wrappers whose callee is only {parameter, convert,
            # bitcast, copy} are pure converts too (wrapped_convert.*)
            for ins in instrs:
                target = None
                if ins.opcode == "convert":
                    target = ins
                elif ins.opcode == "fusion":
                    m = re.search(r"calls=%([\w\.\-]+)", ins.attrs)
                    callee = self.comps.get(m.group(1)) if m else None
                    if callee is not None and all(
                            c.opcode in ("parameter", "convert", "bitcast",
                                         "copy") for c in callee):
                        target = ins
                if target is None:
                    continue
                src = table.get(target.operands[0]) if target.operands \
                    else None
                if src is not None:
                    target.result_bytes = min(target.result_bytes,
                                              src.result_bytes)
                self._pure_convert.add((comp, target.name))

    def _operand_bytes(self, comp: str, names: List[str]) -> float:
        table = self.tables[comp]
        total = 0.0
        for n in names:
            ins = table.get(n)
            if ins is not None:
                total += ins.result_bytes
        return total

    def _callee_has_dus(self, callee: str) -> Optional[Instr]:
        for ins in self.comps.get(callee, []):
            if ins.opcode == "dynamic-update-slice":
                return ins
        return None

    def _fusion_param_charges(self, callee: str) -> Dict[int, float]:
        """HBM bytes actually touched per fusion parameter.

        A parameter consumed ONLY by fused dynamic-slice ops reads just the
        slices; a parameter that is only the target buffer of fused
        dynamic-update-slices is written in place (charge the update).
        Everything else streams in full.  Returns {param_index: bytes} for
        the special cases; absent indices are charged at full size.
        """
        if callee in self._charge_memo:
            return self._charge_memo[callee]
        charges: Dict[int, float] = {}
        instrs = self.comps.get(callee, [])
        table = self.tables.get(callee, {})
        for p in instrs:
            if p.opcode != "parameter":
                continue
            try:
                idx = int(p.inner.strip())
            except ValueError:
                continue
            # transitive consumers: unary convert/bitcast/copy forward the
            # buffer (CPU bf16-legalisation wraps caches in converts; on the
            # TPU target those are identity)
            def effective_consumers(name, depth=0):
                out = []
                if depth > 4:
                    return [None]
                for c in instrs:
                    if name not in c.operands:
                        continue
                    if c.opcode in ("convert", "bitcast", "copy") \
                            and len(c.operands) == 1:
                        out.extend(effective_consumers(c.name, depth + 1))
                    else:
                        out.append((c, name))
                return out

            consumers = effective_consumers(p.name)
            if not consumers:
                charges[idx] = 0.0
                continue
            # sparse-access accounting: a param consumed only through
            # dynamic-slice reads and/or in-place dynamic-update-slice
            # writes touches just the slices, not the whole buffer
            total, sparse = 0.0, True
            for entry in consumers:
                if entry is None:
                    sparse = False
                    break
                c, via = entry
                if c.opcode == "dynamic-slice":
                    total += c.result_bytes
                elif (c.opcode == "dynamic-update-slice" and c.operands
                      and c.operands[0] == via):
                    if len(c.operands) >= 2 and c.operands[1] in table:
                        total += 2 * table[c.operands[1]].result_bytes
                    else:
                        sparse = False
                        break
                else:
                    sparse = False
                    break
            if sparse:
                charges[idx] = total
        self._charge_memo[callee] = charges
        return charges

    def cost(self, comp: str) -> Cost:
        if comp in self.memo:
            return self.memo[comp]
        total = Cost()
        self.memo[comp] = total        # recursion guard
        table = self.tables[comp]
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            base = op.replace("-start", "")
            if op in SKIP_OPS or op.endswith("-done"):
                continue
            if (comp, ins.name) in self._pure_convert:
                continue          # backend dtype legalisation: free on TPU
            if base.startswith(COLLECTIVES):
                ob = self._operand_bytes(comp, ins.operands)
                total.bytes += ob + ins.result_bytes
                total.coll_bytes += ob
                total.coll_wire_bytes += ob * _ring_factor(ins)
                key = base.split(".")[0]
                total.coll_ops[key] = total.coll_ops.get(key, 0.0) + ob
                total.coll_count += 1
                continue
            if op == "dot":
                total.flops += _dot_flops(ins, table)
                total.bytes += self._operand_bytes(comp, ins.operands) \
                    + ins.result_bytes
                continue
            if op == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", ins.attrs)
                callee = m.group(1) if m else None
                charges = self._fusion_param_charges(callee) if callee else {}
                dus = self._callee_has_dus(callee) if callee else None
                for idx, opname in enumerate(ins.operands):
                    if idx in charges:
                        total.bytes += charges[idx]
                    else:
                        src = table.get(opname)
                        if src is not None:
                            total.bytes += src.result_bytes
                if dus is not None:
                    # result aliases the updated buffer: the write was
                    # charged via the param; nothing extra for the result
                    t = self.tables.get(callee, {})
                    if len(dus.operands) >= 2 and dus.operands[1] in t:
                        total.bytes += t[dus.operands[1]].result_bytes
                else:
                    total.bytes += ins.result_bytes
                if callee:
                    sub = self.cost(callee)
                    total.flops += sub.flops
                    total.coll_bytes += sub.coll_bytes
                    total.coll_wire_bytes += sub.coll_wire_bytes
                    total.coll_count += sub.coll_count
                    for k, v in sub.coll_ops.items():
                        total.coll_ops[k] = total.coll_ops.get(k, 0.0) + v
                continue
            if op == "while":
                mtc = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                                ins.attrs)
                n = int(mtc.group(1)) if mtc else None
                mb = re.search(r"body=%([\w\.\-]+)", ins.attrs)
                mc = re.search(r"condition=%([\w\.\-]+)", ins.attrs)
                if n is None and mc:
                    n = self._trip_from_condition(mc.group(1))
                if n is None:
                    n = 1
                    total.unknown_trip_counts += 1
                if mb:
                    total.add(self.cost(mb.group(1)), n)
                if mc:
                    total.add(self.cost(mc.group(1)), n)
                continue
            if op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      ins.attrs)
                names = re.findall(r"%([\w\.\-]+)",
                                   branches[0]) if branches else []
                names += re.findall(r"(?:true|false)_computation=%([\w\.\-]+)",
                                    ins.attrs)
                if names:
                    worst = max((self.cost(nm) for nm in names),
                                key=lambda c: c.flops + c.bytes)
                    total.add(worst)
                continue
            if op == "call":
                m = re.search(r"to_apply=%([\w\.\-]+)", ins.attrs)
                if m:
                    total.add(self.cost(m.group(1)))
                continue
            if op == "dynamic-update-slice":
                t = self.tables[comp]
                upd = (t[ins.operands[1]].result_bytes
                       if len(ins.operands) >= 2 and ins.operands[1] in t
                       else ins.result_bytes)
                total.bytes += 2 * upd
                continue
            if op in ("dynamic-slice", "gather"):
                total.bytes += 2 * ins.result_bytes
                continue
            if op == "scatter":
                # scatter(buf, idx, upd): in-place, touch ~2x update size
                t = self.tables[comp]
                upd = (t[ins.operands[2]].result_bytes
                       if len(ins.operands) >= 3 and ins.operands[2] in t
                       else ins.result_bytes)
                total.bytes += 2 * upd
                continue
            if op in ("convolution",):
                # treat like a dot over the kernel: rare here
                total.flops += 2 * ins.result_bytes
                total.bytes += self._operand_bytes(comp, ins.operands) \
                    + ins.result_bytes
                continue
            # generic elementwise / data movement
            total.bytes += self._operand_bytes(comp, ins.operands) \
                + ins.result_bytes
        self.memo[comp] = total
        return total

    def _trip_from_condition(self, cond: str) -> Optional[int]:
        consts = []
        for ins in self.comps.get(cond, []):
            if ins.opcode == "constant":
                m = re.search(r"constant\((\d+)\)", ins.attrs or "")
                if m:
                    consts.append(int(m.group(1)))
        # also scan raw: constants may appear as operands text; best effort
        return max(consts) if consts else None


def pod_crossing_bytes(text: str, pod_size: int = 256) -> float:
    """Sum of collective operand bytes whose replica groups cross a pod
    boundary (device id // pod_size differs within a group) — the traffic
    that rides the slow DCN instead of ICI.  Trip counts are NOT applied
    (callers usually want per-occurrence totals scaled by the walker);
    here we approximate by scanning def lines once and multiplying nested
    collectives by enclosing known_trip_counts is skipped — collectives on
    the pod axis sit outside layer loops in every step we emit."""
    import numpy as np

    comps, entry = parse_module(text)
    tables = {c: {i.name: i for i in instrs} for c, instrs in comps.items()}
    total = 0.0
    for cname, instrs in comps.items():
        table = tables[cname]
        for ins in instrs:
            base = ins.opcode.replace("-start", "")
            if not base.startswith(COLLECTIVES):
                continue
            crossing = False
            if base.startswith("collective-permute"):
                pairs = re.findall(r"\{(\d+),(\d+)\}", ins.attrs)
                crossing = any(int(a) // pod_size != int(b) // pod_size
                               for a, b in pairs)
            else:
                m = re.search(
                    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                    ins.attrs)
                if m:
                    g, s = int(m.group(1)), int(m.group(2))
                    dims = [int(x) for x in m.group(3).split(",")]
                    ids = np.arange(int(np.prod(dims)))
                    if m.group(4):
                        perm = [int(x) for x in m.group(4).split(",")]
                        ids = ids.reshape(dims).transpose(perm).reshape(-1)
                    groups = ids.reshape(g, s)
                    crossing = bool(
                        ((groups // pod_size).max(1)
                         != (groups // pod_size).min(1)).any())
                else:
                    m2 = re.search(r"replica_groups=\{\{([^}]*)\}", ins.attrs)
                    if m2:
                        first = [int(x) for x in m2.group(1).split(",") if x]
                        crossing = len({i // pod_size for i in first}) > 1
            if crossing:
                for o in ins.operands:
                    if o in table:
                        total += table[o].result_bytes
    return total


def analyze_hlo_text(text: str, pod_size: Optional[int] = None
                     ) -> Dict[str, Any]:
    comps, entry = parse_module(text)
    walker = CostWalker(comps)
    c = walker.cost(entry)
    out = {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "collective_bytes_per_device": c.coll_bytes,
        "collective_wire_bytes_per_device": c.coll_wire_bytes,
        "collective_ops": c.coll_ops,
        "collective_count": c.coll_count,
        "unknown_trip_counts": c.unknown_trip_counts,
    }
    if pod_size:
        out["pod_crossing_bytes_per_device"] = pod_crossing_bytes(text,
                                                                  pod_size)
    return out


def roofline_terms(analysis: Dict[str, Any], model_flops_global: float,
                   chips: int, inter_pod: bool = False,
                   dcn_bw: float = 25e9) -> Dict[str, Any]:
    link_bw = dcn_bw if inter_pod else ICI_BW
    compute_s = analysis["flops_per_device"] / PEAK_FLOPS
    memory_s = analysis["bytes_per_device"] / HBM_BW
    coll_s = analysis["collective_bytes_per_device"] / link_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    model_flops_per_device = model_flops_global / chips
    achievable = model_flops_per_device / max(step_s, 1e-30)
    return {
        **terms,
        "dominant": dominant,
        "bound_step_s": step_s,
        "model_flops_global": model_flops_global,
        "hlo_flops_global": analysis["flops_per_device"] * chips,
        "useful_flops_ratio": model_flops_per_device
        / max(analysis["flops_per_device"], 1e-30),
        "roofline_fraction": achievable / PEAK_FLOPS,
        "achievable_flops_per_chip": achievable,
    }


# ---------------------------------------------------------------------------
# Jitted-callable entry points (the serving-path roofline)
# ---------------------------------------------------------------------------
# This module deliberately avoids importing jax at module scope (the walker
# is pure HLO-text analysis, usable on artifact dumps without a toolchain);
# these helpers import it lazily so the batched scan-fold and the fused
# delivery-merge programs of the serving stack can be costed from their
# REAL jitted entry points (faas.compile_batched_handler's jit_scan,
# store.merge_many_fn) — see benchmarks/roofline_table.py and
# tests/test_roofline_walker.py.

def abstractify(tree):
    """Map a pytree of arrays to ShapeDtypeStructs (lower()-compatible)."""
    import jax
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def compiled_hlo_text(fn, *args, **kwargs) -> str:
    """Post-optimization HLO of a jit-wrapped callable on (possibly
    abstract) arguments — the text the walker costs."""
    return fn.lower(*args, **kwargs).compile().as_text()


def analyze_jit(fn, *args, pod_size: Optional[int] = None,
                **kwargs) -> Dict[str, Any]:
    """Lower + compile ``fn`` on ``args`` and cost its optimized HLO.

    The one-call entry for costing serving programs: trip counts of
    ``lax.scan``-derived while loops are static (the walker multiplies
    the body cost out), so the batched fold at bucket B and the fused
    merge at K snapshots report costs that scale with B and K."""
    return analyze_hlo_text(compiled_hlo_text(fn, *args, **kwargs),
                            pod_size=pod_size)
