"""Wall-clock serving loop: host the batched invocation engine as a server.

Everything below ``launch/`` up to now drives the engine in VIRTUAL time —
explicit ``pump(until_t)`` calls.  ``FaasServer`` closes the loop for real
deployments: client threads ``submit`` requests whose send instants are
taken from a wall clock, a single serving thread maps that wall clock onto
the engine's virtual timeline (``engine.use_clock``), and instead of
polling it sleeps EXACTLY until the next scheduled instant —
``router.next_deadline()``, the earlier of the engine's next window close
and the next windowed-hedge fire time.  A new submission can only move
that horizon earlier, so the condition variable doubles as the wakeup: a
submit notifies the loop, the loop re-queries, and the sleep re-arms.

Timeline mapping: virtual time (ms) = wall time since ``start()`` ×
``time_scale``.  ``time_scale=1`` serves in real time; larger values
compress the emulated network's milliseconds for tests and benchmarks
(a 5 ms window at ``time_scale=100`` closes after 50 µs of wall time).

Concurrency model: ONE lock guards the cluster/engine/router (JAX
dispatches happen while holding it, from whichever thread flushes).  The
serving thread owns ``pump``; client threads own ``submit`` (which may
auto-flush a full window — serialized by the same lock).  Results resolve
``ServedRequest`` futures; a ticket dropped by a failed cycle's
at-most-once contract fails its future instead of hanging it.

    cluster.deploy(...)
    with FaasServer(cluster, window_ms=8.0, hedge_after_ms=4.0,
                    time_scale=50.0) as srv:
        futs = [srv.submit("fn", x, session_id="s") for x in xs]
        outs = [f.result(timeout=5.0) for f in futs]
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent import futures
from typing import Any, Dict, List, Optional

from repro.core.cluster import Cluster, InvokeResult
from repro.core.router import Router


class RequestLost(RuntimeError):
    """The request's ticket can no longer complete (dropped by a failed
    flush cycle or discarded) — at-most-once, the client should re-submit."""


class ServedRequest(futures.Future):
    """Future for one submitted request (resolved by the serving loop):
    a stdlib ``concurrent.futures.Future`` carrying the ticket and the
    request's virtual send instant."""

    def __init__(self, ticket: int, fn: str, t_send: float):
        super().__init__()
        self.ticket = ticket
        self.fn = fn
        self.t_send = t_send            # virtual send instant (ms)


@dataclasses.dataclass
class ServerStats:
    submitted: int = 0
    served: int = 0
    lost: int = 0                   # futures failed (at-most-once drops)
    pumps: int = 0                  # pump passes that delivered results
    wakeups: int = 0                # loop iterations (submits + deadlines)
    cycle_errors: int = 0           # exceptions a flush cycle raised


class FaasServer:
    """Thread-driven wall-clock host for ``BatchedInvocationEngine``."""

    def __init__(self, cluster: Cluster, window_ms: float = 8.0,
                 max_batch: Optional[int] = None,
                 hedge_after_ms: Optional[float] = None,
                 client: str = "client", time_scale: float = 1.0):
        if time_scale <= 0:
            raise ValueError("time_scale must be > 0")
        if window_ms is None or not math.isfinite(window_ms) or window_ms < 0:
            # None is the engine's no-windowing sentinel, and inf/nan give
            # windows that never come due: every future would hang until
            # stop().  The server needs a real close instant to sleep to
            raise ValueError("FaasServer requires a finite window_ms >= 0")
        self.cluster = cluster
        self.router = Router(cluster, client=client,
                             hedge_after_ms=hedge_after_ms)
        self.time_scale = time_scale
        self.stats = ServerStats()
        self.response_ms: List[float] = []      # virtual latency per serve
        self.window_ms = window_ms
        self.max_batch = max_batch
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._futures: Dict[int, ServedRequest] = {}
        self._epoch: Optional[float] = None
        self._running = False
        self._thread: Optional[threading.Thread] = None
        # the cluster's shared engine is only touched between start() and
        # stop(): prior knobs/clock are saved then and restored after
        self._saved_engine_state = None

    # ------------------------------------------------------------------ clock
    def now(self) -> float:
        """Current VIRTUAL time (ms): wall time since start × time_scale."""
        if self._epoch is None:
            return 0.0
        return (time.perf_counter() - self._epoch) * 1e3 * self.time_scale

    def _to_wall_s(self, virtual_ms: float) -> float:
        return virtual_ms / (1e3 * self.time_scale)

    # -------------------------------------------------------------- lifecycle
    def start(self) -> "FaasServer":
        if self._running:
            return self
        eng = self.cluster.engine
        self._saved_engine_state = (eng.window_ms, eng.max_batch, eng.clock)
        eng.configure(window_ms=self.window_ms, max_batch=self.max_batch)
        eng.use_clock(self.now)
        self._epoch = time.perf_counter()
        self._running = True
        self._thread = threading.Thread(target=self._serve_loop,
                                        name="faas-server", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the loop; with ``drain`` every still-queued window is pumped
        out (charged its full wait, as if the deadline passed) so no future
        is left hanging."""
        with self._cond:
            self._running = False
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            with self._cond:
                try:
                    # hedge=False: every wait ends right now, a duplicate
                    # could never complete earlier than its primary
                    self._deliver(self.router.pump(math.inf, hedge=False))
                except Exception:
                    # same contract as the serving loop: redeem what the
                    # failed cycle stashed, fail the dropped tickets
                    self.stats.cycle_errors += 1
                    self._deliver(self.router.reconcile())
                self._fail_lost()
        # hand the CLUSTER's shared engine back exactly as we found it
        # (knobs and clock) — the server's wall clock must not outlive it
        if self._saved_engine_state is not None:
            window_ms, max_batch, clock = self._saved_engine_state
            self.cluster.engine.configure(window_ms=window_ms,
                                          max_batch=max_batch)
            self.cluster.engine.use_clock(clock)
            self._saved_engine_state = None

    def __enter__(self) -> "FaasServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ----------------------------------------------------------------- client
    def submit(self, fn_name: str, x, session_id: Optional[str] = None,
               payload_bytes: int = 64) -> ServedRequest:
        """Enqueue one request with the CURRENT wall instant as its virtual
        send time; wakes the serving loop so its sleep re-arms against the
        (possibly earlier) new deadline.  Thread-safe."""
        with self._cond:
            if not self._running:       # checked under the lock: a submit
                # racing stop() must fail fast, not enqueue into a drained
                # engine and hang its future
                raise RuntimeError(
                    "server not started (use start() or `with`)")
            t_send = self.now()
            try:
                ticket = self.router.submit(fn_name, x, t_send=t_send,
                                            session_id=session_id,
                                            payload_bytes=payload_bytes)
            except Exception:
                # a full window auto-flushes ON THIS THREAD and the cycle
                # can raise, dropping the window (at-most-once).  Settle
                # the damage before re-raising to this caller: redeem what
                # the cycle stashed, fail the dropped tickets' futures
                self.stats.cycle_errors += 1
                self._deliver(self.router.reconcile())
                self._fail_lost()
                self._cond.notify_all()
                raise
            fut = ServedRequest(ticket, fn_name, t_send)
            self._futures[ticket] = fut
            self.stats.submitted += 1
            self._cond.notify_all()
        return fut

    # ------------------------------------------------------------ serving loop
    def _serve_loop(self) -> None:
        with self._cond:
            while self._running:
                self.stats.wakeups += 1
                try:
                    self._deliver(self.router.pump(self.now()))
                except Exception:
                    # a failed flush cycle dropped its group (at-most-once);
                    # surviving windows stay queued.  The router never saw
                    # a result set, so reconcile: redeem what the cycle
                    # stashed and prune the dropped tickets — their futures
                    # fail below instead of hanging
                    self.stats.cycle_errors += 1
                    self._deliver(self.router.reconcile())
                self._fail_lost()
                nxt = self.router.next_deadline()
                if nxt is None:
                    self._cond.wait()           # until a submit or stop
                    continue
                delay = self._to_wall_s(nxt - self.now())
                if delay > 0:
                    # sleep EXACTLY until the next window close/hedge fire;
                    # a submit notifies and the loop re-arms
                    self._cond.wait(timeout=delay)

    def _deliver(self, results: Dict[int, InvokeResult]) -> None:
        if results:
            self.stats.pumps += 1
        for ticket, res in results.items():
            fut = self._futures.pop(ticket, None)
            if fut is None:
                continue
            self.stats.served += 1
            # the router re-stamps hedge winners against the primary's
            # send instant, so response_ms IS the client-observed latency
            self.response_ms.append(res.response_ms)
            fut.set_result(res)

    def _fail_lost(self) -> None:
        """Fail futures whose tickets the router no longer tracks (dropped
        by a failed cycle or discarded) — they can never resolve."""
        if not self._futures:
            return
        for t in [t for t in self._futures if not self.router.tracks(t)]:
            fut = self._futures.pop(t)
            self.stats.lost += 1
            fut.set_exception(RequestLost(
                f"ticket {t} ({fut.fn!r}) dropped before completing"))


def serve_open_loop(server: FaasServer, fn_name: str, make_input,
                    n_requests: int, rate_per_ms: float = 1.0,
                    timeout_s: float = 30.0,
                    session_id: Optional[str] = None) -> List[Any]:
    """Open-loop driver: submissions at a fixed arrival rate
    (``rate_per_ms`` per VIRTUAL millisecond, i.e. wall rate ×
    ``server.time_scale``), regardless of completions — the paper's open
    workload.  Returns all InvokeResults in submission order."""
    spacing_s = 1.0 / (rate_per_ms * 1e3 * server.time_scale)
    futs = []
    for i in range(n_requests):
        futs.append(server.submit(fn_name, make_input(i),
                                  session_id=session_id))
        time.sleep(spacing_s)
    return [f.result(timeout=timeout_s) for f in futs]


def serve_closed_loop(server: FaasServer, fn_name: str, make_input,
                      n_requests: int, concurrency: int = 4,
                      timeout_s: float = 30.0,
                      session_prefix: Optional[str] = None) -> List[Any]:
    """Closed-loop driver: ``concurrency`` client threads, each submitting
    its next request as soon as the previous one completes (the paper's
    §4.2 closed workload).  Returns all InvokeResults."""
    results: List[Any] = []
    errors: List[BaseException] = []
    lock = threading.Lock()
    counter = iter(range(n_requests))

    def client(cid: int):
        sid = f"{session_prefix}{cid}" if session_prefix else None
        while True:
            with lock:
                i = next(counter, None)
            if i is None:
                return
            try:
                fut = server.submit(fn_name, make_input(i), session_id=sid)
                res = fut.result(timeout=timeout_s)
            except BaseException as e:    # surfaced after join, not stderr
                with lock:
                    errors.append(e)
                return
            with lock:
                results.append(res)

    threads = [threading.Thread(target=client, args=(c,), daemon=True)
               for c in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return results
