"""Wall-clock serving loop: host the batched invocation engine as a server.

Everything below ``launch/`` up to now drives the engine in VIRTUAL time —
explicit ``pump(until_t)`` calls.  ``FaasServer`` closes the loop for real
deployments: client threads (or asyncio tasks) ``submit`` requests whose
send instants are taken from a wall clock, a single serving thread maps
that wall clock onto the engine's virtual timeline (``engine.use_clock``),
and instead of polling it sleeps EXACTLY until the next scheduled instant —
``router.next_deadline()``, the earlier of the engine's next window close
and the next windowed-hedge fire time.  A new submission can only move
that horizon earlier, so the condition variable doubles as the wakeup: a
submit notifies the loop, the loop re-queries, and the sleep re-arms.

Timeline mapping: virtual time (ms) = wall time since ``start()`` ×
``time_scale``.  ``time_scale=1`` serves in real time; larger values
compress the emulated network's milliseconds for tests and benchmarks
(a 5 ms window at ``time_scale=100`` closes after 50 µs of wall time).

Concurrency model (PR 4: the concurrent dispatch pipeline): the server no
longer serializes every engine touch under one global lock.  The engine
and router carry their own synchronization — a queue lock for submit-side
bookkeeping, a cycle lock serializing dispatches, per-store-node locks in
the cluster — so a client ``submit`` never waits for a pump's JAX dispatch
in flight, and with ``workers`` > 1 the engine executes a cycle's
independent per-store-node groups concurrently.  The server keeps ONLY a
condition variable: it guards the future table and deadline wake-ups.
Because a submitted ticket can complete (via a racing pump) before its
future is registered, the loop parks such results in an orphan buffer and
``submit`` claims them at registration time — no result is ever dropped.

Two client front-ends share one server:

* threads — ``submit`` returns a ``ServedRequest`` (a stdlib future);
* asyncio — ``async_submit`` returns an awaitable resolving on the same
  serving loop, so ONE process hosts many logical clients without a
  thread per client (``serve_open_loop_async``/``serve_closed_loop_async``
  are the matching drivers).

    cluster.deploy(...)
    with FaasServer(cluster, window_ms=8.0, hedge_after_ms=4.0,
                    time_scale=50.0, workers=4) as srv:
        futs = [srv.submit("fn", x, session_id="s") for x in xs]
        outs = [f.result(timeout=5.0) for f in futs]
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
import threading
import time
from concurrent import futures
from typing import Any, Dict, List, Optional

from repro.analysis import lockdep
from repro.core.cluster import Cluster, InvokeResult
from repro.core.engine import AtomicStats
from repro.core.router import Router


class RequestLost(RuntimeError):
    """The request's ticket can no longer complete (dropped by a failed
    flush cycle or discarded) — at-most-once, the client should re-submit."""


class ServedRequest(futures.Future):
    """Future for one submitted request (resolved by the serving loop):
    a stdlib ``concurrent.futures.Future`` carrying the ticket and the
    request's virtual send instant."""

    def __init__(self, ticket: int, fn: str, t_send: float):
        super().__init__()
        self.ticket = ticket
        self.fn = fn
        self.t_send = t_send            # virtual send instant (ms)


@dataclasses.dataclass
class ServerStats(AtomicStats):
    submitted: int = 0
    served: int = 0
    lost: int = 0                   # futures failed (at-most-once drops)
    pumps: int = 0                  # pump passes that delivered results
    wakeups: int = 0                # loop iterations (submits + deadlines)
    cycle_errors: int = 0           # exceptions a flush cycle raised
    nodes_crashed: int = 0          # membership polls that took a node dark


class FaasServer:
    """Wall-clock host for ``BatchedInvocationEngine`` (thread or asyncio
    clients; one serving thread; optional parallel pump via ``workers``)."""

    def __init__(self, cluster: Cluster, window_ms: float = 8.0,
                 max_batch: Optional[int] = None,
                 hedge_after_ms: Optional[float] = None,
                 client: str = "client", time_scale: float = 1.0,
                 workers: Optional[int] = None,
                 membership=None, health_poll_ms: float = 50.0,
                 offload_ewma_ms: Optional[float] = None):
        if time_scale <= 0:
            raise ValueError("time_scale must be > 0")
        if window_ms is None or not math.isfinite(window_ms) or window_ms < 0:
            # None is the engine's no-windowing sentinel, and inf/nan give
            # windows that never come due: every future would hang until
            # stop().  The server needs a real close instant to sleep to
            raise ValueError("FaasServer requires a finite window_ms >= 0")
        self.cluster = cluster
        self.router = Router(cluster, client=client,
                             hedge_after_ms=hedge_after_ms,
                             offload_ewma_ms=offload_ewma_ms)
        # optional ElasticMembership (runtime/elastic.py): the serving loop
        # polls it every turn — a health-reported death crashes the node
        # through the recovery state machine, the next pump's dead-node
        # eviction reroutes or fail-fasts its queued tickets, and the
        # loop's sleeps are CAPPED at health_poll_ms (virtual) so a quiet
        # server still notices a silent node within one poll interval
        self.membership = membership
        self.health_poll_ms = health_poll_ms
        self.time_scale = time_scale
        self.stats = ServerStats()
        self.response_ms: List[float] = []      # virtual latency per serve
        self.window_ms = window_ms
        self.max_batch = max_batch
        self.workers = workers
        # the ONE server-side lock: future table, orphaned results, and the
        # serving loop's deadline wake-ups.  Dispatches never run under it
        self._cond = lockdep.make_condition("server.cond")
        # serializes whole pump TURNS (router.pump/reconcile -> deliver ->
        # fail-lost): a ticket the router just folded is momentarily
        # untracked but undelivered, and a concurrent fail-lost pass in
        # that gap would fail a request that succeeded.  Ordered ABOVE
        # _cond; client submits never take it
        self._pump_lock = lockdep.make_lock("server.pump_lock")
        self._futures: Dict[int, ServedRequest] = {}
        # bumped (under _cond) by every submit: the serving loop re-pumps
        # instead of sleeping when a submit landed DURING its pump turn —
        # such a submit may have auto-flushed a result into the engine's
        # ready set just after the turn's pump drained it, and its
        # notify_all finds no waiter (the classic lost wakeup)
        self._submit_gen = 0
        # results that surfaced before their future was registered (a pump
        # can race submit between ticket issue and registration)
        self._orphans: Dict[int, InvokeResult] = {}
        self._epoch: Optional[float] = None
        self._running = False
        self._thread: Optional[threading.Thread] = None
        # the cluster's shared engine is only touched between start() and
        # stop(): prior knobs/clock/workers are saved then, restored after
        self._saved_engine_state = None

    # ------------------------------------------------------------------ clock
    def now(self) -> float:
        """Current VIRTUAL time (ms): wall time since start × time_scale."""
        if self._epoch is None:
            return 0.0
        return (time.perf_counter() - self._epoch) * 1e3 * self.time_scale

    def _to_wall_s(self, virtual_ms: float) -> float:
        return virtual_ms / (1e3 * self.time_scale)

    # -------------------------------------------------------------- lifecycle
    def start(self) -> "FaasServer":
        if self._running:
            return self
        eng = self.cluster.engine
        self._saved_engine_state = (eng.window_ms, eng.max_batch, eng.clock,
                                    eng.workers, eng.on_ready)
        eng.configure(window_ms=self.window_ms, max_batch=self.max_batch)
        eng.use_clock(self.now)
        eng.use_workers(self.workers)
        # dataflow-scheduler delivery: a window's results surface the
        # moment its last frame finalizes (mid-cycle), so a fast store
        # node's futures resolve while a straggler node's frames are
        # still executing — the serving loop's pump only picks up
        # leftovers (held-back foreign results, barriered cycles)
        eng.on_ready = self._on_engine_ready
        self._epoch = time.perf_counter()
        self._running = True
        self._thread = threading.Thread(target=self._serve_loop,
                                        name="faas-server", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the loop; with ``drain`` every still-queued window is pumped
        out (charged its full wait, as if the deadline passed) so no future
        is left hanging."""
        with self._cond:
            self._running = False
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            with self._pump_lock:
                try:
                    # hedge=False: every wait ends right now, a duplicate
                    # could never complete earlier than its primary
                    results = self.router.pump(math.inf, hedge=False)
                except Exception:
                    # same contract as the serving loop: redeem what the
                    # failed cycle stashed, fail the dropped tickets
                    self.stats.inc("cycle_errors")
                    results = self.router.reconcile()
                with self._cond:
                    self._deliver(results)
                    self._fail_lost()
                    # anything still registered raced the drain: no pump
                    # will run again, so fail it rather than hang the
                    # client
                    for t in list(self._futures):
                        self._fail(self._futures.pop(t),
                                   f"ticket {t} unresolved at shutdown")
        # hand the CLUSTER's shared engine back exactly as we found it
        # (knobs, clock and pump width) — the server's wall clock must not
        # outlive it
        if self._saved_engine_state is not None:
            (window_ms, max_batch, clock, workers,
             on_ready) = self._saved_engine_state
            self.cluster.engine.configure(window_ms=window_ms,
                                          max_batch=max_batch)
            self.cluster.engine.use_clock(clock)
            self.cluster.engine.use_workers(workers)
            self.cluster.engine.on_ready = on_ready
            self._saved_engine_state = None

    def __enter__(self) -> "FaasServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ----------------------------------------------------------------- client
    def submit(self, fn_name: str, x, session_id: Optional[str] = None,
               payload_bytes: int = 64) -> ServedRequest:
        """Enqueue one request with the CURRENT wall instant as its virtual
        send time; wakes the serving loop so its sleep re-arms against the
        (possibly earlier) new deadline.  Thread-safe, and the enqueue
        itself runs OUTSIDE the server lock — a submit never waits for a
        pump's dispatch in flight."""
        with self._cond:
            if not self._running:       # fail fast instead of enqueueing
                # into a drained engine and hanging the future
                raise RuntimeError(
                    "server not started (use start() or `with`)")
        t_send = self.now()
        try:
            ticket = self.router.submit(fn_name, x, t_send=t_send,
                                        session_id=session_id,
                                        payload_bytes=payload_bytes)
        except Exception:
            # a full window auto-flushes ON THIS THREAD and the cycle
            # can raise, dropping the window (at-most-once).  Settle
            # the damage before re-raising to this caller: redeem what
            # the cycle stashed, fail the dropped tickets' futures —
            # one whole pump turn, under the pump lock like the loop's
            self.stats.inc("cycle_errors")
            with self._pump_lock:
                results = self.router.reconcile()
                with self._cond:
                    self._submit_gen += 1
                    self._deliver(results)
                    self._fail_lost()
                    self._cond.notify_all()
            raise
        fut = ServedRequest(ticket, fn_name, t_send)
        self.stats.inc("submitted")
        stopping = False
        with self._cond:
            self._submit_gen += 1
            orphan = self._orphans.pop(ticket, None)
            if orphan is not None:
                # a pump completed the ticket before we registered: claim
                self._resolve(fut, orphan)
            elif not self._running:
                stopping = True     # settled below, outside _cond (the
                                    # pump lock sits above it)
            else:
                # register even if the router momentarily does not track
                # the ticket: a pump turn in its folded-but-undelivered
                # gap resolves it on delivery, and a genuinely dropped
                # ticket is failed by the next turn's _fail_lost
                self._futures[ticket] = fut
            self._cond.notify_all()
        if stopping:
            # raced stop(): the drain may already have run, so no pump
            # will ever redeem this ticket.  Still queued -> discard and
            # fail fast.  NOT queued -> it auto-flushed on this very
            # thread (max_batch) and its committed result sits in the
            # engine's ready set: claim it rather than strand it as a
            # forever-recycling foreign result
            if self.cluster.engine.discard(ticket):
                with self._cond:
                    self._fail(fut, f"ticket {ticket} submitted while "
                                    f"the server was stopping")
            else:
                with self._pump_lock:
                    results = self.router.reconcile()   # redeems ready
                    with self._cond:                    # results only
                        res = results.pop(ticket, None)
                        if res is not None:
                            self._resolve(fut, res)
                        else:
                            self._fail(fut, f"ticket {ticket} dropped "
                                            f"while the server was "
                                            f"stopping")
                        self._deliver(results)
                        self._fail_lost()
        return fut

    # ---------------------------------------------------------------- asyncio
    async def async_submit(self, fn_name: str, x,
                           session_id: Optional[str] = None,
                           payload_bytes: int = 64) -> InvokeResult:
        """``submit`` for asyncio clients: awaits the InvokeResult (or
        raises ``RequestLost``).  The enqueue itself runs on the loop's
        default thread-pool executor — a full window auto-flushes a whole
        JAX dispatch inside ``submit``, which must never stall the event
        loop's other logical clients.  Many clients live as tasks on one
        loop — no thread per client."""
        loop = asyncio.get_running_loop()
        fut = await loop.run_in_executor(
            None, lambda: self.submit(fn_name, x, session_id=session_id,
                                      payload_bytes=payload_bytes))
        return await asyncio.wrap_future(fut)

    # ------------------------------------------------------------ serving loop
    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                if not self._running:
                    return
                self.stats.inc("wakeups")
                gen0 = self._submit_gen
            if self.membership is not None:
                # health plane first: a node that timed out crashes NOW,
                # so this very turn's pump evicts its queued windows
                # (reroute or fail-fast) instead of dispatching into it
                crashed = self.membership.poll()
                if crashed:
                    self.stats.inc("nodes_crashed", len(crashed))
            # one pump TURN under the pump lock (fold -> deliver -> fail
            # lost stays atomic against the submit error path), OUTSIDE
            # the server lock: submits stay non-blocking while the engine
            # dispatches (the engine's own locks do the rest)
            with self._pump_lock:
                try:
                    results = self.router.pump(self.now())
                except Exception:
                    # a failed flush cycle dropped its group
                    # (at-most-once); surviving windows stay queued.  The
                    # router never saw a result set, so reconcile: redeem
                    # what the cycle stashed and prune the dropped
                    # tickets — their futures fail below, not hang
                    self.stats.inc("cycle_errors")
                    results = self.router.reconcile()
                with self._cond:
                    self._deliver(results)
                    self._fail_lost()
            with self._cond:
                if not self._running:
                    return
                if self._submit_gen != gen0:
                    # a submit landed during the pump turn: its result may
                    # already sit in the engine's ready set (inline auto-
                    # flush) and its notify found no waiter — pump again
                    # instead of arming a sleep that nothing would wake
                    continue
                # with a membership attached, never sleep past one health
                # poll interval — a dead node produces no submit to wake us
                cap = (self._to_wall_s(self.health_poll_ms)
                       if self.membership is not None else None)
                nxt = self.router.next_deadline()
                if nxt is None:
                    self._cond.wait(timeout=cap)    # submit, stop, or poll
                    continue
                delay = self._to_wall_s(nxt - self.now())
                if cap is not None:
                    delay = min(delay, cap)
                if delay > 0:
                    # sleep EXACTLY until the next window close/hedge fire;
                    # a submit notifies and the loop re-arms
                    self._cond.wait(timeout=delay)

    def _on_engine_ready(self, results: Dict[int, InvokeResult]) -> None:
        """Mid-cycle delivery hook (``engine.on_ready``): called on the
        thread running the flush cycle, with the engine cycle lock held,
        the moment one window's results finalize.  Folds them through the
        router (midcycle semantics: no pruning, no partner-dead hedge
        settlement — see ``Router.fold_now``) and resolves futures right
        away.  Lock order stays acyclic: cycle lock > router lock >
        server cond; no path below takes an engine lock."""
        mine = self.router.fold_now(results)
        if mine:
            with self._cond:
                self._deliver(mine)

    def _resolve(self, fut: ServedRequest, res: InvokeResult) -> None:
        """Complete one future (under the server lock).  A client may have
        CANCELLED it (asyncio task cancellation propagates through
        wrap_future) — claim it first, or the set would raise
        InvalidStateError and kill the serving thread."""
        if not fut.set_running_or_notify_cancel():
            return                          # client gave up: drop quietly
        self.stats.inc("served")
        # the router re-stamps hedge winners against the primary's
        # send instant, so response_ms IS the client-observed latency
        self.response_ms.append(res.response_ms)
        fut.set_result(res)

    def _fail(self, fut: ServedRequest, why: str) -> None:
        """Fail one future as lost, cancellation-safe like ``_resolve``."""
        if not fut.set_running_or_notify_cancel():
            return
        self.stats.inc("lost")
        fut.set_exception(RequestLost(f"{why} ({fut.fn!r})"))

    def _deliver(self, results: Dict[int, InvokeResult]) -> None:
        if results:
            self.stats.inc("pumps")
        for ticket, res in results.items():
            fut = self._futures.pop(ticket, None)
            if fut is None:
                # completed before submit registered its future: park the
                # result; submit claims it at registration time
                self._orphans[ticket] = res
                continue
            self._resolve(fut, res)

    def _fail_lost(self) -> None:
        """Fail futures whose tickets the router no longer tracks (dropped
        by a failed cycle or discarded) — they can never resolve.  Only
        ever called with the pump lock held, so no ticket can be in the
        folded-but-undelivered gap of a concurrent pump turn."""
        if not self._futures:
            return
        for t in [t for t in self._futures if not self.router.tracks(t)]:
            self._fail(self._futures.pop(t),
                       f"ticket {t} dropped before completing")


# ---------------------------------------------------------------------------
# workload drivers: threads
# ---------------------------------------------------------------------------

def serve_open_loop(server: FaasServer, fn_name: str, make_input,
                    n_requests: int, rate_per_ms: float = 1.0,
                    timeout_s: float = 30.0,
                    session_id: Optional[str] = None) -> List[Any]:
    """Open-loop driver: submissions at a fixed arrival rate
    (``rate_per_ms`` per VIRTUAL millisecond, i.e. wall rate ×
    ``server.time_scale``), regardless of completions — the paper's open
    workload.  Returns all InvokeResults in submission order."""
    spacing_s = 1.0 / (rate_per_ms * 1e3 * server.time_scale)
    futs = []
    for i in range(n_requests):
        futs.append(server.submit(fn_name, make_input(i),
                                  session_id=session_id))
        time.sleep(spacing_s)
    return [f.result(timeout=timeout_s) for f in futs]


def serve_closed_loop(server: FaasServer, fn_name: str, make_input,
                      n_requests: int, concurrency: int = 4,
                      timeout_s: float = 30.0,
                      session_prefix: Optional[str] = None) -> List[Any]:
    """Closed-loop driver: ``concurrency`` client threads, each submitting
    its next request as soon as the previous one completes (the paper's
    §4.2 closed workload).  Returns all InvokeResults."""
    results: List[Any] = []
    errors: List[BaseException] = []
    lock = threading.Lock()
    counter = iter(range(n_requests))

    def client(cid: int):
        sid = f"{session_prefix}{cid}" if session_prefix else None
        while True:
            with lock:
                i = next(counter, None)
            if i is None:
                return
            try:
                fut = server.submit(fn_name, make_input(i), session_id=sid)
                res = fut.result(timeout=timeout_s)
            except BaseException as e:    # surfaced after join, not stderr
                with lock:
                    errors.append(e)
                return
            with lock:
                results.append(res)

    threads = [threading.Thread(target=client, args=(c,), daemon=True)
               for c in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return results


# ---------------------------------------------------------------------------
# workload drivers: asyncio (many logical clients, one thread)
# ---------------------------------------------------------------------------

async def serve_open_loop_async(server: FaasServer, fn_name: str, make_input,
                                n_requests: int, rate_per_ms: float = 1.0,
                                timeout_s: float = 30.0,
                                session_id: Optional[str] = None
                                ) -> List[Any]:
    """Open-loop driver on the CURRENT event loop: fixed virtual arrival
    rate, all requests in flight as awaitables.  Returns InvokeResults in
    submission order."""
    spacing_s = 1.0 / (rate_per_ms * 1e3 * server.time_scale)
    aws = []
    for i in range(n_requests):
        # ensure_future so the submission actually fires NOW (the arrival
        # process), not when gather first awaits it
        aws.append(asyncio.ensure_future(
            server.async_submit(fn_name, make_input(i),
                                session_id=session_id)))
        await asyncio.sleep(spacing_s)
    return await asyncio.wait_for(asyncio.gather(*aws), timeout=timeout_s)


async def serve_closed_loop_async(server: FaasServer, fn_name: str,
                                  make_input, n_requests: int,
                                  concurrency: int = 4,
                                  timeout_s: float = 30.0,
                                  session_prefix: Optional[str] = None
                                  ) -> List[Any]:
    """Closed-loop driver with ``concurrency`` LOGICAL clients as asyncio
    tasks on one thread — each awaits its completion before submitting the
    next request.  The asyncio analogue of ``serve_closed_loop``."""
    results: List[Any] = []
    counter = iter(range(n_requests))

    async def client(cid: int):
        sid = f"{session_prefix}{cid}" if session_prefix else None
        while True:
            i = next(counter, None)     # single-threaded loop: no race
            if i is None:
                return
            results.append(await server.async_submit(
                fn_name, make_input(i), session_id=sid))

    await asyncio.wait_for(
        asyncio.gather(*(client(c) for c in range(concurrency))),
        timeout=timeout_s)
    return results
