"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Single-pod: (16, 16) ("data", "model") = 256
chips.  Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips; the
``pod`` axis is the Enoki replication domain (DCN), the inner axes are ICI.
"""
from __future__ import annotations

import jax

from repro.configs.base import MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_from_config(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axes,
                         axis_types=(jax.sharding.AxisType.Auto,)
                         * len(cfg.axes))


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=prod(shape))."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
