"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Single-pod: (16, 16) ("data", "model") = 256
chips.  Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips; the
``pod`` axis is the Enoki replication domain (DCN), the inner axes are ICI.

``jax.make_mesh`` grew the ``axis_types`` kwarg (and ``jax.sharding.AxisType``)
only in newer jax releases; ``make_mesh_compat`` passes it when available so
the same call sites work across versions.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.configs.base import MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig


def _axis_type_kwargs(num_axes: int) -> dict:
    """``axis_types=(AxisType.Auto, ...)`` where supported, ``{}`` otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str]):
    """Version-tolerant ``jax.make_mesh`` (Auto axis types when supported)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh_from_config(cfg: MeshConfig):
    return make_mesh_compat(cfg.shape, cfg.axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=prod(shape))."""
    return make_mesh_compat(shape, axes)
