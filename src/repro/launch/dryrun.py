import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including repro.*):
# jax locks the device count at first init.  512 placeholder host devices
# back the production meshes: (16,16)=256 single-pod, (2,16,16)=512 two-pod.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds the jitted step (train_step / prefill_step / decode serve_step
     per the shape's kind) with production shardings,
  2. ``.lower(...)`` against ShapeDtypeStruct stand-ins (zero allocation),
  3. ``.compile()`` — sharding mismatches, unsupported collectives, or
     partitioning bugs fail HERE, which is the point of the exercise,
  4. prints ``memory_analysis()`` (bytes/device: does it fit?) and
     ``cost_analysis()``,
  5. walks the compiled HLO for trip-count-aware FLOPs / bytes /
     collective bytes (launch/roofline.py) and writes a JSON artifact to
     --out for EXPERIMENTS.md §Dry-run/§Roofline.

For multi-pod REPLICATED cells the Enoki replication step (anti-entropy
over the pod axis) is lowered AS WELL and recorded separately — the hot
step must show no additional cross-pod traffic vs the single-pod build.

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--policy replicated]
  python -m repro.launch.dryrun --all --both-meshes --out artifacts/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback


def _build_cell(arch, shape, mesh, policy, parallel=None, enoki=None,
                impl=None):
    """Returns (lower_fn, extras dict).  Deferred imports keep XLA_FLAGS
    first."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import (AttnImpl, EnokiConfig, ReplicationPolicy,
                                    StepKind)
    from repro.launch import serve as serve_mod
    from repro.launch import train as train_mod
    from repro.models import model_zoo as zoo
    from repro.parallel.sharding import batch_specs, named

    if impl is None:
        impl = AttnImpl(parallel.attn_impl) if parallel is not None \
            else AttnImpl.REFERENCE
    enoki = enoki or EnokiConfig(policy=ReplicationPolicy(policy))
    extras = {}

    if shape.step is StepKind.TRAIN:
        parallel = parallel or train_mod.default_parallel(arch, shape)
        jitted, sshape, (sspecs, bspecs) = train_mod.make_train_step(
            arch, shape, mesh, parallel, enoki, impl=impl)
        multi_pod = "pod" in mesh.shape
        n_pods = mesh.shape.get("pod", 1)
        b = shape.global_batch
        bshape = zoo.input_specs(arch, shape)
        if multi_pod and enoki.policy == ReplicationPolicy.REPLICATED:
            bshape = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (n_pods, s.shape[0] // n_pods) + s.shape[1:], s.dtype),
                bshape)
        extras["parallel"] = dataclasses.asdict(parallel)

        def lower():
            return jitted.lower(sshape, bshape)

        rep = None
        if multi_pod and enoki.policy == ReplicationPolicy.REPLICATED:
            rstep, outer_shape, _ = train_mod.make_replicate_step(
                arch, mesh, parallel, enoki, sshape)

            def rep():
                return rstep.lower(sshape, outer_shape)

        return lower, rep, extras

    if shape.step is StepKind.PREFILL:
        jitted, pshape, (pspecs, bspecs, cspecs) = serve_mod.make_prefill_step(
            arch, shape, mesh, parallel=parallel, impl=impl)
        bshape = zoo.input_specs(arch, shape)

        def lower():
            return jitted.lower(pshape, bshape)

        return lower, None, extras

    # decode shapes
    jitted, shapes, specs = serve_mod.make_decode_step(
        arch, shape, mesh, parallel=parallel, enoki=enoki, impl=impl)

    def lower():
        return jitted.lower(shapes["params"], shapes["cache"],
                            shapes["token"])

    rep = None
    if "pod" in mesh.shape:
        rstep, rshape, _ = serve_mod.make_replicate_sessions_step(
            arch, shape, mesh, enoki)

        def rep():
            return rstep.lower(rshape)

    return lower, rep, extras


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, policy: str,
             out_dir: str = None, verbose: bool = True,
             overrides: dict = None, tag: str = ""):
    import dataclasses as dc

    import jax

    from repro.configs import get_arch, get_shape, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze_hlo_text, roofline_terms
    from repro.launch.train import default_parallel
    from repro.models.model_zoo import model_flops

    arch = get_arch(arch_id)
    shape = get_shape(shape_id)
    ok, reason = shape_applicable(arch, shape)
    record = {"arch": arch_id, "shape": shape_id,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "policy": policy, "skipped": not ok, "tag": tag}
    if not ok:
        record["skip_reason"] = reason
        if verbose:
            print(f"[skip] {arch_id} × {shape_id}: {reason}")
        return _write(record, out_dir)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    parallel = None
    if overrides:
        parallel = dc.replace(default_parallel(arch, shape), **overrides)
        record["overrides"] = overrides
    t0 = time.time()
    try:
        lower_fn, rep_fn, extras = _build_cell(arch, shape, mesh, policy,
                                               parallel=parallel)
        lowered = lower_fn()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        walk = analyze_hlo_text(txt)
        mf = model_flops(arch, shape)
        terms = roofline_terms(walk, mf, chips)
        record.update(
            ok=True, chips=chips, lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_total": mem.argument_size_in_bytes
                + mem.output_size_in_bytes + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes,
            },
            cost_analysis={"flops_body_once": ca.get("flops"),
                           "bytes_body_once": ca.get("bytes accessed")},
            hlo=walk, roofline=terms, **extras)
        if rep_fn is not None:
            rl = rep_fn().compile()
            rwalk = analyze_hlo_text(rl.as_text())
            record["replication_step"] = rwalk
        if verbose:
            dom = terms["dominant"]
            print(f"[ok]   {arch_id:18s} × {shape_id:12s} mesh="
                  f"{record['mesh']:8s} compile={t_compile:6.1f}s "
                  f"mem/dev={record['memory']['per_device_total']/2**30:8.2f}GiB "
                  f"flops/dev={walk['flops_per_device']:.3e} "
                  f"coll/dev={walk['collective_bytes_per_device']:.3e}B "
                  f"dominant={dom}")
            print(f"       memory_analysis: {mem}")
            print(f"       cost_analysis(body-once): flops="
                  f"{ca.get('flops')}, bytes={ca.get('bytes accessed')}")
    except Exception as e:
        record.update(ok=False, error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {arch_id} × {shape_id} ({record['mesh']}): "
                  f"{record['error']}")
    return _write(record, out_dir)


def _write(record, out_dir):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = record.get("tag") or ""
        name = (f"{record['arch']}_{record['shape']}_{record['mesh']}"
                f"_{record.get('policy','-')}{('_' + tag) if tag else ''}"
                f".json").replace("/", "-")
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(record, f, indent=1, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--policy", default="replicated",
                    choices=["replicated", "peer_fetch", "cloud_central"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--set", action="append", default=[],
                    help="ParallelConfig overrides, e.g. --set moe_impl=ep "
                         "--set remat=block --set fsdp=false")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = ({"true": True, "false": False}.get(v.lower(), v))

    from repro.configs import ARCH_IDS, SHAPES

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    n_fail = 0
    for mp in meshes:
        for a, s in cells:
            rec = run_cell(a, s, mp, args.policy, args.out,
                           overrides=overrides or None, tag=args.tag)
            if not rec.get("ok", False) and not rec.get("skipped"):
                n_fail += 1
    print(f"\ndry-run complete: {len(cells)*len(meshes)} cells, "
          f"{n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
