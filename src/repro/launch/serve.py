"""Serving: sessions are Enoki keygroups.

A decode session's KV cache (or recurrent state) is a keygroup whose home is
the pod serving it — the decode hot path touches only pod-local state, the
paper's core property.  Three jitted programs:

  prefill_step              builds a session from a prompt (logits + cache)
  decode_step               one token for every local session; NO pod-axis
                            collectives (structurally verified in dry-run)
  replicate_sessions_step   anti-entropy: ring-copy session state to the
                            next pod (ppermute over 'pod') into a backup
                            buffer — pod failure loses ≤R tokens of session
                            state (R = replication_period), the serving
                            analogue of the paper's measured staleness
  migrate_sessions_step     §2's deploy-time keygroup replication: adopt the
                            backup copy as live state (after failover the
                            surviving pod serves the lost pod's sessions)

Multi-pod shapes are pod-stacked (leading n_pods dim, sharded P("pod",...)),
like training keygroups in launch/train.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ArchConfig, AttnImpl, EnokiConfig,
                                ParallelConfig, ShapeConfig)
from repro.models import model_zoo as zoo
from repro.parallel.sharding import (batch_specs, cache_partition_specs,
                                     named, param_partition_specs)
from repro.launch.train import stack_specs, stack_shapes


def serve_param_dtype(arch: ArchConfig):
    return jnp.bfloat16        # serving always runs bf16 weights


def params_shape_tree(arch: ArchConfig):
    return jax.eval_shape(
        lambda: zoo.init_params(arch, jax.random.PRNGKey(0),
                                dtype=serve_param_dtype(arch)))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def make_prefill_step(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                      parallel: Optional[ParallelConfig] = None,
                      impl: AttnImpl = AttnImpl.REFERENCE):
    parallel = parallel or ParallelConfig(remat="none", fsdp=False)
    pshape = params_shape_tree(arch)
    pspecs = param_partition_specs(pshape, arch, mesh, parallel)
    bspecs = batch_specs(arch, shape, mesh, parallel)

    def prefill(params, batch):
        logits, _, cache = zoo.forward_seq(
            arch, params, batch["tokens"], extra=batch, impl=impl,
            return_cache=True, use_scan=parallel.use_scan,
            mesh=mesh if parallel.moe_impl == "ep" else None,
            moe_impl=parallel.moe_impl)
        cache = dict(cache)
        cache["length"] = jnp.asarray(shape.seq_len, jnp.int32)
        return logits[:, -1:, :], cache

    cache_shape = jax.eval_shape(
        lambda: zoo.init_cache(arch, shape.global_batch, shape.seq_len))
    cspecs = cache_partition_specs(cache_shape, arch, mesh,
                                   shape.global_batch)
    # prefill emits per-layer stacked caches with layout (L,B,S,KV,Dh) too
    jitted = jax.jit(prefill,
                     in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
                     out_shardings=(None, named(mesh, cspecs)))
    return jitted, pshape, (pspecs, bspecs, cspecs)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def make_decode_step(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                     parallel: Optional[ParallelConfig] = None,
                     enoki: Optional[EnokiConfig] = None,
                     impl: AttnImpl = AttnImpl.REFERENCE,
                     donate: bool = True):
    """Returns (jitted, shapes dict, specs dict).  Multi-pod: pod-stacked."""
    parallel = parallel or ParallelConfig(remat="none", fsdp=False)
    enoki = enoki or EnokiConfig()
    multi_pod = "pod" in mesh.shape
    n_pods = mesh.shape.get("pod", 1)
    batch_local = shape.global_batch // n_pods if multi_pod \
        else shape.global_batch
    if multi_pod and shape.global_batch % n_pods:
        batch_local = max(1, batch_local)

    pshape = params_shape_tree(arch)
    pspecs = param_partition_specs(pshape, arch, mesh, parallel)
    cache_shape = jax.eval_shape(
        lambda: zoo.init_cache(arch, batch_local, shape.seq_len))
    cspecs = cache_partition_specs(cache_shape, arch, mesh, batch_local,
                                   prefer_seq=parallel.flash_decode)
    tshape = jax.ShapeDtypeStruct((batch_local, 1), jnp.int32)
    tspec = P("data" if batch_local % mesh.shape["data"] == 0
              and batch_local >= mesh.shape["data"] else None, None)

    def step(params, cache, token):
        logits, new_cache = zoo.decode_step(
            arch, params, cache, token, impl=impl,
            use_scan=parallel.use_scan, mesh=mesh if parallel.flash_decode
            and "pod" not in mesh.shape else None,
            flash_decode=parallel.flash_decode)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_token.astype(jnp.int32), new_cache

    if not multi_pod:
        jitted = jax.jit(step,
                         in_shardings=(named(mesh, pspecs),
                                       named(mesh, cspecs),
                                       NamedSharding(mesh, tspec)),
                         out_shardings=(None, named(mesh, cspecs)),
                         donate_argnums=(1,) if donate else ())
        return jitted, {"params": pshape, "cache": cache_shape,
                        "token": tshape}, \
            {"params": pspecs, "cache": cspecs, "token": tspec}

    # pod-stacked serving (Enoki REPLICATED): vmap over the pod dim
    spspecs = stack_specs(pspecs)
    scspecs = stack_specs(cspecs)
    stspec = P("pod", *tspec)
    jitted = jax.jit(jax.vmap(step),
                     in_shardings=(named(mesh, spspecs),
                                   named(mesh, scspecs),
                                   NamedSharding(mesh, stspec)),
                     out_shardings=(None, named(mesh, scspecs)),
                     donate_argnums=(1,) if donate else ())
    shapes = {"params": stack_shapes(pshape, n_pods),
              "cache": stack_shapes(cache_shape, n_pods),
              "token": jax.ShapeDtypeStruct((n_pods,) + tuple(tshape.shape),
                                            jnp.int32)}
    return jitted, shapes, {"params": spspecs, "cache": scspecs,
                            "token": stspec}


# ---------------------------------------------------------------------------
# Session anti-entropy / migration (multi-pod only)
# ---------------------------------------------------------------------------

def make_replicate_sessions_step(arch: ArchConfig, shape: ShapeConfig,
                                 mesh: Mesh, enoki: Optional[EnokiConfig]
                                 = None):
    """backup <- ring-shifted copy of live session state (pod i backs up
    pod i-1).  jnp.roll over the pod-sharded dim lowers to
    collective-permute over the DCN — Enoki's replication traffic, off the
    decode hot path, amortised over replication_period tokens."""
    n_pods = mesh.shape.get("pod", 1)
    batch_local = max(1, shape.global_batch // max(n_pods, 1))
    cache_shape = jax.eval_shape(
        lambda: zoo.init_cache(arch, batch_local, shape.seq_len))
    cspecs = stack_specs(cache_partition_specs(cache_shape, arch, mesh,
                                               batch_local))

    def replicate(live):
        return jax.tree.map(lambda c: jnp.roll(c, 1, axis=0), live)

    jitted = jax.jit(replicate, in_shardings=(named(mesh, cspecs),),
                     out_shardings=named(mesh, cspecs))
    return jitted, stack_shapes(cache_shape, n_pods), cspecs


def make_migrate_sessions_step(arch: ArchConfig, shape: ShapeConfig,
                               mesh: Mesh):
    """Failover: adopt the backup copy for pods flagged dead.
    live' = where(dead[pod], backup, live) — keygroup restore from the
    surviving replica (paper §2 / DESIGN.md §7)."""
    n_pods = mesh.shape.get("pod", 1)
    batch_local = max(1, shape.global_batch // max(n_pods, 1))
    cache_shape = jax.eval_shape(
        lambda: zoo.init_cache(arch, batch_local, shape.seq_len))
    cspecs = stack_specs(cache_partition_specs(cache_shape, arch, mesh,
                                               batch_local))

    def migrate(live, backup, dead_mask):
        def sel(l, b):
            m = dead_mask.reshape((n_pods,) + (1,) * (l.ndim - 1))
            return jnp.where(m, b, l)
        return jax.tree.map(sel, live, backup)

    jitted = jax.jit(
        migrate,
        in_shardings=(named(mesh, cspecs), named(mesh, cspecs),
                      NamedSharding(mesh, P("pod"))),
        out_shardings=named(mesh, cspecs))
    return jitted, stack_shapes(cache_shape, n_pods), cspecs
