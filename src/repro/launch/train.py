"""Training step factory — the paper's three data placements as distribution
schemes (DESIGN.md §2):

REPLICATED (Enoki / DiLoCo)
    Parameters+optimizer are *pod-stacked* keygroups: every leaf carries a
    leading ``n_pods`` dim sharded P("pod", ...).  ``train_step`` is a vmap
    over that dim — each pod trains on pod-local data against its local
    replica, so the hot path contains ZERO pod-axis collectives (verified
    structurally by the dry-run).  ``replicate_step`` is a separate jitted
    program: delta exchange over the pod axis (optionally int8-compressed)
    + DiLoCo outer Nesterov.  Staleness bound = replication_period steps.

CLOUD_CENTRAL (the paper's baseline)
    One shared parameter set, batch sharded over ("pod","data") — fully
    synchronous cross-pod DP.  Gradient all-reduce crosses the DCN every
    step: pod collectives ON the hot path.

PEER_FETCH (SyncMesh analogue)
    Parameters sharded over the pod axis (owner pods hold shards); every
    step all-gathers them across the DCN on demand.  Hot-path pod
    collectives again, read-heavy this time.

Single-pod meshes have no ``pod`` axis: all policies coincide with plain
DP×TP and ``replicate_step`` is the identity.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ArchConfig, AttnImpl, EnokiConfig,
                                ParallelConfig, ReplicationPolicy,
                                ShapeConfig, StepKind, TrainConfig)
from repro.models import model_zoo as zoo
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, diloco_init, diloco_outer_update,
                         warmup_cosine)
from repro.optim.compression import int8_compress
from repro.parallel.sharding import (batch_specs, named, opt_state_specs,
                                     param_partition_specs)


# ---------------------------------------------------------------------------
# Per-cell defaults
# ---------------------------------------------------------------------------

def default_parallel(arch: ArchConfig, shape: ShapeConfig) -> ParallelConfig:
    n = arch.param_count()
    big = n > 20e9
    return ParallelConfig(
        fsdp=big and shape.step is StepKind.TRAIN,
        zero1=True,
        seq_shard=False,
        remat=("full" if big else "block") if shape.step is StepKind.TRAIN
        else "none",
        use_scan=True,
        optimizer="adafactor" if n > 200e9 else "adamw",
    )


def param_dtype_for(arch: ArchConfig) -> Any:
    # ≥200B params: bf16 weights + adafactor, or HBM can never fit (§Dry-run)
    return jnp.bfloat16 if arch.param_count() > 200e9 else jnp.float32


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

def init_state(arch: ArchConfig, key, parallel: ParallelConfig,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or param_dtype_for(arch)
    params = zoo.init_params(arch, key, dtype=dtype)
    if parallel.optimizer == "adafactor":
        opt = adafactor_init(params)
    else:
        # fp32 params are their own master copy
        opt = adamw_init(params, keep_master=(dtype == jnp.bfloat16))
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def state_shapes(arch: ArchConfig, parallel: ParallelConfig,
                 dtype=None) -> Dict[str, Any]:
    """ShapeDtypeStructs of the train state (dry-run: no allocation)."""
    return jax.eval_shape(
        lambda: init_state(arch, jax.random.PRNGKey(0), parallel, dtype))


def state_specs(state_shape: Dict[str, Any], arch: ArchConfig, mesh: Mesh,
                parallel: ParallelConfig,
                peer_fetch_pod: bool = False) -> Dict[str, Any]:
    pspecs = param_partition_specs(state_shape["params"], arch, mesh, parallel)
    ospecs = jax.tree.map(
        lambda leaf: None, state_shape["opt"])
    # moments/master mirror param leaves by name; reuse the same rule fn
    ospecs = opt_specs_tree(state_shape["opt"], arch, mesh, parallel)
    specs = {"params": pspecs, "opt": ospecs, "step": P()}
    if peer_fetch_pod:
        specs = jax.tree.map(_add_pod_axis_spec, specs,
                             _shapes_of(state_shape),
                             is_leaf=lambda x: isinstance(x, P))
    return specs


def _shapes_of(tree):
    return jax.tree.map(lambda l: tuple(l.shape), tree)


def _add_pod_axis_spec(spec: P, shape: tuple) -> P:
    """PEER_FETCH: additionally shard the largest free divisible dim over
    'pod' (FSDP across the DCN)."""
    assign = list(spec) + [None] * (len(shape) - len(spec))
    free = [d for d in range(len(shape)) if assign[d] is None]
    for d in sorted(free, key=lambda d: -shape[d]):
        if shape[d] % 2 == 0 and shape[d] >= 2:
            assign[d] = "pod"
            break
    return P(*assign)


def opt_specs_tree(opt_shape: Any, arch: ArchConfig, mesh: Mesh,
                   parallel: ParallelConfig) -> Any:
    """Optimizer-state specs: params-shaped subtrees (m/v/master or
    adafactor full) get the ZeRO/param rule; factored row/col vectors and
    counters replicate."""
    from repro.parallel.sharding import _spec_for  # leaf-name based

    import dataclasses as dc
    zp = dc.replace(parallel, fsdp=parallel.fsdp or parallel.zero1)

    def spec(path, leaf):
        names = [getattr(e, "key", None) for e in path]
        if "count" in names or names[-1] in ("row", "col"):
            return P()          # tiny
        return _spec_for(path, leaf, arch, mesh, zp)

    return jax.tree_util.tree_map_with_path(spec, opt_shape)


# ---------------------------------------------------------------------------
# The core single-replica train step
# ---------------------------------------------------------------------------

def make_loss_fn(arch: ArchConfig, parallel: ParallelConfig,
                 impl: AttnImpl = AttnImpl.REFERENCE, mesh=None):
    def loss_fn(params, batch):
        return zoo.lm_loss(arch, params, batch, impl=impl,
                           remat=parallel.remat, mesh=mesh,
                           moe_impl=parallel.moe_impl)
    return loss_fn


def make_step_fn(arch: ArchConfig, parallel: ParallelConfig,
                 cfg: TrainConfig, impl: AttnImpl = AttnImpl.REFERENCE,
                 mesh=None) -> Callable:
    loss_fn = make_loss_fn(arch, parallel, impl, mesh)

    def step(state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(state["params"])
        lr = warmup_cosine(state["step"], cfg.lr, cfg.warmup_steps,
                           cfg.total_steps)
        if parallel.optimizer == "adafactor":
            new_params, new_opt, om = adafactor_update(
                grads, state["opt"], state["params"], lr,
                weight_decay=cfg.weight_decay, grad_clip=cfg.grad_clip)
        else:
            new_params, new_opt, om = adamw_update(
                grads, state["opt"], state["params"], lr,
                weight_decay=cfg.weight_decay, grad_clip=cfg.grad_clip)
        metrics = {"loss": loss, "ce": parts["ce"], "lr": lr, **om}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return step


# ---------------------------------------------------------------------------
# Policy-aware jitted builders
# ---------------------------------------------------------------------------

def stack_specs(specs: Any) -> Any:
    """Prepend the pod axis to every spec (pod-stacked keygroups)."""
    return jax.tree.map(lambda s: P("pod", *s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def stack_shapes(shapes: Any, n_pods: int) -> Any:
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_pods,) + tuple(l.shape), l.dtype),
        shapes)


def make_train_step(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    parallel: Optional[ParallelConfig] = None,
                    enoki: Optional[EnokiConfig] = None,
                    cfg: Optional[TrainConfig] = None,
                    impl: AttnImpl = AttnImpl.REFERENCE,
                    donate: bool = True):
    """Returns (jitted_step, state_shape_tree, in_shardings dict).

    Multi-pod behaviour depends on enoki.policy (module docstring).
    """
    parallel = parallel or default_parallel(arch, shape)
    enoki = enoki or EnokiConfig()
    cfg = cfg or TrainConfig()
    multi_pod = "pod" in mesh.shape
    n_pods = mesh.shape.get("pod", 1)

    sshape = state_shapes(arch, parallel)
    step_mesh = mesh if parallel.moe_impl == "ep" and not multi_pod else None
    step = make_step_fn(arch, parallel, cfg, impl, mesh=step_mesh)
    bspecs = batch_specs(arch, shape, mesh, parallel)

    if not multi_pod or enoki.policy == ReplicationPolicy.CLOUD_CENTRAL:
        sspecs = state_specs(sshape, arch, mesh, parallel)
        if multi_pod:  # sync-DP across pods: batch over ("pod","data")
            bspecs = jax.tree.map(
                lambda s: P(("pod", "data") if s and s[0] == "data"
                            else (s[0] if s else None), *s[1:]), bspecs,
                is_leaf=lambda x: isinstance(x, P))
        jitted = jax.jit(step,
                         in_shardings=(named(mesh, sspecs),
                                       named(mesh, bspecs)),
                         out_shardings=(named(mesh, sspecs), None),
                         donate_argnums=(0,) if donate else ())
        return jitted, sshape, (sspecs, bspecs)

    if enoki.policy == ReplicationPolicy.PEER_FETCH:
        sspecs = state_specs(sshape, arch, mesh, parallel,
                             peer_fetch_pod=True)
        bspecs = jax.tree.map(
            lambda s: P(("pod", "data") if s and s[0] == "data"
                        else (s[0] if s else None), *s[1:]), bspecs,
            is_leaf=lambda x: isinstance(x, P))
        jitted = jax.jit(step,
                         in_shardings=(named(mesh, sspecs),
                                       named(mesh, bspecs)),
                         out_shardings=(named(mesh, sspecs), None),
                         donate_argnums=(0,) if donate else ())
        return jitted, sshape, (sspecs, bspecs)

    # REPLICATED: pod-stacked state, vmapped step, no pod collectives
    sspecs = state_specs(sshape, arch, mesh, parallel)
    stacked_specs = stack_specs(sspecs)
    stacked_shape = stack_shapes(sshape, n_pods)
    stacked_bspecs = jax.tree.map(lambda s: P("pod", *s), bspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    vstep = jax.vmap(step)
    jitted = jax.jit(vstep,
                     in_shardings=(named(mesh, stacked_specs),
                                   named(mesh, stacked_bspecs)),
                     out_shardings=(named(mesh, stacked_specs), None),
                     donate_argnums=(0,) if donate else ())
    return jitted, stacked_shape, (stacked_specs, stacked_bspecs)


# ---------------------------------------------------------------------------
# The anti-entropy step (REPLICATED policy, off the hot path)
# ---------------------------------------------------------------------------

def make_replicate_step(arch: ArchConfig, mesh: Mesh,
                        parallel: ParallelConfig, enoki: EnokiConfig,
                        state_shape_stacked: Any):
    """jit((stacked_state, outer_state) -> (stacked_state, outer_state)).

    Pure-jnp anti-entropy: per-pod deltas vs the outer params, optional int8
    wire compression (the cross-pod all-gather then moves 1/4 the bytes —
    visible in the dry-run HLO), mean-merge, DiLoCo outer Nesterov, broadcast
    back into every pod slot.  This program owns ALL pod-axis collectives.
    """
    n_pods = mesh.shape.get("pod", 1)
    sspecs = state_specs(
        jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                     state_shape_stacked),
        arch, mesh, parallel)
    stacked_specs = stack_specs(sspecs)
    outer_specs = {"outer_params": sspecs["params"],
                   "momentum": sspecs["params"], "round": P()}

    def replicate(state, outer_state):
        local = state["params"]                        # (n_pods, ...)
        outer = outer_state["outer_params"]

        if enoki.compress_deltas:
            # int8 ON THE WIRE: quantise per pod, all-gather the int8
            # payload over the pod axis (4× less DCN traffic), dequantise
            # and average locally.  shard_map pins the gather to int8.
            def delta_leaf(o, l):
                def body(o_l, l_l):
                    d = o_l - l_l[0].astype(jnp.float32)
                    q = int8_compress(d)
                    qs = jax.lax.all_gather(q.q, "pod")        # int8 wire
                    ss = jax.lax.all_gather(q.scale, "pod")    # (n_pods,)
                    deq = qs.astype(jnp.float32) * ss.reshape(
                        (n_pods,) + (1,) * d.ndim)
                    return deq.mean(axis=0)
                from repro.parallel.sharding import shard_map_compat
                return shard_map_compat(
                    body, mesh=mesh,
                    in_specs=(P(), P("pod")), out_specs=P(),
                    check_vma=False, axis_names={"pod"})(o, l)
        else:
            def delta_leaf(o, l):
                d = o[None] - l.astype(jnp.float32)    # (n_pods, ...)
                return d.mean(axis=0)                  # pod all-reduce HERE

        mean_delta = jax.tree.map(delta_leaf, outer, local)
        new_outer, new_outer_state = diloco_outer_update(
            outer_state, mean_delta, enoki.outer_lr, enoki.outer_momentum)
        new_params = jax.tree.map(
            lambda no, l: jnp.broadcast_to(no.astype(l.dtype)[None],
                                           l.shape),
            new_outer, local)
        new_state = dict(state)
        new_state["params"] = new_params
        return new_state, new_outer_state

    jitted = jax.jit(replicate,
                     in_shardings=(named(mesh, stacked_specs),
                                   named(mesh, outer_specs)),
                     out_shardings=(named(mesh, stacked_specs),
                                    named(mesh, outer_specs)))
    outer_shape = jax.eval_shape(
        lambda: diloco_init(jax.tree.map(
            lambda l: jnp.zeros(l.shape[1:], l.dtype),
            state_shape_stacked["params"])))
    return jitted, outer_shape, (stacked_specs, outer_specs)
