"""Edge-cloud network model (the tc-netem role in the paper's testbed).

The paper emulates its network with ``tc-netem``: 50 ms RTT and 100 Mb/s
between edge and cloud, 20 ms RTT and 100 Mb/s between edge nodes (§4.1,
§4.3).  We model the same quantities explicitly; the figure-reproduction
benchmarks combine this model with *measured* local compute/store times to
recover the paper's end-to-end latency results on hardware we don't have.

At TPU scale the analogous quantities come from the roofline constants
(ICI/DCN bandwidth) instead — see launch/roofline.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class Link:
    rtt_ms: float
    bandwidth_mbps: float      # megaBITS per second, like the paper's 100Mb/s

    @property
    def one_way_ms(self) -> float:
        return self.rtt_ms / 2.0

    def transfer_ms(self, nbytes: int) -> float:
        if self.bandwidth_mbps <= 0:
            return 0.0
        return (nbytes * 8.0) / (self.bandwidth_mbps * 1e6) * 1e3


LOCAL_LINK = Link(rtt_ms=0.0, bandwidth_mbps=0.0)   # same node


@dataclasses.dataclass
class NetworkModel:
    links: Dict[Tuple[str, str], Link]
    default: Link = Link(rtt_ms=50.0, bandwidth_mbps=100.0)

    def link(self, a: str, b: str) -> Link:
        if a == b:
            return LOCAL_LINK
        return self.links.get((a, b)) or self.links.get((b, a)) or self.default

    def rtt_ms(self, a: str, b: str) -> float:
        return self.link(a, b).rtt_ms

    def one_way_ms(self, a: str, b: str) -> float:
        return self.link(a, b).one_way_ms

    def request_ms(self, a: str, b: str, payload_bytes: int = 0,
                   response_bytes: int = 0) -> float:
        """One request/response exchange: RTT + serialisation of both payloads."""
        l = self.link(a, b)
        return l.rtt_ms + l.transfer_ms(payload_bytes) + l.transfer_ms(response_bytes)


def paper_topology() -> NetworkModel:
    """The §4 testbed: client, edge (x2 for §4.3), cloud.

    client<->edge is LAN-local (sub-ms; we use 1 ms RTT), edge<->cloud is
    50 ms RTT / 100 Mb/s, edge<->edge is 20 ms RTT / 100 Mb/s.
    """
    e_c = Link(rtt_ms=50.0, bandwidth_mbps=100.0)
    e_e = Link(rtt_ms=20.0, bandwidth_mbps=100.0)
    lan = Link(rtt_ms=1.0, bandwidth_mbps=1000.0)
    return NetworkModel(links={
        ("client", "edge"): lan,
        ("client", "edge1"): lan,
        ("client", "edge2"): Link(rtt_ms=21.0, bandwidth_mbps=100.0),
        ("client", "cloud"): e_c,
        ("edge", "cloud"): e_c,
        ("edge1", "cloud"): e_c,
        ("edge2", "cloud"): e_c,
        ("edge", "edge1"): e_e,
        ("edge", "edge2"): e_e,
        ("edge1", "edge2"): e_e,
    })


def tpu_pod_topology(num_pods: int = 2, dcn_gbps: float = 25.0) -> NetworkModel:
    """Inter-pod DCN as a network model (for the serving router's cost model).

    ~25 GB/s effective DCN per pod pair, ~1 ms RTT; intra-pod ICI handled by
    XLA collectives, not this model.
    """
    links = {}
    for i in range(num_pods):
        for j in range(i + 1, num_pods):
            links[(f"pod{i}", f"pod{j}")] = Link(rtt_ms=1.0,
                                                 bandwidth_mbps=dcn_gbps * 8e3)
    return NetworkModel(links=links, default=Link(rtt_ms=1.0,
                                                  bandwidth_mbps=dcn_gbps * 8e3))
