"""Edge-cloud network model (the tc-netem role in the paper's testbed).

The paper emulates its network with ``tc-netem``: 50 ms RTT and 100 Mb/s
between edge and cloud, 20 ms RTT and 100 Mb/s between edge nodes (§4.1,
§4.3).  We model the same quantities explicitly; the figure-reproduction
benchmarks combine this model with *measured* local compute/store times to
recover the paper's end-to-end latency results on hardware we don't have.

At TPU scale the analogous quantities come from the roofline constants
(ICI/DCN bandwidth) instead — see launch/roofline.py.

``FaultPlane`` layers the UNRELIABLE part of the WAN on top: per-link drop
probability, duplication, delay jitter, and named partitions, all sampled
from a seeded counter-based stream so any fault schedule replays
bit-identically.  The replication transport (core/cluster.py outboxes) and
the heartbeat reachability views (runtime/health.py) consult it; the
latency model above stays separate — a partition does not change a link's
nominal RTT, it makes transmissions on it fail until healed.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, FrozenSet, Optional, Tuple

from repro.analysis import lockdep


@dataclasses.dataclass(frozen=True)
class Link:
    rtt_ms: float
    bandwidth_mbps: float      # megaBITS per second, like the paper's 100Mb/s

    @property
    def one_way_ms(self) -> float:
        return self.rtt_ms / 2.0

    def transfer_ms(self, nbytes: int) -> float:
        if self.bandwidth_mbps <= 0:
            return 0.0
        return (nbytes * 8.0) / (self.bandwidth_mbps * 1e6) * 1e3


LOCAL_LINK = Link(rtt_ms=0.0, bandwidth_mbps=0.0)   # same node


@dataclasses.dataclass
class NetworkModel:
    links: Dict[Tuple[str, str], Link]
    default: Link = Link(rtt_ms=50.0, bandwidth_mbps=100.0)

    def link(self, a: str, b: str) -> Link:
        if a == b:
            return LOCAL_LINK
        return self.links.get((a, b)) or self.links.get((b, a)) or self.default

    def rtt_ms(self, a: str, b: str) -> float:
        return self.link(a, b).rtt_ms

    def one_way_ms(self, a: str, b: str) -> float:
        return self.link(a, b).one_way_ms

    def request_ms(self, a: str, b: str, payload_bytes: int = 0,
                   response_bytes: int = 0) -> float:
        """One request/response exchange: RTT + serialisation of both payloads."""
        l = self.link(a, b)
        return l.rtt_ms + l.transfer_ms(payload_bytes) + l.transfer_ms(response_bytes)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-link lossiness: each transmission independently drops with
    ``drop_p``, duplicates with ``dup_p``, and every delivered copy picks
    up a uniform extra delay in ``[0, jitter_ms]``."""
    drop_p: float = 0.0
    dup_p: float = 0.0
    jitter_ms: float = 0.0


@dataclasses.dataclass(frozen=True)
class Transmission:
    """The sampled fate of ONE send attempt on a faulty link."""
    ok: bool                            # False: dropped (or partitioned)
    copies: int                         # delivered copies (2 = duplicated)
    jitter_ms: Tuple[float, ...]        # per-copy extra delay


_DELIVERED = Transmission(ok=True, copies=1, jitter_ms=(0.0,))
_DROPPED = Transmission(ok=False, copies=0, jitter_ms=())


class FaultPlane:
    """Seeded, deterministic link-fault model over a ``NetworkModel``.

    Every sampling decision is a pure function of ``(seed, link, n)``
    where ``n`` is a per-directed-link send counter — no hidden RNG
    state, so a replay that issues the same sequence of sends per link
    observes the same drop/dup/jitter schedule regardless of thread
    interleaving across OTHER links.  (``zlib.crc32`` keys the stream:
    Python's ``hash`` is salted per process and would not replay.)

    Partitions are NAMED groups: ``partition({"edge1"}, {"cloud",
    "edge2"})`` severs every pair straddling two groups; nodes not
    listed are unaffected.  ``heal(name)`` removes one partition,
    ``heal()`` removes all.  A partitioned pair fails every transmission
    deterministically (no randomness burned) until healed.
    """

    def __init__(self, net: NetworkModel, seed: int = 0):
        self.net = net
        self.seed = int(seed)
        # guards fault specs, partitions and send counters (leaf lock:
        # pure dict/int ops, nothing else is ever acquired under it)
        self._lock = lockdep.make_lock("network.fault_lock")
        self._faults: Dict[FrozenSet[str], FaultSpec] = {}
        self._partitions: Dict[str, Tuple[FrozenSet[str], ...]] = {}
        self._counters: Dict[Tuple[str, str], int] = {}
        self._pnames = 0
        #: optional zero-arg callback fired AFTER a heal() removes at
        #: least one partition (outside the lock).  The Cluster hooks it
        #: to re-arm parked outbox entries so partition-era snapshots
        #: deliver as if freshly scheduled on the healed link.
        self.on_heal = None

    # ------------------------------------------------------------- config
    def set_fault(self, a: str, b: str, drop_p: float = 0.0,
                  dup_p: float = 0.0, jitter_ms: float = 0.0) -> None:
        """Install (or replace) the symmetric fault spec of link a<->b."""
        with self._lock:
            self._faults[frozenset((a, b))] = FaultSpec(
                drop_p=float(drop_p), dup_p=float(dup_p),
                jitter_ms=float(jitter_ms))

    def clear_fault(self, a: str, b: str) -> None:
        with self._lock:
            self._faults.pop(frozenset((a, b)), None)

    def clear_faults(self) -> None:
        with self._lock:
            self._faults.clear()

    def partition(self, *groups, name: Optional[str] = None) -> str:
        """Install a named partition separating the given node groups.
        Returns the name (auto-generated when omitted) for ``heal``."""
        gs = tuple(frozenset(g) for g in groups)
        if len(gs) < 2:
            raise ValueError("a partition needs >= 2 groups")
        with self._lock:
            if name is None:
                name = f"partition-{self._pnames}"
                self._pnames += 1
            self._partitions[name] = gs
            return name

    def heal(self, name: Optional[str] = None) -> None:
        """Remove one named partition, or every partition when ``name``
        is omitted.  Healing an unknown name is a no-op."""
        with self._lock:
            if name is None:
                healed = bool(self._partitions)
                self._partitions.clear()
            else:
                healed = self._partitions.pop(name, None) is not None
        # outside the lock: the hook takes the cluster's outbox lock,
        # which itself nests ABOVE this leaf
        if healed and self.on_heal is not None:
            self.on_heal()

    def partitioned(self, a: str, b: str) -> bool:
        """Whether any active partition separates ``a`` from ``b``."""
        if a == b:
            return False
        with self._lock:
            return self._partitioned_locked(a, b)

    def _partitioned_locked(self, a: str, b: str) -> bool:
        for groups in self._partitions.values():
            ga = gb = None
            for i, g in enumerate(groups):
                if a in g:
                    ga = i
                if b in g:
                    gb = i
            if ga is not None and gb is not None and ga != gb:
                return True
        return False

    def partitions(self) -> Dict[str, Tuple[FrozenSet[str], ...]]:
        with self._lock:
            return dict(self._partitions)

    # ----------------------------------------------------------- sampling
    def _u(self, a: str, b: str, n: int, salt: str) -> float:
        """Deterministic uniform [0,1) keyed by (seed, directed link,
        send counter, decision salt)."""
        key = f"{self.seed}|{a}>{b}|{n}|{salt}".encode()
        return zlib.crc32(key) / 2**32

    def transmit(self, a: str, b: str) -> Transmission:
        """Sample the fate of one a->b send: partitioned links always
        fail; otherwise drop/dup/jitter per the link's ``FaultSpec``.
        Each call burns one counter tick on the directed link."""
        if a == b:
            return _DELIVERED
        with self._lock:
            if self._partitioned_locked(a, b):
                return _DROPPED
            spec = self._faults.get(frozenset((a, b)))
            if spec is None:
                return _DELIVERED
            n = self._counters.get((a, b), 0)
            self._counters[(a, b)] = n + 1
        if spec.drop_p > 0.0 and self._u(a, b, n, "drop") < spec.drop_p:
            return _DROPPED
        copies = 2 if (spec.dup_p > 0.0
                       and self._u(a, b, n, "dup") < spec.dup_p) else 1
        if spec.jitter_ms > 0.0:
            jit = tuple(self._u(a, b, n, f"jit{i}") * spec.jitter_ms
                        for i in range(copies))
        else:
            jit = (0.0,) * copies
        return Transmission(ok=True, copies=copies, jitter_ms=jit)


def paper_topology() -> NetworkModel:
    """The §4 testbed: client, edge (x2 for §4.3), cloud.

    client<->edge is LAN-local (sub-ms; we use 1 ms RTT), edge<->cloud is
    50 ms RTT / 100 Mb/s, edge<->edge is 20 ms RTT / 100 Mb/s.
    """
    e_c = Link(rtt_ms=50.0, bandwidth_mbps=100.0)
    e_e = Link(rtt_ms=20.0, bandwidth_mbps=100.0)
    lan = Link(rtt_ms=1.0, bandwidth_mbps=1000.0)
    return NetworkModel(links={
        ("client", "edge"): lan,
        ("client", "edge1"): lan,
        ("client", "edge2"): Link(rtt_ms=21.0, bandwidth_mbps=100.0),
        ("client", "cloud"): e_c,
        ("edge", "cloud"): e_c,
        ("edge1", "cloud"): e_c,
        ("edge2", "cloud"): e_c,
        ("edge", "edge1"): e_e,
        ("edge", "edge2"): e_e,
        ("edge1", "edge2"): e_e,
    })


def tpu_pod_topology(num_pods: int = 2, dcn_gbps: float = 25.0) -> NetworkModel:
    """Inter-pod DCN as a network model (for the serving router's cost model).

    ~25 GB/s effective DCN per pod pair, ~1 ms RTT; intra-pod ICI handled by
    XLA collectives, not this model.
    """
    links = {}
    for i in range(num_pods):
        for j in range(i + 1, num_pods):
            links[(f"pod{i}", f"pod{j}")] = Link(rtt_ms=1.0,
                                                 bandwidth_mbps=dcn_gbps * 8e3)
    return NetworkModel(links=links, default=Link(rtt_ms=1.0,
                                                  bandwidth_mbps=dcn_gbps * 8e3))
