"""The FaaS layer (tinyFaaS role): function registry, deployment, invocation.

The paper's programming model (Listing 1)::

    import kv
    def call(i: str) -> str:
        curr = kv.get(key="current")
        ...
        kv.set(key="current", val=curr)
        return curr

is preserved as::

    @enoki_function(keygroups=["avg"])
    def call(kv, i):
        curr = kv.get("current")
        ...
        kv.set("current", curr)
        return curr

``kv`` is a handle whose get/set/scan/delete trace to pure ops on a
``Store`` threaded through the handler; deployment jit-compiles the wrapper
``(store, clock, input) -> (store', clock', output)``.  As in the paper,
"global imports stay warm": compilation happens once at deploy time, so warm
invocations pay no setup cost.

Values are encoded by per-keygroup codecs (the arena stores fixed-width
rows).  Key *strings* are hashed at trace time — they are static, exactly
like the paper's literal key names.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.store import (Store, donate_store_argnums, kv_delete, kv_get,
                              kv_scan, kv_set, store_select)
from repro.core.versioning import fnv1a


# ---------------------------------------------------------------------------
# Codecs: python value <-> fixed-width arena row
# ---------------------------------------------------------------------------

class VectorCodec:
    """Float32 vectors up to ``width`` elements (scalars are width-1 views)."""

    def __init__(self, width: int):
        self.width = width

    def encode(self, val) -> Tuple[jnp.ndarray, jnp.ndarray]:
        arr = jnp.atleast_1d(jnp.asarray(val, jnp.float32))
        n = arr.shape[0]
        if n > self.width:
            raise ValueError(f"value of length {n} exceeds arena width {self.width}")
        row = jnp.zeros((self.width,), jnp.float32).at[:n].set(arr)
        return row, jnp.int32(n)

    def decode(self, row: jnp.ndarray, length: jnp.ndarray) -> jnp.ndarray:
        # static-width view; mask the padding so stale bytes never leak
        idx = jnp.arange(self.width)
        return jnp.where(idx < length, row, 0.0)


class BytesCodec:
    """uint8 payloads (for the size-sweep throughput benchmarks)."""

    def __init__(self, width: int):
        self.width = width

    def encode(self, val) -> Tuple[jnp.ndarray, jnp.ndarray]:
        arr = jnp.asarray(val, jnp.uint8)
        n = arr.shape[0]
        row = jnp.zeros((self.width,), jnp.uint8).at[:n].set(arr)
        return row, jnp.int32(n)

    def decode(self, row, length):
        return row  # callers slice by length host-side


# ---------------------------------------------------------------------------
# The kv handle (Listing 1's `import kv`)
# ---------------------------------------------------------------------------

class KV:
    """Functional KV handle: mutating methods rebind the wrapped store.

    Also counts operations and payload bytes — the invocation layer charges
    network costs per op for remote placements (CLOUD_CENTRAL/PEER_FETCH),
    which is how the paper's per-op round-trips (§4.1: 4 ops -> +200 ms)
    are accounted.
    """

    def __init__(self, store: Store, clock: jnp.ndarray, node_id: int,
                 codec: VectorCodec):
        self._store = store
        self._clock = clock
        self._node_id = node_id
        self._codec = codec
        self.ops: List[Tuple[str, int]] = []   # (kind, payload_bytes)
        # every key hash the handler touches — static (keys are literal
        # strings hashed at trace time), so one trace enumerates the full
        # key set; deploy uses it for canonical slot pre-assignment
        self.key_hashes: List[int] = []

    # -- paper API ----------------------------------------------------------
    def get(self, key: str):
        h = fnv1a(key)
        row, length, _, found = kv_get(self._store, h)
        val = self._codec.decode(row, length)
        nbytes = int(np.dtype(np.float32).itemsize) * self._codec.width
        self.ops.append(("get", nbytes))
        self.key_hashes.append(h)
        return val, found

    def set(self, key: str, val) -> None:
        h = fnv1a(key)
        row, length = self._codec.encode(val)
        self._store, self._clock, ok = kv_set(
            self._store, h, row, length, self._clock, self._node_id)
        self.ops.append(("set", int(row.nbytes)))
        self.key_hashes.append(h)

    def scan(self, keys: Sequence[str]):
        hashes = [fnv1a(k) for k in keys]
        vals, lengths, founds = kv_scan(self._store, hashes)
        idx = jnp.arange(vals.shape[1])[None, :]
        vals = jnp.where(idx < lengths[:, None], vals, 0.0)
        self.ops.append(("scan", int(vals.nbytes)))
        self.key_hashes.extend(hashes)
        return vals, founds

    def delete(self, key: str) -> None:
        h = fnv1a(key)
        self._store, self._clock, _ = kv_delete(
            self._store, h, self._clock, self._node_id)
        self.ops.append(("delete", 0))
        self.key_hashes.append(h)

    # -- plumbing -------------------------------------------------------------
    @property
    def state(self) -> Tuple[Store, jnp.ndarray]:
        return self._store, self._clock


# ---------------------------------------------------------------------------
# Function registry + deployment
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FunctionSpec:
    name: str
    handler: Callable            # handler(kv, x) -> y
    keygroups: List[str]
    codec_width: int = 64
    calls: List[str] = dataclasses.field(default_factory=list)  # downstream fns
    async_calls: List[str] = dataclasses.field(default_factory=list)


_REGISTRY: Dict[str, FunctionSpec] = {}


def enoki_function(name: Optional[str] = None, keygroups: Sequence[str] = (),
                   codec_width: int = 64, calls: Sequence[str] = (),
                   async_calls: Sequence[str] = ()):
    """Decorator registering a stateful FaaS function."""

    def wrap(fn: Callable) -> Callable:
        spec = FunctionSpec(name=name or fn.__name__, handler=fn,
                            keygroups=list(keygroups), codec_width=codec_width,
                            calls=list(calls), async_calls=list(async_calls))
        _REGISTRY[spec.name] = spec
        fn.spec = spec
        return fn

    return wrap


def get_function(name: str) -> FunctionSpec:
    return _REGISTRY[name]


def registry() -> Dict[str, FunctionSpec]:
    return dict(_REGISTRY)


def compile_handler(spec: FunctionSpec, node_id: int,
                    example_input: Any) -> Callable:
    """Jit the pure wrapper around the user handler (deploy-time).

    Returns ``step(store, clock, x) -> (store', clock', y, op_log)`` where
    op_log is the static per-invocation (kind, bytes) trace used for network
    accounting (it is identical across invocations by construction: key
    strings and shapes are static, as in the paper's functions).
    """
    codec = VectorCodec(spec.codec_width)
    op_log: List[Tuple[str, int]] = []
    hash_log: List[int] = []

    def pure(store: Store, clock: jnp.ndarray, x):
        kv = KV(store, clock, node_id, codec)
        y = spec.handler(kv, x)
        op_log.clear()
        op_log.extend(kv.ops)
        hash_log.clear()
        hash_log.extend(kv.key_hashes)
        new_store, new_clock = kv.state
        return new_store, new_clock, y

    jitted = jax.jit(pure)
    # trace once to populate the op log and warm the cache (warm start)
    _ = jax.eval_shape(pure, *_example_state(spec, example_input, node_id))

    def step(store, clock, x):
        return jitted(store, clock, x) + (list(op_log),)

    step.op_log = op_log
    step.key_hashes = tuple(dict.fromkeys(hash_log))
    step.read_only = handler_read_only(op_log)
    return step


def handler_read_only(op_log: Sequence[Tuple[str, int]]) -> bool:
    """Whether a deploy-time op trace contains no mutating store ops.

    The router uses this to decide which handlers are safe to re-invoke
    (hedged retries): a mutating handler re-runs its writes and replication
    events on every retry, so only read-only handlers may be hedged.  An
    EMPTY trace (stateless handler) is trivially read-only."""
    return all(k in ("get", "scan") for k, _ in op_log)


def compile_batched_handler(spec: FunctionSpec, node_id: int,
                            example_input: Any) -> Callable:
    """Jit the *batched* pure wrapper (deploy-time) — the §4.2 hot path.

    Returns ``bstep(store, clock, xs, valid, independent=False)`` where
    ``xs`` stacks B request inputs along axis 0 and ``valid`` (B,) bool masks
    bucket padding.  Produces ``(store', clock', ys, op_log)`` with ``ys``
    stacked per-request outputs.

    Execution strategy, chosen from the handler's static op trace:

    * mutating handlers — a ``jax.lax.scan`` over the batch threads
      (store, clock) through the requests in order, masking padded steps
      with ``store_select``, so per-key last-writer-wins semantics and the
      final clock are EXACTLY those of B sequential invocations — but the
      host pays one dispatch instead of B Python round-trips;
    * read-only handlers (only get/scan ops) — a ``jax.vmap`` over requests
      against the shared store: every request sees the same snapshot and
      runs data-parallel on the device;
    * ``independent=True`` (stateless functions, no keygroup) — vmap with
      per-request throwaway state, matching B fresh-arena invocations.

    Both variants are traced lazily per (batch-bucket, store-shape) and
    cached by jit, so warm batches pay zero setup — the batched analogue of
    the paper's "global imports stay warm".
    """
    codec = VectorCodec(spec.codec_width)
    op_log: List[Tuple[str, int]] = []
    hash_log: List[int] = []

    def pure(store: Store, clock: jnp.ndarray, x):
        kv = KV(store, clock, node_id, codec)
        y = spec.handler(kv, x)
        op_log.clear()
        op_log.extend(kv.ops)
        hash_log.clear()
        hash_log.extend(kv.key_hashes)
        new_store, new_clock = kv.state
        return new_store, new_clock, y

    # trace once at deploy time: populates the static op + key-hash logs
    _ = jax.eval_shape(pure, *_example_state(spec, example_input, node_id))
    read_only = handler_read_only(op_log)

    def scanned(store, clock, xs, valid):
        def step(carry, inp):
            s, c = carry
            x, v = inp
            ns, nc, y = pure(s, c, x)
            return (store_select(v, ns, s), jnp.where(v, nc, c)), y

        (fs, fc), ys = jax.lax.scan(step, (store, clock), (xs, valid))
        return fs, fc, ys

    def mapped(store, clock, xs):
        # outputs only: the store result is dropped per-request, so vmap
        # never materialises a batched arena
        return jax.vmap(lambda x: pure(store, clock, x)[2])(xs)

    # donate the arena through the fold on backends where donation is
    # real: XLA reuses the input buffers for the output store, so warm
    # folds stop allocating a fresh arena per dispatch.  The caller's
    # reference (nd.stores[kg]) dies with the dispatch — every snapshot
    # that outlives it must be a clone (see cluster._schedule_replication
    # and docs/batched_engine.md "Device-resident store").  jit_map is
    # NOT donated: it hands the caller's own store refs back.
    jit_scan = jax.jit(scanned, donate_argnums=donate_store_argnums())
    jit_map = jax.jit(mapped)

    def bstep(store, clock, xs, valid, independent: bool = False):
        if independent or read_only:
            # hand back the caller's own store/clock refs: routing them
            # through jit outputs would copy the whole arena per dispatch
            out = (store, clock, jit_map(store, clock, xs))
        else:
            out = jit_scan(store, clock, xs, valid)
        return out + (list(op_log),)

    bstep.op_log = op_log
    bstep.key_hashes = tuple(dict.fromkeys(hash_log))
    bstep.read_only = read_only
    bstep.example = example_input
    bstep.jit_scan = jit_scan
    bstep.jit_map = jit_map
    return bstep


def _example_state(spec: FunctionSpec, example_input, node_id):
    from repro.core.store import store_new
    from repro.core.versioning import MAX_NODES

    store = store_new(64, spec.codec_width, MAX_NODES)
    return store, jnp.zeros((), jnp.int32), example_input
