"""Client-side router: nearest-replica selection, session affinity, hedging.

The paper's clients "directly access their local, lightweight edge FaaS
instances" (§6) — the router codifies that: pick the lowest-latency live
deployment that satisfies the session's consistency requirement, with an
optional hedged second request as straggler mitigation (runtime tier).

Correctness notes (the two bugs PR 2 fixed):

* hedging re-invokes the function, so it is only safe for READ-ONLY
  handlers — re-running a mutating handler applies its writes and
  replication events twice.  The router checks the deploy-time op trace
  (``faas.compile_handler``'s ``read_only`` flag) and suppresses the hedge
  for mutating handlers (counted in ``stats.hedges_suppressed``);
* session tokens must observe the STORE node's version vector and clock,
  not the serving node's: under ``PEER_FETCH``/``CLOUD_CENTRAL`` the write
  lands at the owner/cloud store while ``res.node`` is the edge node the
  client talked to.  Placement is resolved via
  ``cluster._resolve_placement`` so reads-your-writes holds under every
  placement.

The router also fronts the batched invocation engine: ``submit`` enqueues a
request (same nearest-replica/session pick as ``invoke``), and
``pump``/``flush`` drain the engine's arrival-time windows, folding each
completed result back into its session.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import Cluster, InvokeResult
from repro.core.consistency import Session
from repro.core.network import NetworkModel


@dataclasses.dataclass
class RouterStats:
    requests: int = 0
    hedges_fired: int = 0
    hedge_wins: int = 0
    hedges_suppressed: int = 0      # mutating handler: hedge would double-write
    redirects_for_consistency: int = 0


class Router:
    def __init__(self, cluster: Cluster, client: str = "client",
                 hedge_after_ms: Optional[float] = None):
        self.cluster = cluster
        self.client = client
        self.hedge_after_ms = hedge_after_ms
        self.stats = RouterStats()
        self.sessions: Dict[str, Session] = {}
        # engine tickets in flight through this router: ticket -> (fn, session)
        self._inflight: Dict[int, Tuple[str, Optional[str]]] = {}

    # ------------------------------------------------------------------ picks
    def candidates(self, fn_name: str) -> List[str]:
        alive = set(self.cluster.naming.alive_nodes())
        nodes = [n for n in self.cluster.naming.deployments_of(fn_name)
                 if n in alive]
        return sorted(nodes,
                      key=lambda n: self.cluster.net.rtt_ms(self.client, n))

    def pick(self, fn_name: str, session: Optional[Session] = None) -> str:
        cands = self.candidates(fn_name)
        if not cands:
            raise KeyError(f"no live deployment of {fn_name}")
        if session is not None:
            spec = self.cluster.specs[fn_name]
            kg = spec.keygroups[0] if spec.keygroups else None
            if kg is not None:
                for n in cands:
                    vv = np.asarray(self.cluster.store_of(kg, n).vv) \
                        if kg in self.cluster.nodes[n].stores else None
                    if vv is not None and session.can_read_from(vv):
                        if n != cands[0]:
                            self.stats.redirects_for_consistency += 1
                        return n
                # nobody satisfies yet -> nearest replica; caller may retry
                return cands[0]
        return cands[0]

    def _session(self, session_id: Optional[str]) -> Optional[Session]:
        if session_id is None:
            return None
        from repro.core.versioning import MAX_NODES
        return self.sessions.setdefault(session_id,
                                        Session(num_nodes=MAX_NODES))

    # ----------------------------------------------------------------- invoke
    def invoke(self, fn_name: str, x, t_send: float = 0.0,
               session_id: Optional[str] = None,
               payload_bytes: int = 64) -> InvokeResult:
        session = self._session(session_id)
        node = self.pick(fn_name, session)
        self.stats.requests += 1
        res = self.cluster.invoke(fn_name, node, x, t_send=t_send,
                                  client=self.client,
                                  payload_bytes=payload_bytes)

        # hedged request: if the primary exceeded the hedge deadline, fire the
        # second-nearest replica and take the earlier completion (straggler
        # mitigation).  Re-invoking re-RUNS the handler, so only read-only
        # handlers may hedge: a mutating handler would apply its writes (and
        # schedule replication) twice.
        if (self.hedge_after_ms is not None
                and res.response_ms > self.hedge_after_ms):
            cands = self.candidates(fn_name)
            if len(cands) > 1:
                if self.cluster.is_read_only(fn_name):
                    self.stats.hedges_fired += 1
                    alt = self.cluster.invoke(
                        fn_name, cands[1], x,
                        t_send=t_send + self.hedge_after_ms,
                        client=self.client, payload_bytes=payload_bytes)
                    if alt.t_received < res.t_received:
                        self.stats.hedge_wins += 1
                        res = alt
                else:
                    self.stats.hedges_suppressed += 1

        if session is not None:
            self._observe(session, fn_name, res)
        return res

    def _observe(self, session: Session, fn_name: str,
                 res: InvokeResult) -> None:
        """Fold a completed invocation into the session token.

        The version vector and clock are taken from the STORE node the kv
        ops actually hit (placement-resolved), not from ``res.node``: under
        PEER_FETCH/CLOUD_CENTRAL the serving edge node holds no replica and
        the write landed at the owner/cloud store."""
        spec = self.cluster.specs[fn_name]
        kg, store_node, _ = self.cluster._resolve_placement(spec, res.node)
        if kg is None:
            return
        snd = self.cluster.nodes[store_node]
        if kg not in snd.stores:
            return
        session.observe_read(np.asarray(snd.stores[kg].vv))
        wrote = any(k in ("set", "delete") for k, _ in res.kv_ops)
        if wrote:
            # the write's version stamp carries the SERVING node's id (the
            # handler is compiled with it) but the clock that advanced is
            # the STORE node's — the pair the store's vv actually recorded
            session.observe_write(self.cluster.nodes[res.node].node_id,
                                  int(snd.clock))

    # ---------------------------------------------------------------- batched
    def submit(self, fn_name: str, x, t_send: float = 0.0,
               session_id: Optional[str] = None,
               payload_bytes: int = 64) -> int:
        """Enqueue one invocation on the cluster's batched engine, routed
        through the same nearest-replica/session pick as ``invoke``.  The
        returned ticket is redeemed by ``pump``/``flush``, which also fold
        the result back into the session.  Hedging does not apply to the
        batched path (a coalescing server owns the whole batch timeline)."""
        session = self._session(session_id)
        node = self.pick(fn_name, session)
        self.stats.requests += 1
        ticket = self.cluster.engine.submit(fn_name, node, x, t_send=t_send,
                                            client=self.client,
                                            payload_bytes=payload_bytes)
        self._inflight[ticket] = (fn_name, session_id)
        return ticket

    def pump(self, until_t: float = math.inf) -> Dict[int, InvokeResult]:
        """Advance the engine's background flusher to ``until_t`` and fold
        every completed request of this router into its session.  Returns
        only THIS router's tickets — results of tickets submitted by other
        callers of the shared engine are handed back for their owner's next
        pump/flush."""
        return self._fold(self.cluster.engine.pump(until_t))

    def flush(self) -> Dict[int, InvokeResult]:
        """Drain the engine regardless of window deadlines (own tickets
        only, like ``pump``)."""
        return self._fold(self.cluster.engine.flush())

    def _fold(self, results: Dict[int, InvokeResult]) -> Dict[int, InvokeResult]:
        mine: Dict[int, InvokeResult] = {}
        foreign: Dict[int, InvokeResult] = {}
        for ticket, res in results.items():
            if ticket not in self._inflight:
                foreign[ticket] = res     # another submitter's: not ours
                continue
            fn_name, session_id = self._inflight.pop(ticket)
            session = self.sessions.get(session_id) if session_id else None
            if session is not None:
                self._observe(session, fn_name, res)
            mine[ticket] = res
        if foreign:
            self.cluster.engine.hold_results(foreign)
        # prune in-flight tickets that can no longer complete: not in this
        # drain and no longer queued — dropped by a failed cycle's
        # at-most-once contract or discarded via engine.discard
        if self._inflight:
            queued = {p["ticket"] for p in self.cluster.engine.pending()}
            for t in [t for t in self._inflight
                      if t not in results and t not in queued]:
                del self._inflight[t]
        return mine
