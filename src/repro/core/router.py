"""Client-side router: nearest-replica selection, session affinity, hedging.

The paper's clients "directly access their local, lightweight edge FaaS
instances" (§6) — the router codifies that: pick the lowest-latency live
deployment that satisfies the session's consistency requirement, with an
optional hedged second request as straggler mitigation (runtime tier).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import Cluster, InvokeResult
from repro.core.consistency import Session
from repro.core.network import NetworkModel


@dataclasses.dataclass
class RouterStats:
    requests: int = 0
    hedges_fired: int = 0
    hedge_wins: int = 0
    redirects_for_consistency: int = 0


class Router:
    def __init__(self, cluster: Cluster, client: str = "client",
                 hedge_after_ms: Optional[float] = None):
        self.cluster = cluster
        self.client = client
        self.hedge_after_ms = hedge_after_ms
        self.stats = RouterStats()
        self.sessions: Dict[str, Session] = {}

    # ------------------------------------------------------------------ picks
    def candidates(self, fn_name: str) -> List[str]:
        alive = set(self.cluster.naming.alive_nodes())
        nodes = [n for n in self.cluster.naming.deployments_of(fn_name)
                 if n in alive]
        return sorted(nodes,
                      key=lambda n: self.cluster.net.rtt_ms(self.client, n))

    def pick(self, fn_name: str, session: Optional[Session] = None) -> str:
        cands = self.candidates(fn_name)
        if not cands:
            raise KeyError(f"no live deployment of {fn_name}")
        if session is not None:
            spec = self.cluster.specs[fn_name]
            kg = spec.keygroups[0] if spec.keygroups else None
            if kg is not None:
                for n in cands:
                    vv = np.asarray(self.cluster.store_of(kg, n).vv) \
                        if kg in self.cluster.nodes[n].stores else None
                    if vv is not None and session.can_read_from(vv):
                        if n != cands[0]:
                            self.stats.redirects_for_consistency += 1
                        return n
                # nobody satisfies yet -> nearest replica; caller may retry
                return cands[0]
        return cands[0]

    # ----------------------------------------------------------------- invoke
    def invoke(self, fn_name: str, x, t_send: float = 0.0,
               session_id: Optional[str] = None,
               payload_bytes: int = 64) -> InvokeResult:
        session = None
        if session_id is not None:
            from repro.core.versioning import MAX_NODES
            session = self.sessions.setdefault(
                session_id, Session(num_nodes=MAX_NODES))
        node = self.pick(fn_name, session)
        self.stats.requests += 1
        res = self.cluster.invoke(fn_name, node, x, t_send=t_send,
                                  client=self.client,
                                  payload_bytes=payload_bytes)

        # hedged request: if the primary exceeded the hedge deadline, fire the
        # second-nearest replica and take the earlier completion (straggler
        # mitigation; only sensible for read-dominated handlers).
        if (self.hedge_after_ms is not None
                and res.response_ms > self.hedge_after_ms):
            cands = self.candidates(fn_name)
            if len(cands) > 1:
                self.stats.hedges_fired += 1
                alt = self.cluster.invoke(
                    fn_name, cands[1], x,
                    t_send=t_send + self.hedge_after_ms,
                    client=self.client, payload_bytes=payload_bytes)
                if alt.t_received < res.t_received:
                    self.stats.hedge_wins += 1
                    res = alt

        if session is not None:
            spec = self.cluster.specs[fn_name]
            kg = spec.keygroups[0] if spec.keygroups else None
            if kg is not None and kg in self.cluster.nodes[res.node].stores:
                vv = np.asarray(self.cluster.store_of(kg, res.node).vv)
                session.observe_read(vv)
                wrote = any(k in ("set", "delete") for k, _ in res.kv_ops)
                if wrote:
                    nd = self.cluster.nodes[res.node]
                    session.observe_write(nd.node_id, int(nd.clock))
        return res
