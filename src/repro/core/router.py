"""Client-side router: nearest-replica selection, session affinity, hedging.

The paper's clients "directly access their local, lightweight edge FaaS
instances" (§6) — the router codifies that: pick the lowest-latency live
deployment that satisfies the session's consistency requirement, with an
optional hedged second request as straggler mitigation (runtime tier).

EVERY invocation path runs through the batched engine's dataflow
scheduler: ``invoke`` submits a singleton ticket and pumps the engine
until it resolves, so one-off and batched requests share one queue, one
set of windows, one hedging mechanism and one stats ledger (there is no
separate post-hoc hedge anymore — a singleton's "window" closes at
``+inf`` without ``window_ms``, which makes every queued singleton
hedge-eligible the moment its ``hedge_after_ms`` deadline passes).

Correctness notes (the two bugs PR 2 fixed):

* hedging re-invokes the function, so it is only safe for READ-ONLY
  handlers — re-running a mutating handler applies its writes and
  replication events twice.  The router checks the deploy-time op trace
  (``faas.compile_handler``'s ``read_only`` flag) and suppresses the hedge
  for mutating handlers (counted in ``stats.hedges_suppressed``);
* session tokens must observe the STORE node's version vector and clock,
  not the serving node's: under ``PEER_FETCH``/``CLOUD_CENTRAL`` the write
  lands at the owner/cloud store while ``res.node`` is the edge node the
  client talked to.  Placement is resolved via
  ``cluster._resolve_placement`` so reads-your-writes holds under every
  placement.

The router also fronts the batched invocation engine: ``submit`` enqueues a
request (same nearest-replica/session pick as ``invoke``), and
``pump``/``flush`` drain the engine's arrival-time windows, folding each
completed result back into its session.

Straggler mitigation extends to the batched path as a WINDOWED HEDGE
(``hedge_after_ms``): when a read-only request's arrival-time window
outlives its hedge deadline (``t_send + hedge_after_ms``), ``pump`` fires a
duplicate ticket at the nearest OTHER replica at the hedge instant.  The
pair resolves to the earlier completion — reported under the primary
ticket — and the loser is discarded from the queue if it never dispatched
(at-most-once: a hedge only ever duplicates read-only work).  Hedge fire
times are part of ``next_deadline()`` so a serving loop wakes for them.

Hedge TARGET policy: every completion feeds a per-replica EWMA of observed
latency (``stats.ewma_ms``); when a hedge fires, the duplicate goes to the
lowest-EWMA session-satisfying replica — the tail-at-scale heuristic of
preferring the replica that has actually been answering fastest — falling
back to the nearest other replica while no replica has a sample yet.

Thread-safety: the router's own bookkeeping (sessions, in-flight tickets,
hedge pairs) lives behind one router lock, held only for host-side folds —
``engine.pump``'s device dispatches always run OUTSIDE it, so submitting
threads never wait on a dispatch in flight (see docs/batched_engine.md,
"Concurrency contract").
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional

import numpy as np

from repro.analysis import lockdep
from repro.core.cluster import Cluster, InvokeResult
from repro.core.consistency import Session
from repro.core.engine import AtomicStats
from repro.core.network import NetworkModel


@dataclasses.dataclass
class RouterStats(AtomicStats):
    requests: int = 0
    hedges_fired: int = 0
    hedge_wins: int = 0
    hedges_suppressed: int = 0      # mutating handler: hedge would double-write
    redirects_for_consistency: int = 0
    offloads: int = 0               # picks redirected off a saturated node
                                    # by the local-decision offload policy
    # per-replica EWMA of client-observed completion latency (ms) — the
    # hedge-target policy's signal; see observe_latency
    ewma_ms: Dict[str, float] = dataclasses.field(default_factory=dict)

    def observe_latency(self, node: str, ms: float, alpha: float) -> None:
        """Fold one completion into ``node``'s latency EWMA (atomic).
        ``alpha`` is the caller's smoothing factor (the router passes its
        ``EWMA_ALPHA`` — the one source of truth)."""
        with self._lock:
            prev = self.ewma_ms.get(node)
            self.ewma_ms[node] = (ms if prev is None
                                  else alpha * ms + (1.0 - alpha) * prev)


@dataclasses.dataclass
class _InFlight:
    """Everything the router needs to re-route a queued ticket (hedging)
    and to fold its eventual result into the right session."""
    fn: str
    session_id: Optional[str]
    x: object
    t_send: float
    node: str
    payload_bytes: int
    hedge_decided: bool = False     # the fire/suppress choice is made ONCE


@dataclasses.dataclass(eq=False)
class _Hedge:
    """A hedged pair: the primary ticket and its duplicate.  Registered in
    ``Router._hedges`` under BOTH tickets; resolves to the earlier
    completion, reported under the primary."""
    primary: int
    hedge: int
    primary_res: Optional[InvokeResult] = None
    hedge_res: Optional[InvokeResult] = None


class Router:
    #: smoothing factor of the per-replica latency EWMA (hedge targeting)
    EWMA_ALPHA = 0.2

    def __init__(self, cluster: Cluster, client: str = "client",
                 hedge_after_ms: Optional[float] = None,
                 offload_ewma_ms: Optional[float] = None):
        self.cluster = cluster
        self.client = client
        self.hedge_after_ms = hedge_after_ms
        # local-decision offload threshold (Cicconetti et al.,
        # arXiv:2203.06385): a pick whose target's latency EWMA exceeds
        # this redirects to the fastest-answering other replica — edge
        # overflow drains to cloud replicas with no central coordinator,
        # because the signal is the client's own completion observations
        self.offload_ewma_ms = offload_ewma_ms
        self.stats = RouterStats()
        self.sessions: Dict[str, Session] = {}
        # engine tickets in flight through this router (primary tickets only)
        self._inflight: Dict[int, _InFlight] = {}
        # hedged pairs, keyed by BOTH member tickets (same _Hedge object)
        self._hedges: Dict[int, _Hedge] = {}
        # deploy-time traces are static, so read-only-ness per fn is too:
        # cache it off the hedging hot path (is_read_only walks call graphs)
        self._ro_cache: Dict[str, bool] = {}
        # results a synchronous ``invoke`` drained for OTHER tickets of
        # this router while pumping for its own: parked here (instead of
        # handing them back to the engine as foreign, which would recycle
        # them forever) and merged into the next fold's return
        self._claimed: Dict[int, InvokeResult] = {}
        # guards sessions/_inflight/_hedges; held for host-side folds only,
        # never across an engine dispatch — pump/hedge submits release it
        # first, so router.lock nests only engine.qlock (and, mid-cycle,
        # is itself taken under the cycle lock on the on_ready delivery
        # path).  Declared in repro/analysis/lock_order.py
        self._lock = lockdep.make_rlock("router.lock")

    # ------------------------------------------------------------------ picks
    def candidates(self, fn_name: str) -> List[str]:
        # routable = alive and not SUSPECT: a node parked suspect by the
        # membership (minority-view partition) keeps its replicas but
        # stops being picked until its reachability clears
        alive = set(self.cluster.naming.routable_nodes())
        nodes = [n for n in self.cluster.naming.deployments_of(fn_name)
                 if n in alive]
        return sorted(nodes,
                      key=lambda n: self.cluster.net.rtt_ms(self.client, n))

    def pick(self, fn_name: str, session: Optional[Session] = None) -> str:
        cands = self.candidates(fn_name)
        if not cands:
            raise KeyError(f"no live deployment of {fn_name}")
        spec = self.cluster.specs[fn_name]
        chosen = cands[0]
        if session is not None and spec.keygroups:
            for n in cands:
                if self._satisfies(spec, n, session):
                    if n != cands[0]:
                        self.stats.inc("redirects_for_consistency")
                    chosen = n
                    break
            # nobody satisfies yet -> nearest replica; caller may retry
        return self._maybe_offload(chosen, cands, spec, session)

    def _maybe_offload(self, chosen: str, cands: List[str], spec,
                       session: Optional[Session]) -> str:
        """Local-decision offload: if the chosen node's completion-latency
        EWMA says it is saturated (above ``offload_ewma_ms``), redirect to
        the fastest-answering OTHER candidate that still satisfies the
        session — unsampled replicas count as fast (give them a first
        request rather than pile onto a known-slow node).  The decision is
        purely client-local, made from this router's own observations."""
        if self.offload_ewma_ms is None:
            return chosen
        ewma = self.stats.ewma_ms
        cur = ewma.get(chosen)
        if cur is None or cur <= self.offload_ewma_ms:
            return chosen
        best, best_ms = None, cur
        for n in cands:
            if n == chosen:
                continue
            if (session is not None and spec.keygroups
                    and not self._satisfies(spec, n, session)):
                continue
            ms = ewma.get(n, 0.0)
            if ms < best_ms:
                best, best_ms = n, ms
        if best is None:
            return chosen           # everyone else is as slow or stale
        self.stats.inc("offloads")
        return best

    def _satisfies(self, spec, node: str, session: Session) -> bool:
        """Whether serving ``spec`` at ``node`` can satisfy the session.
        The version vector that decides lives at the STORE the candidate's
        kv ops would actually hit (placement-resolved, as in ``_observe``):
        under PEER_FETCH/CLOUD_CENTRAL that is the owner/cloud node, not
        the serving candidate — checking the candidate's own (empty)
        stores made every session read fall through, or bogusly redirect
        to the owner replica."""
        kg, store_node, _ = self.cluster._resolve_placement(spec, node)
        snd = self.cluster.nodes[store_node]
        if kg not in snd.stores:
            return False
        return session.can_read_from(np.asarray(snd.stores[kg].vv))

    def _session(self, session_id: Optional[str]) -> Optional[Session]:
        if session_id is None:
            return None
        from repro.core.versioning import MAX_NODES
        return self.sessions.setdefault(session_id,
                                        Session(num_nodes=MAX_NODES))

    # ----------------------------------------------------------------- invoke
    def invoke(self, fn_name: str, x, t_send: float = 0.0,
               session_id: Optional[str] = None,
               payload_bytes: int = 64) -> InvokeResult:
        """One-off invocation through the SAME engine path as
        ``submit``/``pump``: submits a singleton ticket and pumps the
        engine (by ``next_deadline``, so every due hedge fires at its
        instant) until the ticket resolves.  This retires the separate
        sequential code path: the singleton rides the dataflow scheduler,
        shares the dead-node eviction and stats ledger, folds into its
        session through ``_fold``, and — with ``hedge_after_ms`` set —
        gets the WINDOWED hedge (``_maybe_hedge``/``_hedge_target``, the
        lowest-EWMA session-satisfying replica) instead of a bespoke
        post-hoc duplicate.  Results other tickets of this router
        surfaced during the drain are parked in ``_claimed`` for their
        owner's next ``pump``/``flush``."""
        ticket = self.submit(fn_name, x, t_send=t_send,
                             session_id=session_id,
                             payload_bytes=payload_bytes)
        while True:
            with self._lock:
                res = self._claimed.pop(ticket, None)
            if res is not None:
                return res
            nxt = self.next_deadline()
            out = self.pump(math.inf if nxt is None else nxt)
            res = out.pop(ticket, None)
            if out:
                with self._lock:
                    self._claimed.update(out)
            if res is not None:
                return res
            if not self.tracks(ticket):
                # dropped by a failed flush cycle / dead-node fail-fast:
                # at-most-once, surface the loss instead of spinning
                raise KeyError(f"ticket {ticket} ({fn_name!r}) was "
                               f"dropped before completing")

    def _observe(self, session: Session, fn_name: str,
                 res: InvokeResult) -> None:
        """Fold a completed invocation into the session token.

        The version vector and clock are taken from the STORE node the kv
        ops actually hit (placement-resolved), not from ``res.node``: under
        PEER_FETCH/CLOUD_CENTRAL the serving edge node holds no replica and
        the write landed at the owner/cloud store."""
        spec = self.cluster.specs[fn_name]
        kg, store_node, _ = self.cluster._resolve_placement(spec, res.node)
        if kg is None:
            return
        snd = self.cluster.nodes[store_node]
        if kg not in snd.stores:
            return
        session.observe_read(np.asarray(snd.stores[kg].vv))
        wrote = any(k in ("set", "delete") for k, _ in res.kv_ops)
        if wrote:
            # the write's version stamp carries the SERVING node's id (the
            # handler is compiled with it) but the clock that advanced is
            # the STORE node's — the pair the store's vv actually recorded
            session.observe_write(self.cluster.nodes[res.node].node_id,
                                  int(snd.clock))

    # ---------------------------------------------------------------- batched
    def submit(self, fn_name: str, x, t_send: float = 0.0,
               session_id: Optional[str] = None,
               payload_bytes: int = 64) -> int:
        """Enqueue one invocation on the cluster's batched engine, routed
        through the same nearest-replica/session pick as ``invoke``.  The
        returned ticket is redeemed by ``pump``/``flush``, which also fold
        the result back into the session.  With ``hedge_after_ms`` set,
        read-only requests whose window outlives the hedge deadline are
        hedged at the next ``pump`` (windowed hedge, see module docstring).
        Thread-safe: many client threads may submit concurrently while the
        serving thread pumps — the engine enqueue (which can auto-flush a
        full window, a whole dispatch cycle) runs OUTSIDE the router lock.
        A result that surfaces before the ticket registers is handed back
        to the engine as foreign and redeemed by the next pump."""
        with self._lock:
            session = self._session(session_id)
            node = self.pick(fn_name, session)
            self.stats.inc("requests")
        ticket = self.cluster.engine.submit(fn_name, node, x,
                                            t_send=t_send,
                                            client=self.client,
                                            payload_bytes=payload_bytes)
        with self._lock:
            self._inflight[ticket] = _InFlight(fn_name, session_id, x, t_send,
                                               node, payload_bytes)
        return ticket

    def pump(self, until_t: Optional[float] = None,
             hedge: bool = True) -> Dict[int, InvokeResult]:
        """Advance the engine's background flusher to ``until_t`` (the
        engine clock's current time when omitted and a clock is plugged)
        and fold every completed request of this router into its session.
        Fires due windowed hedges first, so a hedge submitted at its fire
        instant can still join this pump's flush cycle; pass
        ``hedge=False`` when draining at shutdown — every wait is about to
        end anyway, so firing duplicates would only waste dispatches.
        Returns only THIS router's tickets — results of tickets submitted
        by other callers of the shared engine are handed back for their
        owner's next pump/flush."""
        eng = self.cluster.engine
        if until_t is None:
            until_t = eng.now()     # the one clock-resolution convention
        if hedge:
            self._maybe_hedge(until_t)
        results = eng.pump(until_t)     # dispatch OUTSIDE the router lock
        with self._lock:
            return self._fold(results)

    def flush(self) -> Dict[int, InvokeResult]:
        """Drain the engine regardless of window deadlines (own tickets
        only, like ``pump``).  No hedges fire: flushing ends every wait
        immediately, so no window outlives its hedge deadline."""
        results = self.cluster.engine.flush()
        with self._lock:
            return self._fold(results)

    def fold_now(self, results: Dict[int, InvokeResult]
                 ) -> Dict[int, InvokeResult]:
        """Fold results delivered MID-CYCLE by the engine's dataflow
        scheduler (``engine.on_ready``: a window's results surface the
        moment its last frame finalizes, while the flush cycle is still
        running).  Same session/hedge/EWMA bookkeeping as a pump's fold,
        with two midcycle restrictions (see ``_fold``): no in-flight
        pruning, and no partner-dead hedge settlement — both judgements
        need the cycle-end view of the queue."""
        with self._lock:
            return self._fold(results, midcycle=True)

    def tracks(self, ticket: int) -> bool:
        """Whether ``ticket`` can still produce a result through this
        router (in flight, or a member of an unresolved hedged pair).  A
        serving loop fails the request's future once this turns False."""
        with self._lock:
            return ticket in self._inflight or ticket in self._hedges

    def reconcile(self) -> Dict[int, InvokeResult]:
        """Settle state after a flush cycle RAISED: the failing group's
        tickets are gone from the engine but ``_fold`` never ran.  Pumping
        to ``-inf`` dispatches nothing — it only redeems results the
        failed cycle already stashed (groups that completed cleanly) — and
        the fold prunes tickets that can no longer complete, so a serving
        loop can fail their futures instead of hanging them."""
        results = self.cluster.engine.pump(-math.inf)
        with self._lock:
            return self._fold(results)

    def next_deadline(self) -> Optional[float]:
        """Earliest virtual instant at which this router has scheduled
        work: the engine's next window close, or an in-flight read-only
        ticket's hedge fire time, whichever comes first.  ``None`` when
        nothing is queued — the wall-clock serving loop sleeps exactly
        until this instant."""
        due = []
        if (d := self.cluster.engine.next_deadline()) is not None:
            due.append(d)
        with self._lock:
            due.extend(hd for _, _, hd in self._hedgeable())
        return min(due) if due else None

    def _read_only(self, fn_name: str) -> bool:
        ro = self._ro_cache.get(fn_name)
        if ro is None:
            ro = self._ro_cache[fn_name] = self.cluster.is_read_only(fn_name)
        return ro

    def _hedgeable(self) -> List:
        """(ticket, meta, hedge instant) for every READ-ONLY in-flight
        ticket still queued in a window that outlives its hedge deadline,
        with the fire decision still open — the ONE eligibility rule
        shared by ``next_deadline`` (when to wake) and ``_maybe_hedge``
        (what to fire).  A mutating ticket is decided (suppressed) the
        first time it qualifies, so the serving loop never schedules a
        wakeup at a hedge instant that cannot fire."""
        if self.hedge_after_ms is None or not self._inflight:
            return []
        queued = {p["ticket"]: p["deadline"]
                  for p in self.cluster.engine.pending()}
        out = []
        for t, m in self._inflight.items():
            if m.hedge_decided:
                continue
            dl = queued.get(t)
            hd = m.t_send + self.hedge_after_ms
            if dl is None or dl <= hd:
                continue            # dispatched, or window beats the hedge
            if not self._read_only(m.fn):
                m.hedge_decided = True      # can never hedge: decide now
                self.stats.inc("hedges_suppressed")
                continue
            out.append((t, m, hd))
        return out

    def _maybe_hedge(self, until_t: float) -> None:
        """Fire the windowed hedge for every queued read-only ticket whose
        window outlives its hedge deadline (``t_send + hedge_after_ms``),
        once the pump horizon has reached that instant.  The duplicate is
        submitted to the hedge-target replica (lowest EWMA) that can still
        satisfy the request's session, with the hedge instant as its send
        time — deterministic in virtual time, independent of pump cadence.
        Each fire DECIDES under the router lock immediately before its
        own engine submit, which runs outside the lock (it can auto-flush
        a whole dispatch on a full window, like ``submit``) — so a submit
        that raises mid-pass leaves the REMAINING tickets undecided and
        they retry at the next pump instead of silently losing their
        hedge."""
        with self._lock:
            due = [(t, m) for t, m, hd in self._hedgeable()
                   if until_t >= hd]
        for ticket, m in due:
            with self._lock:
                if m.hedge_decided:
                    continue        # raced another pump: decided there
                m.hedge_decided = True  # one fire decision per ticket
                alt = self._hedge_target(m)
                if alt is None:
                    continue        # no second replica can serve this one
                self.stats.inc("hedges_fired")
                hd = m.t_send + self.hedge_after_ms
            ht = self.cluster.engine.submit(m.fn, alt, m.x, t_send=hd,
                                            client=self.client,
                                            payload_bytes=m.payload_bytes)
            with self._lock:
                pair = _Hedge(primary=ticket, hedge=ht)
                self._hedges[ticket] = self._hedges[ht] = pair

    def _hedge_target(self, m: _InFlight) -> Optional[str]:
        """Where the duplicate goes: among the replicas other than the
        primary's that can serve the request (honouring the session's
        consistency requirement exactly like ``pick``, so a hedge never
        wins with a stale read), prefer the one with the LOWEST latency
        EWMA — the replica that has actually been answering fastest.
        While no eligible replica has a sample yet, fall back to the
        nearest one (the candidates come RTT-sorted)."""
        session = (self.sessions.get(m.session_id)
                   if m.session_id is not None else None)
        spec = self.cluster.specs[m.fn]
        eligible = []
        for n in self.candidates(m.fn):
            if n == m.node:
                continue
            if (session is None or not spec.keygroups
                    or self._satisfies(spec, n, session)):
                eligible.append(n)
        if not eligible:
            return None
        ewma = self.stats.ewma_ms
        sampled = [n for n in eligible if n in ewma]
        if sampled:
            return min(sampled, key=lambda n: ewma[n])
        return eligible[0]

    def _fold(self, results: Dict[int, InvokeResult],
              midcycle: bool = False) -> Dict[int, InvokeResult]:
        mine: Dict[int, InvokeResult] = {}
        if self._claimed:
            # results a synchronous invoke drained for this router's other
            # tickets: already folded — just surface them to this caller
            mine.update(self._claimed)
            self._claimed.clear()
        foreign: Dict[int, InvokeResult] = {}
        touched: List[_Hedge] = []
        for ticket, res in results.items():
            pair = self._hedges.get(ticket)
            if pair is not None:
                if ticket == pair.primary:
                    pair.primary_res = res
                else:
                    pair.hedge_res = res
                if pair not in touched:
                    touched.append(pair)
                continue
            if ticket not in self._inflight:
                foreign[ticket] = res     # another submitter's: not ours
                continue
            mine[ticket] = res
            self._finish(ticket, res)
        queued = {p["ticket"]: p["deadline"]
                  for p in self.cluster.engine.pending()}
        for pair in touched:
            res = self._try_resolve_hedge(pair, queued, midcycle=midcycle)
            if res is not None:
                mine[pair.primary] = res
        if foreign:
            self.cluster.engine.hold_results(foreign)
        # prune in-flight tickets that can no longer complete: not in this
        # drain and no longer queued — dropped by a failed cycle's
        # at-most-once contract or discarded via engine.discard.  NEVER
        # midcycle: a ticket being dispatched by the running cycle is
        # neither queued nor in this partial drain, yet it is about to
        # complete — pruning it here would fail every in-flight future the
        # moment the first window of a cycle delivered
        if self._inflight and not midcycle:
            for t in [t for t in self._inflight
                      if t not in results and t not in queued]:
                pair = self._hedges.get(t)
                if pair is not None:
                    if pair in touched or pair.hedge in queued:
                        continue    # just handled / duplicate still possible
                    held = pair.primary_res or pair.hedge_res
                    if held is not None:
                        # partner died while we held a completion: settle
                        mine[pair.primary] = self._settle(
                            pair, held, held is pair.hedge_res)
                    else:           # both members dead: unredeemable
                        del self._hedges[pair.primary]
                        del self._hedges[pair.hedge]
                        del self._inflight[t]
                else:
                    del self._inflight[t]
        return mine

    def _try_resolve_hedge(self, pair: _Hedge, queued: Dict[int, float],
                           midcycle: bool = False
                           ) -> Optional[InvokeResult]:
        """Settle a hedged pair on the EARLIER completion.  With only one
        member complete, the pair settles early iff the partner provably
        cannot beat it — without flush-on-full a queued partner completes
        no sooner than its window's close, so a present result at or
        before that close wins and the loser is discarded before it ever
        dispatches (with ``max_batch`` set the window could fill and
        dispatch early, so the pair waits for the partner instead).
        Returns ``None`` while genuinely undecided."""
        pr, hr = pair.primary_res, pair.hedge_res
        if pr is not None and hr is not None:
            hedge_won = hr.t_received < pr.t_received
            return self._settle(pair, hr if hedge_won else pr, hedge_won)
        present, missing = (pr, pair.hedge) if hr is None else (hr, pair.primary)
        deadline = queued.get(missing)
        if deadline is None:
            if midcycle:
                # the partner is not queued but the cycle is still
                # RUNNING: it may be dispatching right now, its result one
                # on_ready delivery away.  Wait — the cycle-end fold (or
                # its prune path) settles the pair if the partner truly
                # died
                return None
            # partner dead (failed cycle / discarded): present completes
            return self._settle(pair, present, hr is not None)
        if (self.cluster.engine.max_batch is None
                and present.t_received <= deadline):
            # the no-sooner-than-the-close bound only holds without
            # flush-on-full: with max_batch set the partner's window could
            # fill and dispatch BEFORE its deadline, so wait for it instead
            self.cluster.engine.discard(missing)    # loser never dispatches
            return self._settle(pair, present, hr is not None)
        return None

    def _settle(self, pair: _Hedge, winner: InvokeResult,
                hedge_won: bool) -> InvokeResult:
        # EVERY completion of the pair feeds its replica's latency EWMA
        # with its OWN (pre-restamp) latency — the loser included, so a
        # straggler that keeps losing hedges still teaches the policy it
        # is slow (dropping losers is survivorship bias), and the winner's
        # sample is its true service latency, not the client-observed
        # value inflated by the window wait before the hedge fired
        for res in (pair.primary_res, pair.hedge_res):
            if res is not None:
                self.stats.observe_latency(res.node, res.response_ms,
                                           self.EWMA_ALPHA)
        if hedge_won:
            self.stats.inc("hedge_wins")
            # re-stamp the winner against the PRIMARY's send instant: the
            # hedge's own t_send is the later fire time, and the client
            # observes latency from its original submission
            t0 = self._inflight[pair.primary].t_send
            winner = dataclasses.replace(
                winner, t_sent=t0, response_ms=winner.t_received - t0)
        del self._hedges[pair.primary], self._hedges[pair.hedge]
        self._finish(pair.primary, winner, observe_latency=False)
        return winner

    def _finish(self, ticket: int, res: InvokeResult,
                observe_latency: bool = True) -> None:
        m = self._inflight.pop(ticket)
        if observe_latency:     # hedged pairs observed both members in
            self.stats.observe_latency(res.node, res.response_ms,
                                       self.EWMA_ALPHA)      # _settle
        session = (self.sessions.get(m.session_id)
                   if m.session_id is not None else None)
        if session is not None:
            self._observe(session, m.fn, res)
