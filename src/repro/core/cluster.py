"""Discrete-event Enoki cluster (the §4/§5 testbed).

Runs REAL jitted function handlers against REAL store arenas on this machine,
and layers the paper's emulated network (network.py) on top as virtual time —
the same methodology as the paper's tc-netem testbed, with the network
emulated analytically instead of in the kernel.

Replication is asynchronous, exactly as in FReD — but the wire is the
paper's WAN, not a reliable bus.  A local write to a REPLICATED keygroup
appends an outbox entry per (source, target) link carrying ``(kg, seq,
epoch, snapshot)``; transmission attempts consult the ``FaultPlane``
(drops, duplication, jitter, partitions) and re-offer with capped
exponential backoff until the entry is ACKED by the target's drain.
Delivery is at-least-once on the wire and exactly-once at the store: the
drain dedups by ``seq`` and rejects entries whose fencing ``epoch`` is
stale (a crash/rebalance bumps the keygroup epoch, so a restored node's
pre-crash snapshots cannot resurrect overwritten state).  Arrival times
are stamped at TRANSMIT time from the current link, so snapshots queued
during a partition deliver after ``heal()`` instead of stranding at inf.
Staleness falls out of the event timeline and is measured by the
benchmarks the same way the paper measures it (read time minus the apply
time of the overwriting operation).

Placements (ReplicationPolicy):
  REPLICATED     kv ops hit the node-local replica; async replication to peers
  PEER_FETCH     kv ops hit the owner node's store; remote nodes pay one RTT/op
  CLOUD_CENTRAL  kv ops hit the cloud node's store; everyone else pays RTT/op

Concurrency: every node carries its own lock (guarding that node's store/
clock rebinds) and its own replication delivery queue with a queue lock, so
the engine's parallel pump can execute independent store nodes' groups
concurrently — ``_deliver_until``/``_schedule_replication`` never touch
global state.  Lock order within the cluster: a node's lock may be taken
before that same node's queue lock; queue locks of PEERS are only ever
taken with no node lock held (``_schedule_replication`` runs outside them).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import lockdep
from repro.configs.base import ReplicationPolicy
from repro.core.engine import AtomicStats, BatchedInvocationEngine
from repro.core.faas import (FunctionSpec, VectorCodec,
                             compile_batched_handler, compile_handler)
from repro.core.keygroup import KeygroupSpec, arena_new
from repro.core.naming import NamingService
from repro.core.network import FaultPlane, NetworkModel, paper_topology
from repro.core.store import (Store, arena_clone, donation_enabled,
                              merge_snapshots_fused, store_assign_slots)
from repro.core.versioning import MAX_NODES


def fires_sync_downstream(y) -> bool:
    """The paper's fig-8 filter convention: a leading output element < 0
    suppresses synchronous downstream calls.  Single source of truth for
    both the sequential (`invoke`) and batched (engine) routing paths."""
    arr = np.asarray(y)
    return bool(arr.size == 0 or float(arr.ravel()[0]) >= 0.0)


@dataclasses.dataclass
class InvokeResult:
    output: Any
    response_ms: float          # client-observed request-response latency
    t_sent: float
    t_received: float
    t_applied: float            # when state mutation took effect at the store
    kv_ops: List[Tuple[str, int]]
    node: str
    chain: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ClusterStats(AtomicStats):
    """Delivery-merge and transport accounting — the dispatch-count probe
    the fused-merge tests and the verify smoke assert against, plus the
    ack/retry transport's fault counters.  Mutate via ``inc`` only
    (``stats.lock`` is a leaf in the lock order, safe under node locks)."""
    merge_dispatches: int = 0   # fused delivery merges (ONE device dispatch each)
    merge_snapshots: int = 0    # queued snapshots folded by those dispatches
    merge_aligned: int = 0      # dispatches that took the slot-aligned kernel
    merge_fallback: int = 0     # dispatches on the O(S^2) merge_stores body
    repl_retries: int = 0       # outbox re-offers (backoff after drop/partition)
    repl_dropped: int = 0       # transmissions the fault plane dropped
    repl_duped: int = 0         # duplicate deliveries suppressed at the drain
    epoch_rejections: int = 0   # stale-fencing-epoch deliveries rejected


# -- transport knobs: capped exponential backoff of the replication outbox
REPL_RETRY_BASE_MS = 5.0
REPL_RETRY_CAP_MS = 160.0
# per entry per pump: bounds the retry loop under adversarial drop_p ~ 1.0
# (p <= 0.2 converges in a couple of attempts; 64 straight drops at p=0.2
# has probability ~1e-45)
_MAX_ATTEMPTS_PER_PUMP = 64


@dataclasses.dataclass
class _OutboxEntry:
    """One unacked replication snapshot on a (source, target) link.

    State machine: PENDING (``sent=False``) — transmission attempts sample
    the fault plane; a drop or partition re-offers at ``t_ready + backoff``
    — then SENT once a transmission succeeds (the copy, or copies, are in
    the target's delivery queue with finite arrivals), and the entry is
    removed when the target's drain ACKS ``seq``.  A target crash clears
    both its queue and the entries addressed to it; a SOURCE crash leaves
    its own outgoing entries intact (the at-least-once sender restarts
    with its outbox) — the fencing epoch rejects them if state moved on."""
    kg: str
    seq: int
    epoch: int
    snapshot: Store
    nbytes: int
    t_ready: float              # next transmission attempt (virtual ms)
    t_base: float = 0.0         # original schedule instant: heal() re-arms
                                # parked entries back to it so they deliver
                                # as if freshly scheduled on the healed link
    attempts: int = 0
    sent: bool = False


@dataclasses.dataclass
class _Node:
    name: str
    kind: str                   # "edge" | "cloud"
    node_id: int
    stores: Dict[str, Store] = dataclasses.field(default_factory=dict)
    clock: jnp.ndarray = None
    handlers: Dict[str, Callable] = dataclasses.field(default_factory=dict)
    batched_handlers: Dict[str, Callable] = dataclasses.field(
        default_factory=dict)
    compute_ms: Dict[str, float] = dataclasses.field(default_factory=dict)
    # guards store/clock rebinds of THIS node (a Store itself is an
    # immutable NamedTuple — mutation is rebinding the dict entry, and the
    # read-dispatch-write of one invocation holds the lock across all
    # three so concurrent touches of one store node serialize)
    lock: threading.RLock = dataclasses.field(
        default_factory=lambda: lockdep.make_rlock("cluster.node_lock"),
        repr=False, compare=False)

    def __post_init__(self):
        if self.clock is None:
            self.clock = jnp.zeros((), jnp.int32)


@dataclasses.dataclass
class _DeliveryQueue:
    """One node's pending replication deliveries: a heap of
    ``(arrival_t, seq, kg, snapshot, source, epoch)`` behind its own lock,
    so link pumps push into it and the target drains it without any global
    state.  ``applied`` is the dedup ledger of every seq this node ever
    folded (or rejected) — touched only by ``_deliver_until`` under the
    node lock, which serializes drains of one node."""
    heap: List[Tuple[float, int, str, Store, str, int]] = dataclasses.field(
        default_factory=list)
    applied: set = dataclasses.field(default_factory=set)
    lock: threading.Lock = dataclasses.field(
        default_factory=lambda: lockdep.make_lock("cluster.delivery_lock"),
        repr=False, compare=False)


class Cluster:
    def __init__(self, nodes: Dict[str, str], net: Optional[NetworkModel] = None,
                 measure_compute: bool = True, fault_seed: int = 0):
        self.net = net or paper_topology()
        # the lossy-WAN layer: replication transmissions and heartbeat
        # reachability sample it (seeded => any fault schedule replays)
        self.faults = FaultPlane(self.net, seed=fault_seed)
        self.faults.on_heal = self._rearm_outboxes
        self.naming = NamingService()
        self.nodes: Dict[str, _Node] = {}
        for i, (name, kind) in enumerate(nodes.items()):
            self.nodes[name] = _Node(name=name, kind=kind, node_id=i)
            self.naming.register_node(name, kind)
        # per-node pending replication deliveries (each behind its own lock)
        self._queues: Dict[str, _DeliveryQueue] = {
            name: _DeliveryQueue() for name in self.nodes}
        self._seq = itertools.count()
        # per-(source, target) replication outboxes: unacked entries with
        # their retry state.  One lock for the whole table — entries are
        # tiny and the pump holds it only across host-side bookkeeping.
        self._outboxes: Dict[Tuple[str, str], List[_OutboxEntry]] = {}
        self._outbox_lock = lockdep.make_lock("cluster.outbox_lock")
        # per-keygroup fencing epochs (bumped by membership crash/rebalance
        # under membership.lock -> outbox_lock; read lock-free on the
        # schedule/drain paths — a torn read is impossible for a dict of
        # ints and staleness only delays a rejection by one drain)
        self._epochs: Dict[str, int] = {}
        # back-reference set by ElasticMembership.__init__ so the drain can
        # report epoch rejections into MembershipStats
        self.membership = None
        self._repl_lock = lockdep.make_lock(
            "cluster.repl_lock")             # replication_bytes accounting
        self._measure = measure_compute
        self.replication_bytes = 0   # accounting for §Perf
        self.stats = ClusterStats()
        self.specs: Dict[str, FunctionSpec] = {}
        self.policies: Dict[str, KeygroupSpec] = {}
        # canonical key->slot layout per keygroup (deploy-time, grows
        # monotonically) and whether every replica still carries it; an
        # unaligned keygroup PERMANENTLY uses the O(S^2) fallback merge
        self._slot_maps: Dict[str, Dict[int, int]] = {}
        self._aligned: Dict[str, bool] = {}
        self.engine = BatchedInvocationEngine(self)

    # ------------------------------------------------------------------ deploy
    def create_keygroup(self, spec: KeygroupSpec, nodes: List[str]) -> None:
        self.naming.create_keygroup(spec)
        self.policies[spec.name] = spec
        for n in nodes:
            self._materialise_keygroup(spec, n)

    def _materialise_keygroup(self, spec: KeygroupSpec, node: str) -> None:
        """Create or replicate a keygroup to ``node`` (§2: deploy-time copy)."""
        existing = self.naming.replicas_of(spec.name)
        nd = self.nodes[node]
        if node in existing:
            return
        if existing:
            # replicate current contents from any live replica — as a
            # CLONE: replicas must never share arena buffers, or a donated
            # fold at one node would invalidate the other's store (TPU/GPU)
            src = next(iter(existing))
            with self.nodes[src].lock:
                snapshot = self.nodes[src].stores[spec.name]
            nd.stores[spec.name] = arena_clone(snapshot)
        else:
            nd.stores[spec.name] = self.blank_arena(spec.name, spec)
        self.naming.add_replica(spec.name, node)

    def blank_arena(self, kg: str, kspec: Optional[KeygroupSpec] = None
                    ) -> Store:
        """A fresh arena for ``kg`` with the keygroup's canonical slot
        layout pre-applied.  Restores/rebalances (runtime/elastic,
        runtime/failure) MUST use this instead of a raw ``arena_new`` so a
        rebuilt replica stays slot-aligned with its peers."""
        kspec = kspec or self.policies[kg]
        arena = arena_new(kspec, MAX_NODES)
        amap = self._slot_maps.get(kg)
        if amap:
            arena, ok = store_assign_slots(arena, amap)
            assert ok, kg   # fresh arena: the layout always applies
        return arena

    def deploy(self, spec: FunctionSpec, nodes: List[str],
               policy: ReplicationPolicy = ReplicationPolicy.REPLICATED,
               owner: Optional[str] = None, value_width: Optional[int] = None,
               example_input=None) -> None:
        """Deploy a function (and its keygroups) to ``nodes`` — §2 flow."""
        self.specs[spec.name] = spec
        self.naming.register_function(spec.name, spec.keygroups)
        example = example_input if example_input is not None else jnp.zeros((1,), jnp.float32)
        for kg_name in spec.keygroups:
            kspec = self.policies.get(kg_name) or KeygroupSpec(
                name=kg_name, policy=policy,
                value_width=value_width or spec.codec_width, owner=owner)
            self.policies[kg_name] = kspec
            self.naming.create_keygroup(kspec)
            # store placement depends on policy
            if kspec.policy == ReplicationPolicy.REPLICATED:
                placement = nodes
            elif kspec.policy == ReplicationPolicy.PEER_FETCH:
                placement = [kspec.owner or nodes[0]]
            else:  # CLOUD_CENTRAL
                placement = [kspec.owner or self._cloud_node()]
            for n in placement:
                self._materialise_keygroup(kspec, n)
        for n in nodes:
            nd = self.nodes[n]
            nd.handlers[spec.name] = compile_handler(spec, nd.node_id, example)
            nd.batched_handlers[spec.name] = compile_batched_handler(
                spec, nd.node_id, example)
            self.naming.add_deployment(spec.name, n)
            if self._measure:
                nd.compute_ms[spec.name] = self._measure_compute(spec, nd, example)
            else:
                nd.compute_ms[spec.name] = 0.0
        if spec.keygroups:
            # canonical slot pre-assignment: the handler's key set is
            # static (literal strings hashed at trace time), so stamp it
            # into every replica now — delivery merges then take the
            # elementwise slot-aligned kernel instead of the O(S^2) probe
            bh = self.nodes[nodes[0]].batched_handlers[spec.name]
            self._register_keys(spec.keygroups[0],
                                getattr(bh, "key_hashes", ()))

    def _register_keys(self, kg: str, hashes) -> None:
        """Assign each new key hash the next free canonical slot and apply
        the layout to every replica of ``kg`` (``store_assign_slots``).

        If the layout cannot apply — arena overflow, or a dynamic write
        already claimed a conflicting slot — the keygroup permanently
        falls back to the layout-agnostic ``merge_stores`` path:
        correctness never depends on alignment, only the merge cost does.
        """
        hashes = tuple(dict.fromkeys(int(h) for h in hashes))
        if not hashes or self._aligned.get(kg) is False:
            return
        kspec = self.policies.get(kg)
        slots = kspec.slots if kspec else 64
        amap = self._slot_maps.setdefault(kg, {})
        fresh = [h for h in hashes if h not in amap]
        if len(amap) + len(fresh) > slots:
            self._aligned[kg] = False   # more static keys than slots
            return
        used = set(amap.values())
        nxt = 0
        for h in fresh:
            while nxt in used:
                nxt += 1
            amap[h] = nxt
            used.add(nxt)
        new = {h: amap[h] for h in fresh}
        ok_all = True
        for node in self.naming.replicas_of(kg):
            nd = self.nodes[node]
            with nd.lock:
                arena, ok = store_assign_slots(nd.stores[kg], new)
                if not ok:
                    ok_all = False
                    break
                nd.stores[kg] = arena
        self._aligned[kg] = ok_all

    def _cloud_node(self) -> str:
        for n, nd in self.nodes.items():
            if nd.kind == "cloud":
                return n
        return next(iter(self.nodes))

    def _measure_compute(self, spec: FunctionSpec, nd: _Node, example) -> float:
        """Median wall-time of the jitted handler on this host (warm starts)."""
        kg = spec.keygroups[0] if spec.keygroups else None
        if kg and kg in nd.stores:
            store = nd.stores[kg]
        elif kg:
            # store placed remotely (PEER_FETCH/CLOUD_CENTRAL): measure against
            # any replica's state — compute cost is placement-independent.
            replica = next(iter(self.naming.replicas_of(kg)))
            store = self.nodes[replica].stores[kg]
        else:
            store = arena_new(
                KeygroupSpec(name="_tmp", value_width=spec.codec_width),
                MAX_NODES)
        h = nd.handlers[spec.name]
        h(store, nd.clock, example)  # compile
        ts = []
        for _ in range(10):
            t0 = time.perf_counter()
            out = h(store, nd.clock, example)
            jax.block_until_ready(out[:3])
            ts.append((time.perf_counter() - t0) * 1e3)
        return float(np.median(ts))

    # --------------------------------------------------------------- timeline
    # -- fencing epochs ------------------------------------------------------
    def fence_epoch(self, kg: str) -> int:
        """Current fencing epoch of ``kg`` (0 until the first crash or
        rebalance touches it).  Snapshots are stamped with it at schedule
        time; the drain rejects anything older."""
        return self._epochs.get(kg, 0)

    def bump_fence(self, kg: str) -> int:
        """Advance ``kg``'s fencing epoch (membership calls this on every
        crash/rebalance involving the keygroup).  Outstanding snapshots
        stamped with the old epoch are rejected at delivery — a restored
        node cannot resurrect pre-crash state past the rebalance; it
        re-syncs through the catch-up path instead."""
        with self._outbox_lock:
            e = self._epochs.get(kg, 0) + 1
            self._epochs[kg] = e
            return e

    # -- the ack/retry transport --------------------------------------------
    @staticmethod
    def _backoff_ms(attempts: int) -> float:
        return min(REPL_RETRY_BASE_MS * (2.0 ** attempts), REPL_RETRY_CAP_MS)

    def _pump_entries(self, src: str, dst: str,
                      entries: List[_OutboxEntry], t: float) -> None:
        """Attempt transmission of every PENDING entry of one link whose
        retry timer is due (``t_ready <= t``).  Called with the outbox lock
        held; pushes successful copies into ``dst``'s delivery queue with
        arrival stamped from the CURRENT link state (transmit time + one
        way + sampled jitter) — partition-era entries re-time after heal.

        A partitioned link costs ONE re-offer per pump (its state cannot
        change within the call — heal() happens between pumps); lossy
        links retry inline up to the per-pump attempt budget."""
        base_t = t if np.isfinite(t) else 0.0
        for e in entries:
            if e.sent:
                continue
            budget = _MAX_ATTEMPTS_PER_PUMP
            while not e.sent and e.t_ready <= t and budget > 0:
                budget -= 1
                attempt_t = e.t_ready if np.isfinite(e.t_ready) else base_t
                if self.faults.partitioned(src, dst):
                    e.t_ready = base_t + self._backoff_ms(e.attempts)
                    e.attempts += 1
                    self.stats.inc("repl_retries")
                    break
                tx = self.faults.transmit(src, dst)
                if not tx.ok:
                    e.t_ready = attempt_t + self._backoff_ms(e.attempts)
                    e.attempts += 1
                    self.stats.inc("repl_dropped")
                    self.stats.inc("repl_retries")
                    continue
                arrival0 = attempt_t + self.net.one_way_ms(src, dst)
                q = self._queues[dst]
                with q.lock:
                    for j in range(tx.copies):
                        heapq.heappush(
                            q.heap, (arrival0 + tx.jitter_ms[j], e.seq,
                                     e.kg, e.snapshot, src, e.epoch))
                e.sent = True

    def _rearm_outboxes(self) -> None:
        """heal() hook: reset every PENDING entry on a now-reachable link
        back to its original schedule instant (``t_base``, fresh backoff).
        Without this a partition-era retry timer sits at "last pump time +
        backoff", and a flush at that same virtual horizon could never
        reach it — the snapshot would strand exactly like the historical
        ``inf``-arrival events.  Re-armed entries deliver at
        ``t_base + one_way`` as if freshly scheduled on the healed link."""
        with self._outbox_lock:
            for (src, dst), entries in self._outboxes.items():
                if self.faults.partitioned(src, dst):
                    continue
                for e in entries:
                    if not e.sent and e.t_ready > e.t_base:
                        e.t_ready = e.t_base
                        e.attempts = 0

    def _pump_inbound(self, node: str, t: float) -> None:
        """Drive the retry state machine of every link INTO ``node`` up to
        virtual time ``t`` — the receive half of the transport, run by the
        target's drain so no extra scheduler thread exists."""
        with self._outbox_lock:
            for (src, dst), entries in self._outboxes.items():
                if dst == node and entries:
                    self._pump_entries(src, dst, entries, t)

    def _ack(self, node: str, acks: List[Tuple[str, int]]) -> None:
        """Remove drained entries from their (source, ``node``) outboxes —
        the delivery ack.  A rejected (stale-epoch) or deduped delivery
        acks too: the sender must stop re-offering either way."""
        by_src: Dict[str, set] = {}
        for src, seq in acks:
            by_src.setdefault(src, set()).add(seq)
        with self._outbox_lock:
            for src, seqs in by_src.items():
                key = (src, node)
                entries = self._outboxes.get(key)
                if entries:
                    self._outboxes[key] = [e for e in entries
                                           if e.seq not in seqs]

    def _deliver_until(self, node: str, t: float) -> None:
        """Pump the transport for ``node``'s inbound links, then apply all
        deliveries with arrival <= t in (arrival, seq) order — network
        delivery order, so a later snapshot is always merged after an
        earlier one regardless of how the pending heap happens to be laid
        out.  Duplicate seqs (link-level duplication, or a retransmit
        racing its own ack) are suppressed via the queue's ``applied``
        ledger, and entries carrying a stale fencing epoch are rejected;
        both still ACK so the sender stops re-offering.

        The K due snapshots of each keygroup fold with ONE fused device
        dispatch (``merge_snapshots_fused``: a ``lax.scan`` over the
        stacked snapshots) instead of K sequential jit calls under the
        node lock — on the slot-aligned elementwise kernel when the
        keygroup's canonical layout held up, on the O(S²) ``merge_stores``
        body otherwise.  Either way the result is bit-identical to the
        old per-snapshot loop (the scan folds in the same order).

        Thread-safe: ``node``'s own lock and queue lock serialize the
        drain (the outbox lock nests inside the node lock for the ack),
        so deliveries to different nodes run concurrently under the
        parallel pump."""
        self._pump_inbound(node, t)
        nd = self.nodes[node]
        q = self._queues[node]
        with nd.lock:
            with q.lock:
                due = [ev for ev in q.heap if ev[0] <= t]
                if not due:
                    return
                keep = [ev for ev in q.heap if ev[0] > t]
                # the filtered keep-list is no longer a valid heap for
                # later heappush
                heapq.heapify(keep)
                q.heap = keep
            per_kg: Dict[str, List[Store]] = {}
            acks: List[Tuple[str, int]] = []
            dups = stale = 0
            for arrival, seq, kg, snapshot, source, epoch in sorted(
                    due, key=lambda e: e[:2]):
                acks.append((source, seq))
                if seq in q.applied:
                    dups += 1
                    continue
                q.applied.add(seq)
                if epoch < self._epochs.get(kg, 0):
                    stale += 1      # fenced: state moved on past the sender
                    continue
                if kg not in nd.stores:
                    continue    # replica crashed away mid-flight: stale
                per_kg.setdefault(kg, []).append(snapshot)
            for kg, snaps in per_kg.items():
                aligned = self._aligned.get(kg, False)
                nd.stores[kg] = merge_snapshots_fused(
                    nd.stores[kg], snaps, aligned=aligned)
                self.stats.inc("merge_dispatches")
                self.stats.inc("merge_snapshots", len(snaps))
                self.stats.inc("merge_aligned" if aligned
                               else "merge_fallback")
            if dups:
                self.stats.inc("repl_duped", dups)
            if stale:
                self.stats.inc("epoch_rejections", stale)
                m = self.membership
                if m is not None:
                    m.stats.inc("epoch_rejections", stale)
            self._ack(node, acks)

    def _schedule_replication(self, kg: str, source: str, t_apply: float) -> None:
        spec = self.policies[kg]
        if spec.policy != ReplicationPolicy.REPLICATED:
            return
        with self.nodes[source].lock:
            snapshot = self.nodes[source].stores[kg]
            if donation_enabled():
                # a queued snapshot must never alias the live arena: the
                # source's next fold and the target's fused merge DONATE
                # their arena argument on TPU/GPU, which would invalidate
                # every queued reference.  On CPU donation is a no-op and
                # the immutable arena is shared for free.
                snapshot = arena_clone(snapshot)
        nbytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                     for x in snapshot[:4])
        epoch = self._epochs.get(kg, 0)
        alive = set(self.naming.alive_nodes())
        targets = [peer for peer in self.naming.replicas_of(kg)
                   if peer != source and peer in alive]
                    # a dead replica receives nothing; a restore re-syncs
                    # it from a live peer snapshot instead.  SUSPECT peers
                    # DO receive entries — their outboxes simply retry
                    # until the partition heals (replicas are not torn
                    # down on suspicion).
        t0 = t_apply if np.isfinite(t_apply) else 0.0
        with self._outbox_lock:
            for peer in targets:
                entries = self._outboxes.setdefault((source, peer), [])
                entries.append(_OutboxEntry(
                    kg=kg, seq=next(self._seq), epoch=epoch,
                    snapshot=snapshot, nbytes=nbytes, t_ready=t0,
                    t_base=t0))
                # eager first attempt at schedule time: on a healthy link
                # this lands the old fire-and-forget arrival
                # (t_apply + one_way) exactly
                self._pump_entries(source, peer, entries, t0)
            with self._repl_lock:
                self.replication_bytes += nbytes * len(targets)

    def drop_pending_deliveries(self, node: str) -> int:
        """Discard every undelivered replication event addressed to
        ``node``: its delivery queue AND the unacked outbox entries its
        peers still hold for it (a crashed replica loses what was on the
        wire TO it; the crashed node's own OUTGOING entries survive — the
        at-least-once sender keeps its outbox across a restart, and the
        fencing epoch rejects whatever went stale).  Returns the number of
        dropped events (queued arrivals + never-transmitted entries; a
        transmitted entry is already counted by its queued copy)."""
        q = self._queues[node]
        with q.lock:
            n = len(q.heap)
            q.heap = []
        with self._outbox_lock:
            for key in [k for k in self._outboxes if k[1] == node]:
                n += sum(1 for e in self._outboxes.pop(key) if not e.sent)
        return n

    def transport_idle(self) -> bool:
        """True when nothing is in flight: every delivery queue is empty
        and every outbox entry still unacked sits on a PARTITIONED link
        (those cannot make progress until heal)."""
        with self._outbox_lock:
            for (src, dst), entries in self._outboxes.items():
                if entries and not self.faults.partitioned(src, dst):
                    return False
        for q in self._queues.values():
            with q.lock:
                if q.heap:
                    return False
        return True

    def drain_transport(self, t: float = 0.0, max_rounds: int = 200,
                        step_ms: float = 1000.0) -> bool:
        """Flush replication repeatedly, advancing virtual time from ``t``
        by ``step_ms`` per round, until the transport is idle (retries on
        lossy links need time to elapse for their backoff timers).  Returns
        False when non-partitioned work remains after ``max_rounds`` —
        never the case for drop_p < 1 links at the default budget."""
        for i in range(max_rounds):
            self.flush_replication(t + i * step_ms)
            if self.transport_idle():
                return True
        return self.transport_idle()

    def add_node(self, name: str, kind: str = "edge") -> None:
        """Register a NEW node at runtime (elastic join).  The node starts
        with no stores or handlers — membership catch-up replicates
        keygroups and deploys handlers before it serves (runtime/elastic)."""
        if name in self.nodes:
            raise ValueError(f"node {name!r} already exists")
        node_id = max(nd.node_id for nd in self.nodes.values()) + 1
        if node_id >= MAX_NODES:
            raise ValueError(f"cluster is at MAX_NODES={MAX_NODES}")
        self.nodes[name] = _Node(name=name, kind=kind, node_id=node_id)
        self._queues[name] = _DeliveryQueue()
        self.naming.register_node(name, kind)

    def pending_replication(self, node: Optional[str] = None
                            ) -> List[Tuple[float, str, str]]:
        """Read-only view of undelivered replication events as
        ``(arrival_t, keygroup, target_node)`` tuples, sorted by arrival —
        the public replacement for poking the (now per-node) delivery
        queues directly."""
        out = []
        for name, q in self._queues.items():
            if node is not None and name != node:
                continue
            with q.lock:
                out.extend((ev[0], ev[2], name) for ev in q.heap)
        # plus outbox entries not yet transmitted (partitioned or retrying
        # links): surfaced with their next-attempt time as the horizon
        with self._outbox_lock:
            for (_, dst), entries in self._outboxes.items():
                if node is not None and dst != node:
                    continue
                out.extend((e.t_ready, e.kg, dst)
                           for e in entries if not e.sent)
        return sorted(out)

    # ----------------------------------------------------------------- invoke
    def _resolve_placement(self, spec: FunctionSpec, node: str
                           ) -> Tuple[Optional[str], str, float]:
        """(keygroup, store_node, per_op_rtt_ms) for an invocation at
        ``node`` — which replica the kv ops hit and what each op costs."""
        kg = spec.keygroups[0] if spec.keygroups else None
        if kg is None:
            return None, node, 0.0
        kspec = self.policies[kg]
        if kspec.policy == ReplicationPolicy.REPLICATED:
            return kg, node, 0.0
        owner = (kspec.owner or
                 (self._cloud_node()
                  if kspec.policy == ReplicationPolicy.CLOUD_CENTRAL
                  else node))
        per_op_ms = 0.0 if owner == node else self.net.rtt_ms(node, owner)
        return kg, owner, per_op_ms

    def _op_network_ms(self, node: str, store_node: str, per_op_ms: float,
                       ops: List[Tuple[str, int]]) -> float:
        """Per-op network charges for remote store placements (§4.1: the
        +200ms of 4 kv ops against a cloud store)."""
        if per_op_ms <= 0.0:
            return 0.0
        link = self.net.link(node, store_node)
        return sum(per_op_ms + link.transfer_ms(nbytes) for _, nbytes in ops)

    def invoke(self, fn_name: str, node: str, x, t_send: float = 0.0,
               client: str = "client", payload_bytes: int = 64) -> InvokeResult:
        """One-off invocation: a SINGLETON frame through the batched
        engine's scheduler, drained synchronously.

        There is no separate sequential pipeline any more — the engine's
        flush cycle (store fold, per-request virtual timeline, coalesced
        replication snapshot, downstream call chains, dead-node reroute)
        is the one implementation both paths share, so every stat,
        eviction rule and hedging hook applies identically whether a
        request arrives alone or in a window.  A singleton cycle charges
        the exact same network/compute timeline the old inline path did
        (the engine's latency-parity tests pin this); ``output`` holds a
        host numpy row like ``invoke_batch``'s results do."""
        [res] = self.engine.dispatch(fn_name, node, [x], [t_send],
                                     client=client,
                                     payload_bytes=payload_bytes)
        return res

    def invoke_batch(self, fn_name: str, node: str, xs,
                     t_sends: Optional[List[float]] = None,
                     client: str = "client",
                     payload_bytes: int = 64) -> List[InvokeResult]:
        """Invoke ``fn_name`` at ``node`` for every input in ``xs`` with ONE
        batched device dispatch (per bucket chunk) instead of len(xs) Python
        round-trips — the §4.2 throughput hot path.

        The emulated network is threaded per request (each entry of
        ``t_sends`` keeps its own arrival/response timeline).  For the
        invoked function itself, store-update semantics match len(xs)
        sequential ``invoke`` calls exactly (scan-fold, last-writer-wins,
        identical clocks).  Downstream call chains follow the engine's
        flush-cycle model instead: callees run after the caller chunks of
        the cycle (chunks cap at the largest bucket, 256 by default) and
        coalesce per callee ACROSS chunks, so a callee that reads state its
        caller writes sees the post-chunk value, not its own request's
        prefix (see core/engine.py and docs/batched_engine.md for this and
        the replication-coalescing trade-off).  Returns per-request
        InvokeResults in input order;
        ``output`` holds host numpy rows (the batch is materialised once),
        exactly like ``invoke``'s singleton frames.
        """
        return self.engine.dispatch(fn_name, node, xs, t_sends,
                                    client=client,
                                    payload_bytes=payload_bytes)

    def is_read_only(self, fn_name: str) -> bool:
        """Whether invoking ``fn_name`` is free of state mutation ANYWHERE
        in its call graph: its own deploy-time op trace plus every
        transitive callee's.  This is the hedge-safety gate — a hedged
        retry re-runs the WHOLE downstream chain, so a stateless caller
        with a mutating callee (e.g. a fig-8 filter in front of a writer)
        is NOT safe to re-invoke even though its own trace is empty."""
        seen = set()
        stack = [fn_name]
        while stack:
            fn = stack.pop()
            if fn in seen:
                continue
            seen.add(fn)
            if not self._handler_read_only(fn):     # raises if fn_name
                return False                        # itself is undeployed
            spec = self.specs[fn]
            for callee in (*spec.calls, *spec.async_calls):
                if callee not in self.specs:
                    return False    # unknown callee: cannot prove safety
                stack.append(callee)
        return True

    def _handler_read_only(self, fn_name: str) -> bool:
        """The per-handler flag from the deploy-time op trace (identical at
        every deployment since the trace is static)."""
        for n in self.naming.deployments_of(fn_name):
            h = self.nodes[n].handlers.get(fn_name)
            if h is not None:
                return bool(getattr(h, "read_only", False))
        raise KeyError(f"{fn_name} not deployed anywhere")

    def _nearest_deployment(self, fn_name: str, from_node: str) -> str:
        """Nearest ROUTABLE deployment — dead nodes never receive new
        work, and SUSPECT nodes (minority-view partition) stop receiving
        it too, so a downstream wave whose usual target crashed or went
        unreachable fails over to the nearest surviving replica instead of
        dispatching into the void."""
        alive = set(self.naming.routable_nodes())
        nodes = [n for n in self.naming.deployments_of(fn_name)
                 if n in alive and fn_name in self.nodes[n].handlers]
        if not nodes:
            raise KeyError(f"no live deployment of {fn_name}")
        return min(nodes, key=lambda n: self.net.rtt_ms(from_node, n))

    def set_compute_ms(self, node: str, fn_name: str, ms: float) -> None:
        """Override the per-invocation compute charge of ``fn_name`` at
        ``node`` in the virtual timeline — the knob benchmarks/tests use to
        model an overloaded STRAGGLER replica (the hedging scenario): the
        nearest deployment stays nearest by RTT but serves slowly."""
        if fn_name not in self.nodes[node].compute_ms:
            raise KeyError(f"{fn_name!r} is not deployed at {node!r}")
        self.nodes[node].compute_ms[fn_name] = float(ms)

    # -------------------------------------------------------------- debugging
    def store_of(self, kg: str, node: str) -> Store:
        return self.nodes[node].stores[kg]

    def flush_replication(self, t: float = float("inf")) -> None:
        for n in self.nodes:
            self._deliver_until(n, t)
