"""Keygroups — Enoki/FReD's unit of replication.

Two flavours cover the edge-FaaS scale and the TPU scale:

* ``ArenaKeygroup`` — a string-keyed KV arena (``store.Store``) with a
  replication policy; what the paper's Python functions see via ``kv.*``.
* ``TensorKeygroup`` — an arbitrary pytree of arrays (model parameters, a
  session KV cache, a data-pipeline cursor) with a scalar step-version and a
  pluggable merge rule.  This is how the paper's technique becomes a
  first-class feature of the training/serving framework: the hot path only
  ever touches the *local* replica; ``replication.py`` reconciles replicas
  off the hot path.

Merge rules for tensor keygroups:
  lww     — replica with the higher version wins wholesale (sessions/cursors)
  mean    — elementwise average (parameter averaging / local SGD)
  diloco  — delta-based outer optimizer (optim/diloco.py supplies the step)
  max     — elementwise max (CRDT counters, metrics high-water marks)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ReplicationPolicy
from repro.core import crdt
from repro.core.store import Store, merge_stores, store_new


@dataclasses.dataclass(frozen=True)
class KeygroupSpec:
    name: str
    policy: ReplicationPolicy = ReplicationPolicy.REPLICATED
    # arena keygroups
    slots: int = 64
    value_width: int = 64
    dtype: Any = jnp.float32
    # tensor keygroups
    merge: str = "lww"            # lww | mean | max | diloco
    # owner node for PEER_FETCH / CLOUD_CENTRAL placements
    owner: Optional[str] = None


def arena_new(spec: KeygroupSpec, num_nodes: int) -> Store:
    return store_new(spec.slots, spec.value_width, num_nodes, spec.dtype)


@jax.tree_util.register_pytree_node_class
class TensorKeygroup:
    """A replicated pytree with a version and a merge rule."""

    def __init__(self, tree: Any, version: jnp.ndarray, merge: str = "lww"):
        self.tree = tree
        self.version = version
        self.merge = merge

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.tree, self.version), self.merge

    @classmethod
    def tree_unflatten(cls, merge, children):
        tree, version = children
        return cls(tree, version, merge)

    # -- API ----------------------------------------------------------------
    @classmethod
    def create(cls, tree: Any, merge: str = "lww") -> "TensorKeygroup":
        return cls(tree, jnp.zeros((), jnp.int32), merge)

    def write(self, new_tree: Any) -> "TensorKeygroup":
        return TensorKeygroup(new_tree, self.version + 1, self.merge)

    def merged_with(self, other: "TensorKeygroup") -> "TensorKeygroup":
        return merge_tensor_keygroups(self, other)


def merge_tensor_keygroups(a: TensorKeygroup, b: TensorKeygroup) -> TensorKeygroup:
    if a.merge != b.merge:
        raise ValueError(f"merge-rule mismatch: {a.merge} vs {b.merge}")
    if a.merge == "lww":
        take_b = b.version > a.version
        tree = jax.tree.map(lambda x, y: jnp.where(take_b, y, x), a.tree, b.tree)
        version = jnp.maximum(a.version, b.version)
    elif a.merge == "mean":
        tree = jax.tree.map(lambda x, y: (x + y) / 2, a.tree, b.tree)
        version = jnp.maximum(a.version, b.version)
    elif a.merge == "max":
        tree = jax.tree.map(crdt.max_merge, a.tree, b.tree)
        version = jnp.maximum(a.version, b.version)
    else:
        raise ValueError(
            f"merge rule {a.merge!r} needs the replication engine "
            "(diloco merges are stateful; see optim/diloco.py)")
    return TensorKeygroup(tree, version, a.merge)


def merge_arena_keygroups(a: Store, b: Store) -> Store:
    return merge_stores(a, b)
