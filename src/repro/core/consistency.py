"""Client-centric consistency (the FReD guarantee Enoki inherits).

FReD's client library gives *client-centric* guarantees — read-your-writes and
monotonic reads — while replica contents may be stale.  We realise the same
contract with session tokens: a session carries version-vector high-water
marks of everything it has read and written; a replica can serve the session
iff its own version vector dominates the session's requirement.

These checks run host-side in the router (control plane) against device
version vectors; they are cheap (N<=64 int32 compares).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.crdt import vv_dominates, vv_merge


@dataclasses.dataclass
class Session:
    """Mutable client session token (host-side)."""

    num_nodes: int
    read_vv: np.ndarray = None    # highest clocks this session has observed
    write_vv: np.ndarray = None   # highest clocks this session has written

    def __post_init__(self):
        if self.read_vv is None:
            self.read_vv = np.zeros((self.num_nodes,), np.int32)
        if self.write_vv is None:
            self.write_vv = np.zeros((self.num_nodes,), np.int32)

    # -- requirements -----------------------------------------------------
    def requirement(self) -> np.ndarray:
        """vv a replica must dominate to serve this session:
        read-your-writes needs write_vv; monotonic reads needs read_vv."""
        return np.maximum(self.read_vv, self.write_vv)

    def can_read_from(self, replica_vv) -> bool:
        return bool(np.all(np.asarray(replica_vv) >= self.requirement()))

    # -- observations -----------------------------------------------------
    def observe_read(self, replica_vv) -> None:
        self.read_vv = np.maximum(self.read_vv, np.asarray(replica_vv))

    def observe_write(self, node_id: int, clock: int) -> None:
        self.write_vv[node_id] = max(self.write_vv[node_id], int(clock))


def replica_dominates(replica_vv: jnp.ndarray, required_vv: jnp.ndarray):
    """Device-side variant of the session check (used inside jitted guards)."""
    return vv_dominates(replica_vv, required_vv)


def merge_observed(a_vv: jnp.ndarray, b_vv: jnp.ndarray) -> jnp.ndarray:
    return vv_merge(a_vv, b_vv)
