"""Convergent replicated data types (CRDTs) as pure jnp merge functions.

The paper (§2) notes applications can resolve concurrent-update conflicts with
CRDTs [Shapiro et al. 2011].  Every merge here is **commutative, associative
and idempotent** (property-tested in tests/test_crdt_properties.py), which is
what makes Enoki's asynchronous anti-entropy safe: replicas converge no matter
the order or repetition of merge rounds.

All merges operate on arrays so they can run inside jitted replication steps
and, for large state, inside the ``enoki_merge`` Pallas kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LWW register: (value, packed_version) — merge keeps the higher version.
# ---------------------------------------------------------------------------

class LWWRegister(NamedTuple):
    value: jnp.ndarray      # (..., payload)
    version: jnp.ndarray    # (...,) packed lamport version


def lww_merge(a: LWWRegister, b: LWWRegister) -> LWWRegister:
    """Elementwise last-writer-wins.  version ties are identical writes."""
    take_b = b.version > a.version
    # broadcast the selection mask over trailing payload dims
    mask = take_b.reshape(take_b.shape + (1,) * (a.value.ndim - take_b.ndim))
    return LWWRegister(
        value=jnp.where(mask, b.value, a.value),
        version=jnp.maximum(a.version, b.version),
    )


# ---------------------------------------------------------------------------
# G-counter: per-node grow-only counters; merge = elementwise max.
# ---------------------------------------------------------------------------

class GCounter(NamedTuple):
    counts: jnp.ndarray     # (num_nodes,) int32 — one slot per node


def gcounter_new(num_nodes: int) -> GCounter:
    return GCounter(jnp.zeros((num_nodes,), jnp.int32))


def gcounter_increment(c: GCounter, node_id, amount=1) -> GCounter:
    return GCounter(c.counts.at[node_id].add(amount))


def gcounter_merge(a: GCounter, b: GCounter) -> GCounter:
    return GCounter(jnp.maximum(a.counts, b.counts))


def gcounter_value(c: GCounter) -> jnp.ndarray:
    return c.counts.sum()


# ---------------------------------------------------------------------------
# PN-counter: increments and decrements as two G-counters.
# ---------------------------------------------------------------------------

class PNCounter(NamedTuple):
    pos: jnp.ndarray
    neg: jnp.ndarray


def pncounter_new(num_nodes: int) -> PNCounter:
    z = jnp.zeros((num_nodes,), jnp.int32)
    return PNCounter(z, z)


def pncounter_add(c: PNCounter, node_id, amount) -> PNCounter:
    amount = jnp.asarray(amount, jnp.int32)
    pos = c.pos.at[node_id].add(jnp.maximum(amount, 0))
    neg = c.neg.at[node_id].add(jnp.maximum(-amount, 0))
    return PNCounter(pos, neg)


def pncounter_merge(a: PNCounter, b: PNCounter) -> PNCounter:
    return PNCounter(jnp.maximum(a.pos, b.pos), jnp.maximum(a.neg, b.neg))


def pncounter_value(c: PNCounter) -> jnp.ndarray:
    return c.pos.sum() - c.neg.sum()


# ---------------------------------------------------------------------------
# Max/min registers (grow-only extremes) — trivially CRDT.
# ---------------------------------------------------------------------------

def max_merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(a, b)


def min_merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.minimum(a, b)


# ---------------------------------------------------------------------------
# Version vectors: (num_nodes,) per-node high-water marks; merge = max.
# A version vector is itself a G-counter-shaped CRDT.
# ---------------------------------------------------------------------------

def vv_new(num_nodes: int) -> jnp.ndarray:
    return jnp.zeros((num_nodes,), jnp.int32)


def vv_merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(a, b)


def vv_dominates(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """True iff a >= b componentwise (a has seen everything b has)."""
    return jnp.all(a >= b)
