"""Staleness measurement (paper §4.3).

"We consider a value as stale if it has been overwritten before the client
reads it, with staleness measured as difference between current (read) time
and timestamp of the operation that changed the value."

The benchmark drives a single logical client (no clock drift, as in the
paper) writing monotonically increasing payloads; given the write log and a
read observation these helpers compute the paper's staleness statistic.
"""
from __future__ import annotations

import bisect
import dataclasses
import sys
from typing import List, Optional, Tuple

_INF_ID = sys.maxsize       # sorts a (t, id) probe after every real record
                            # sharing the same timestamp


@dataclasses.dataclass
class WriteLog:
    """(t_applied, payload_id) records of one key's writes, kept sorted.

    ``add`` may be called OUT of apply-time order — replicated writes
    arrive out of order by design — so records are insertion-sorted on
    ``(t_applied, payload_id)`` and queries are ``bisect`` lookups instead
    of full scans.  The single-logical-client contract (module docstring)
    makes payload ids co-monotonic with apply times, so the sorted order
    is simultaneously time- and payload-ordered; ``add`` verifies that
    property against the insertion point (O(1)) and, should a feed ever
    violate it, ``staleness_of_read`` degrades to the exact linear scan
    instead of silently bisecting a list that is unsorted by payload."""

    records: List[Tuple[float, int]] = dataclasses.field(default_factory=list)
    _payload_sorted: bool = True

    def add(self, t_applied: float, payload_id: int) -> None:
        i = bisect.bisect_right(self.records, (t_applied, payload_id))
        if ((i > 0 and self.records[i - 1][1] > payload_id)
                or (i < len(self.records) and self.records[i][1] < payload_id)):
            self._payload_sorted = False
        self.records.insert(i, (t_applied, payload_id))

    def staleness_of_read(self, t_read: float, payload_id: int) -> float:
        """0.0 if the read value was the newest applied at t_read; otherwise
        t_read - t_apply(first write that overwrote it)."""
        hi = bisect.bisect_right(self.records, (t_read, _INF_ID))
        if not self._payload_sorted:            # exact fallback, O(n)
            newer = [t for t, p in self.records[:hi] if p > payload_id]
            return t_read - min(newer) if newer else 0.0
        # first record with a newer payload among those applied by t_read:
        # payloads are co-monotonic with apply times, so this is a bisect
        # on the same sorted list (earliest overwriter == leftmost)
        j = bisect.bisect_right(self.records, payload_id, hi=hi,
                                key=lambda r: r[1])
        if j >= hi:
            return 0.0
        return t_read - self.records[j][0]

    def latest_at(self, t: float) -> Optional[int]:
        # exact under ANY feed: max((ta, p) with ta <= t) is the last
        # record of the (t, payload)-sorted prefix
        hi = bisect.bisect_right(self.records, (t, _INF_ID))
        return self.records[hi - 1][1] if hi else None


def percentiles(xs: List[float], ps=(50, 90, 99)) -> dict:
    import numpy as np

    if not xs:
        return {p: float("nan") for p in ps}
    arr = np.asarray(xs)
    return {p: float(np.percentile(arr, p)) for p in ps}
