"""Staleness measurement (paper §4.3).

"We consider a value as stale if it has been overwritten before the client
reads it, with staleness measured as difference between current (read) time
and timestamp of the operation that changed the value."

The benchmark drives a single logical client (no clock drift, as in the
paper) writing monotonically increasing payloads; given the write log and a
read observation these helpers compute the paper's staleness statistic.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class WriteLog:
    """Ordered (t_applied, payload_id) records of one key's writes."""

    records: List[Tuple[float, int]] = dataclasses.field(default_factory=list)

    def add(self, t_applied: float, payload_id: int) -> None:
        self.records.append((t_applied, payload_id))

    def staleness_of_read(self, t_read: float, payload_id: int) -> float:
        """0.0 if the read value was the newest applied at t_read; otherwise
        t_read - t_apply(first write that overwrote it)."""
        newer = [t for t, p in self.records if p > payload_id and t <= t_read]
        if not newer:
            return 0.0
        return t_read - min(newer)

    def latest_at(self, t: float) -> Optional[int]:
        cands = [(ta, p) for ta, p in self.records if ta <= t]
        return max(cands)[1] if cands else None


def percentiles(xs: List[float], ps=(50, 90, 99)) -> dict:
    import numpy as np

    if not xs:
        return {p: float("nan") for p in ps}
    arr = np.asarray(xs)
    return {p: float(np.percentile(arr, p)) for p in ps}
