"""Central naming service (the etcd role in FReD).

Holds global configuration — keygroup -> replica set & policy, function ->
deployment set — as CONTROL state only.  Exactly like the paper: the naming
service is consulted when deploying or re-configuring, never on the data
path.  It is deliberately a plain in-process object; at real scale it would
be backed by etcd/Zookeeper, and the interface below is what the rest of the
system is allowed to depend on.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Set

from repro.analysis import lockdep
from repro.configs.base import ReplicationPolicy
from repro.core.keygroup import KeygroupSpec


@dataclasses.dataclass
class KeygroupRecord:
    spec: KeygroupSpec
    replicas: Set[str] = dataclasses.field(default_factory=set)
    config_version: int = 0


@dataclasses.dataclass
class FunctionRecord:
    name: str
    keygroups: List[str]
    deployed_to: Set[str] = dataclasses.field(default_factory=set)


class NamingService:
    """Thread-safe control-plane registry."""

    def __init__(self) -> None:
        self._lock = lockdep.make_lock("naming.lock")   # leaf: dict ops only
        self._keygroups: Dict[str, KeygroupRecord] = {}
        self._functions: Dict[str, FunctionRecord] = {}
        self._nodes: Dict[str, dict] = {}

    # -- node membership (heartbeats feed this; see runtime/health.py) -----
    def register_node(self, name: str, kind: str = "edge", **meta) -> None:
        with self._lock:
            self._nodes[name] = {"kind": kind, "alive": True,
                                 "suspect": False, **meta}

    def mark_dead(self, name: str) -> None:
        with self._lock:
            if name in self._nodes:
                self._nodes[name]["alive"] = False
                self._nodes[name]["suspect"] = False

    def mark_alive(self, name: str) -> None:
        """Re-admit a node (rejoin after crash/leave).  Callers must have
        caught the node's keygroups up FIRST (see runtime/elastic.py):
        liveness is what the router's candidate filter reads, so flipping
        it early would serve stale reads."""
        with self._lock:
            if name in self._nodes:
                self._nodes[name]["alive"] = True
                self._nodes[name]["suspect"] = False

    def mark_suspect(self, name: str) -> None:
        """Park a node SUSPECT (minority reachability view — see
        runtime/elastic.py): it stays ALIVE (replicas intact, replication
        keeps queueing to it) but drops out of the ROUTABLE set, so the
        router and the engine's reroute paths stop picking it."""
        with self._lock:
            if name in self._nodes:
                self._nodes[name]["suspect"] = True

    def clear_suspect(self, name: str) -> None:
        with self._lock:
            if name in self._nodes:
                self._nodes[name]["suspect"] = False

    def is_alive(self, name: str) -> bool:
        with self._lock:
            m = self._nodes.get(name)
            return bool(m and m["alive"])

    def is_suspect(self, name: str) -> bool:
        with self._lock:
            m = self._nodes.get(name)
            return bool(m and m.get("suspect"))

    def is_routable(self, name: str) -> bool:
        """Alive AND not suspect: eligible to receive NEW work.  Routing
        reads this; replication/liveness bookkeeping keeps reading
        ``is_alive`` (a suspect node's replicas are not torn down)."""
        with self._lock:
            m = self._nodes.get(name)
            return bool(m and m["alive"] and not m.get("suspect"))

    def alive_nodes(self) -> List[str]:
        with self._lock:
            return [n for n, m in self._nodes.items() if m["alive"]]

    def routable_nodes(self) -> List[str]:
        with self._lock:
            return [n for n, m in self._nodes.items()
                    if m["alive"] and not m.get("suspect")]

    def node_kind(self, name: str) -> str:
        return self._nodes[name]["kind"]

    # -- keygroups ----------------------------------------------------------
    def create_keygroup(self, spec: KeygroupSpec) -> KeygroupRecord:
        with self._lock:
            if spec.name in self._keygroups:
                return self._keygroups[spec.name]
            rec = KeygroupRecord(spec=spec)
            self._keygroups[spec.name] = rec
            return rec

    def keygroup(self, name: str) -> Optional[KeygroupRecord]:
        return self._keygroups.get(name)

    def add_replica(self, kg_name: str, node: str) -> KeygroupRecord:
        with self._lock:
            rec = self._keygroups[kg_name]
            if node not in rec.replicas:
                rec.replicas.add(node)
                rec.config_version += 1
            return rec

    def remove_replica(self, kg_name: str, node: str) -> None:
        with self._lock:
            rec = self._keygroups[kg_name]
            rec.replicas.discard(node)
            rec.config_version += 1

    def replicas_of(self, kg_name: str) -> Set[str]:
        rec = self._keygroups.get(kg_name)
        return set(rec.replicas) if rec else set()

    # -- functions ------------------------------------------------------------
    def register_function(self, name: str, keygroups: List[str]) -> FunctionRecord:
        with self._lock:
            rec = self._functions.get(name) or FunctionRecord(name, list(keygroups))
            self._functions[name] = rec
            return rec

    def add_deployment(self, fn_name: str, node: str) -> None:
        with self._lock:
            self._functions[fn_name].deployed_to.add(node)

    def remove_deployment(self, fn_name: str, node: str) -> None:
        with self._lock:
            rec = self._functions.get(fn_name)
            if rec is not None:
                rec.deployed_to.discard(node)

    def deployments_of(self, fn_name: str) -> Set[str]:
        rec = self._functions.get(fn_name)
        return set(rec.deployed_to) if rec else set()

    def function(self, name: str) -> Optional[FunctionRecord]:
        return self._functions.get(name)
