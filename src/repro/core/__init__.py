"""Enoki core: the paper's contribution as a composable JAX module.

Layers (DESIGN.md §3):
  versioning/crdt/store   — versioned KV arena + convergent merges
  keygroup/naming         — replication units + control plane
  replication             — anti-entropy (logical nodes & pod mesh axis)
  consistency             — client-centric session guarantees
  faas/cluster/router     — the FaaS programming model + testbed + routing
  network/staleness       — the paper's network emulation + metrics
"""
from repro.core.cluster import Cluster, InvokeResult
from repro.core.consistency import Session
from repro.core.engine import BatchedInvocationEngine, EngineStats
from repro.core.crdt import (GCounter, LWWRegister, PNCounter, gcounter_merge,
                             lww_merge, pncounter_merge, vv_merge)
from repro.core.faas import (KV, FunctionSpec, VectorCodec,
                             compile_batched_handler, enoki_function,
                             get_function, handler_read_only, registry)
from repro.core.keygroup import KeygroupSpec, TensorKeygroup
from repro.core.naming import NamingService
from repro.core.network import NetworkModel, paper_topology
from repro.core.replication import (anti_entropy_round, converge,
                                    make_pod_replicate_step,
                                    replicate_pod_axis)
from repro.core.router import Router
from repro.core.staleness import WriteLog, percentiles
from repro.core.store import (Store, kv_delete, kv_get, kv_scan, kv_set,
                              kv_set_fold, merge_stores, store_new,
                              store_select, stores_equal)
from repro.core.versioning import fnv1a

__all__ = [
    "Cluster", "InvokeResult", "Session", "BatchedInvocationEngine",
    "EngineStats", "GCounter", "LWWRegister",
    "PNCounter", "gcounter_merge", "lww_merge", "pncounter_merge", "vv_merge",
    "KV", "FunctionSpec", "VectorCodec", "compile_batched_handler",
    "enoki_function", "get_function", "handler_read_only",
    "registry", "KeygroupSpec", "TensorKeygroup", "NamingService",
    "NetworkModel", "paper_topology", "anti_entropy_round", "converge",
    "make_pod_replicate_step", "replicate_pod_axis", "Router", "WriteLog",
    "percentiles", "Store", "kv_delete", "kv_get", "kv_scan", "kv_set",
    "kv_set_fold", "merge_stores", "store_new", "store_select",
    "stores_equal", "fnv1a",
]
