"""The node-local key-value store (FReD-replica analogue).

JAX requires static shapes, so a store replica is a fixed-capacity *arena*:

    keys      (S,)    int32   FNV-1a key hashes, 0 == empty slot
    values    (S, V)  dtype   fixed-width payload rows (padded)
    lengths   (S,)    int32   actual payload length; -1 == tombstone
    versions  (S,)    int32   packed lamport versions (see versioning.py)
    vv        (N,)    int32   version vector: highest clock seen per node

All operations are pure functions (jit-friendly); the imperative ``kv.get`` /
``kv.set`` programming model of the paper's Listing 1 is recovered by the
``KV`` handle in ``faas.py`` which threads a ``Store`` through the handler.

Writes that find neither their key nor an empty slot are dropped with
``ok=False`` (arena overflow) — the FaaS layer surfaces this as an error, the
same way FReD surfaces storage-backend failures.

Thread-safety: a ``Store`` is an immutable NamedTuple of arrays, so every
function here is safe to call from any thread — "mutation" is producing a
new arena and rebinding a node's reference, which ``Cluster`` serializes
behind per-node locks (see cluster.py); snapshots handed to the replication
queues therefore never change under a concurrent reader.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.versioning import VERSION_DTYPE, pack_version
from repro.kernels.enoki_merge.kernel import enoki_merge_rows


class Store(NamedTuple):
    keys: jnp.ndarray       # (S,) int32
    values: jnp.ndarray     # (S, V)
    lengths: jnp.ndarray    # (S,) int32; -1 marks a tombstone
    versions: jnp.ndarray   # (S,) int32 packed
    vv: jnp.ndarray         # (N,) int32 version vector

    @property
    def slots(self) -> int:
        return self.keys.shape[0]

    @property
    def value_width(self) -> int:
        return self.values.shape[1]


def store_new(slots: int, value_width: int, num_nodes: int,
              dtype=jnp.float32) -> Store:
    return Store(
        keys=jnp.zeros((slots,), jnp.int32),
        values=jnp.zeros((slots, value_width), dtype),
        lengths=jnp.zeros((slots,), jnp.int32),
        versions=jnp.zeros((slots,), VERSION_DTYPE),
        vv=jnp.zeros((num_nodes,), jnp.int32),
    )


def store_select(pred, a: Store, b: Store) -> Store:
    """``pred ? a : b`` over every arena leaf (pred: scalar bool, traced ok).

    The workhorse of conditional writes (kv_set/kv_delete) and of masking
    padded requests out of batched folds (see faas.compile_batched_handler).
    """
    pred = jnp.asarray(pred)

    def sel(x, y):
        p = pred.reshape((1,) * x.ndim) if x.ndim else pred
        return jnp.where(p, x, y)

    return jax.tree.map(sel, a, b)


# ---------------------------------------------------------------------------
# Single-key ops
# ---------------------------------------------------------------------------

def _locate(store: Store, key_hash) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Canonical slot probe.  Returns ``(slot, found, ok)``:

    * ``slot``  — the matching slot when ``found``, else the first empty
      slot (the dynamic-key fallback assignment),
    * ``found`` — whether ``key_hash`` already occupies a slot (live OR
      tombstoned; occupancy, not liveness),
    * ``ok``    — False only on arena overflow (no match and no empty
      slot); callers drop the write.

    Slot-alignment contract: when a keygroup's keys were pre-assigned at
    deploy time (``store_assign_slots`` stamps each key into its
    canonical slot as a version-0 tombstone), the argmax probe lands on
    the same slot on every replica, which is the invariant the
    elementwise merge path (``merge_stores_aligned``) relies on."""
    match = store.keys == key_hash
    found = match.any()
    empty = store.keys == 0
    slot = jnp.where(found, jnp.argmax(match), jnp.argmax(empty))
    ok = found | empty.any()
    return slot, found, ok


def kv_get(store: Store, key_hash) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (value_row, length, version, found).

    Tombstone-read contract: ``_locate``'s ``found`` means the key
    occupies a slot, but the ``found`` returned HERE is liveness — a
    tombstoned key (length < 0, written by ``kv_delete`` or by the
    deploy-time slot pre-assignment) reads as absent: zero value, zero
    length, found=False.  Its version still reads through so causal
    consumers can observe the delete."""
    slot, found, _ = _locate(store, key_hash)
    live = found & (store.lengths[slot] >= 0)
    value = jnp.where(live, store.values[slot], jnp.zeros_like(store.values[slot]))
    length = jnp.where(live, store.lengths[slot], 0)
    version = jnp.where(found, store.versions[slot], 0)
    return value, length, version, live


def kv_set(store: Store, key_hash, value_row, length, clock, node_id
           ) -> Tuple[Store, jnp.ndarray, jnp.ndarray]:
    """Write (upsert).  Returns (store', new_clock, ok).

    The node's lamport clock advances past everything this replica has seen
    (max of vv) so versions from causally-later writes always dominate.
    """
    slot, _, ok = _locate(store, key_hash)
    new_clock = jnp.maximum(clock, store.vv.max()) + 1
    version = pack_version(new_clock, node_id)
    write = ok  # drop on arena overflow

    def apply(s: Store) -> Store:
        return Store(
            keys=s.keys.at[slot].set(key_hash),
            values=s.values.at[slot].set(value_row.astype(s.values.dtype)),
            lengths=s.lengths.at[slot].set(length),
            versions=s.versions.at[slot].set(version),
            vv=s.vv.at[node_id].max(new_clock),
        )

    new_store = store_select(write, apply(store), store)
    return new_store, jnp.where(write, new_clock, clock), write


def kv_delete(store: Store, key_hash, clock, node_id) -> Tuple[Store, jnp.ndarray, jnp.ndarray]:
    """Tombstone write (length = -1) so deletes replicate like updates."""
    zero = jnp.zeros((store.value_width,), store.values.dtype)
    slot, found, _ = _locate(store, key_hash)
    new_clock = jnp.maximum(clock, store.vv.max()) + 1
    version = pack_version(new_clock, node_id)

    def apply(s: Store) -> Store:
        return Store(
            keys=s.keys,
            values=s.values.at[slot].set(zero),
            lengths=s.lengths.at[slot].set(-1),
            versions=s.versions.at[slot].set(version),
            vv=s.vv.at[node_id].max(new_clock),
        )

    new_store = store_select(found, apply(store), store)
    return new_store, jnp.where(found, new_clock, clock), found


def kv_scan(store: Store, key_hashes) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Vectorised multi-get: (values (K,V), lengths (K,), found (K,))."""
    def one(h):
        v, l, _, f = kv_get(store, h)
        return v, l, f

    return jax.vmap(one)(jnp.asarray(key_hashes, jnp.int32))


def kv_set_fold(store: Store, key_hashes, rows, lengths, clock, node_id
                ) -> Tuple[Store, jnp.ndarray, jnp.ndarray]:
    """Batched upsert: the sequential fold of N ``kv_set``s as ONE traced op.

    ``jax.lax.scan`` threads (store, clock) through the writes in order, so
    per-key last-writer-wins, version stamping, and the final clock match N
    separate ``kv_set`` calls exactly — while the device sees a single
    dispatch instead of N round-trips.  Returns (store', clock', oks (B,)).
    """
    def step(carry, inp):
        s, c = carry
        h, row, ln = inp
        s2, c2, ok = kv_set(s, h, row, ln, c, node_id)
        return (s2, c2), ok

    xs = (jnp.asarray(key_hashes, jnp.int32), rows,
          jnp.asarray(lengths, jnp.int32))
    (new_store, new_clock), oks = jax.lax.scan(step, (store, clock), xs)
    return new_store, new_clock, oks


# ---------------------------------------------------------------------------
# Replica merge (the anti-entropy inner op)
# ---------------------------------------------------------------------------

def merge_stores(a: Store, b: Store) -> Store:
    """LWW merge of replica ``b`` into ``a`` (pure; commutative up to slot
    permutation, and convergent: merged *contents* are order-independent).

    1. keys present in both  -> keep the higher packed version,
    2. keys only in ``b``    -> insert into a's empty slots (rank-matched),
    3. version vectors       -> elementwise max.

    O(S^2) comparisons; S is small (<=256) for arena keygroups.  Large tensor
    keygroups use slot-aligned merges (see replication.py) or the
    ``enoki_merge`` Pallas kernel instead.
    """
    S = a.slots
    b_live = b.keys != 0
    # --- 1. matched keys -------------------------------------------------
    match = (a.keys[:, None] == b.keys[None, :]) & b_live[None, :]   # (Sa, Sb)
    a_has_match = match.any(axis=1)
    b_idx = jnp.argmax(match, axis=1)                                 # (Sa,)
    b_versions = b.versions[b_idx]
    take_b = a_has_match & (b_versions > a.versions)

    def sel(av, bv):
        mask = take_b.reshape(take_b.shape + (1,) * (av.ndim - 1))
        return jnp.where(mask, bv[b_idx], av)

    keys = jnp.where(take_b, b.keys[b_idx], a.keys)
    values = sel(a.values, b.values)
    lengths = jnp.where(take_b, b.lengths[b_idx], a.lengths)
    versions = jnp.where(take_b, b_versions, a.versions)

    # --- 2. b-only keys -> empty slots of a -------------------------------
    b_matched = match.any(axis=0)                                     # (Sb,)
    b_new = b_live & ~b_matched
    empty = keys == 0
    # rank-match: the i-th new b key goes to the i-th empty a slot
    empty_rank = jnp.cumsum(empty) - 1                                # (Sa,)
    new_rank = jnp.cumsum(b_new) - 1                                  # (Sb,)
    num_empty = empty.sum()
    # for each a slot: which new b key lands here (if any)?
    lands = (empty[:, None] & b_new[None, :]
             & (empty_rank[:, None] == new_rank[None, :]))            # (Sa, Sb)
    has_insert = lands.any(axis=1)
    src = jnp.argmax(lands, axis=1)
    # respect capacity: ranks beyond num_empty simply find no empty slot (mask
    # already guarantees that since empty_rank < num_empty on empty slots).
    del num_empty

    def ins(cur, bv):
        mask = has_insert.reshape(has_insert.shape + (1,) * (cur.ndim - 1))
        return jnp.where(mask, bv[src], cur)

    keys = jnp.where(has_insert, b.keys[src], keys)
    values = ins(values, b.values)
    lengths = jnp.where(has_insert, b.lengths[src], lengths)
    versions = jnp.where(has_insert, b.versions[src], versions)

    # --- 3. version vectors ------------------------------------------------
    vv = jnp.maximum(a.vv, b.vv)
    return Store(keys=keys, values=values, lengths=lengths,
                 versions=versions, vv=vv)


# one fused dispatch per merge instead of ~40 eager op dispatches (the
# delivery profile is dominated by merges under replicated workloads).
# jit's cache is keyed by arena shape, so every keygroup geometry
# compiles once and is shared by all nodes/threads.  This is the
# FALLBACK path — slot-aligned keygroups take merge_stores_aligned /
# merge_snapshots_fused below.
merge_stores_jit = jax.jit(merge_stores)


# ---------------------------------------------------------------------------
# Device-resident merge path: slot-aligned arenas + fused multi-way merge
# ---------------------------------------------------------------------------

def donation_enabled() -> bool:
    """Whether jit buffer donation is real on this backend.

    XLA honours ``donate_argnums`` on TPU/GPU and silently ignores it on
    CPU, so the serving stack only pays for the defensive snapshot clones
    donation requires (queued snapshots must never alias a donated live
    arena — see cluster._schedule_replication) where donation actually
    reuses buffers."""
    return jax.default_backend() in ("tpu", "gpu")


def donate_store_argnums() -> tuple:
    """``donate_argnums`` for entry points whose argument 0 is the arena
    being folded/merged into (see faas.compile_batched_handler and
    merge_many_fn)."""
    return (0,) if donation_enabled() else ()


@jax.jit
def arena_clone(store: Store) -> Store:
    """Deep-copy an arena into fresh device buffers.

    Snapshot hygiene for donation: anything pushed into a delivery queue
    or shared across nodes must be a clone, never a live reference to an
    arena a later fold/merge may donate."""
    return jax.tree.map(jnp.copy, store)


def _merge_rows_tile(slots: int) -> int:
    # largest divisor of the arena size <= 256: enoki_merge_rows requires
    # the tile to divide the row count exactly
    for tile in range(min(256, slots), 0, -1):
        if slots % tile == 0:
            return tile
    return 1


def merge_stores_aligned(a: Store, b: Store) -> Store:
    """Elementwise LWW merge for SLOT-ALIGNED replicas.

    Precondition: ``a.keys == b.keys`` slot for slot (deploy-time key
    pre-assignment, see ``store_assign_slots``).  Matching then costs
    nothing — each slot is its own match — and the merge degenerates to
    the per-row versioned select the ``enoki_merge_rows`` Pallas kernel
    implements: O(S·V) instead of ``merge_stores``'s O(S²) probe.  Runs
    the real kernel on TPU and interpret mode elsewhere.

    Bit-compatible with ``merge_stores`` on aligned arenas: strictly
    greater version takes ``b``'s row (ties keep ``a``), version vectors
    max elementwise.  Keys follow the winning row so a dynamic key that
    ``b`` wrote into a still-empty canonical slot inserts correctly; what
    this path canNOT express is two replicas claiming the same empty slot
    for DIFFERENT novel keys — impossible for deployed handlers (their
    key sets are pre-assigned), which is why alignment is tracked per
    keygroup and anything else takes the ``merge_stores`` fallback.
    """
    take_b = b.versions > a.versions
    values, versions = enoki_merge_rows(
        a.values, a.versions, b.values, b.versions,
        rows_tile=_merge_rows_tile(a.slots),
        interpret=jax.default_backend() != "tpu")
    return Store(
        keys=jnp.where(take_b, b.keys, a.keys),
        values=values,
        lengths=jnp.where(take_b, b.lengths, a.lengths),
        versions=versions,
        vv=jnp.maximum(a.vv, b.vv),
    )


@functools.lru_cache(maxsize=None)
def merge_many_fn(aligned: bool):
    """Jitted K-way merge: fold a tuple of snapshots into an accumulator
    arena with ONE device dispatch (``lax.scan`` over the stacked
    snapshots).  jit's cache keys on the pytree structure, so each
    (aligned, K, geometry) combination traces once.  The accumulator is
    donated on backends where donation is real."""
    body = merge_stores_aligned if aligned else merge_stores

    def many(acc: Store, snaps) -> Store:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *snaps)
        out, _ = jax.lax.scan(lambda s, snap: (body(s, snap), None),
                              acc, stacked)
        return out

    return jax.jit(many, donate_argnums=donate_store_argnums())


# K is padded up to a small bucket set so warm delivery never sees a new
# pytree structure (a new K would retrace); beyond the largest bucket the
# exact K runs — still one dispatch, just a fresh trace.
SNAPSHOT_K_BUCKETS = (1, 2, 4, 8, 16, 32)


def merge_snapshots_fused(acc: Store, snaps: Sequence[Store], *,
                          aligned: bool) -> Store:
    """Merge K queued snapshots into ``acc``, in order, as ONE dispatch.

    Order-preserving: identical to folding ``merge_stores`` (or the
    aligned variant) left to right, which is what the sequential
    delivery loop used to do — so (arrival, seq) LWW semantics are
    bit-identical.  K is padded to the next ``SNAPSHOT_K_BUCKETS`` entry
    by repeating the LAST snapshot: LWW merge is idempotent (matched
    rows need a strictly greater version to win, vv max is idempotent),
    so the repeats are no-ops.
    """
    snaps = tuple(snaps)
    if not snaps:
        return acc
    for k in SNAPSHOT_K_BUCKETS:
        if k >= len(snaps):
            snaps = snaps + (snaps[-1],) * (k - len(snaps))
            break
    return merge_many_fn(bool(aligned))(acc, snaps)


def store_assign_slots(store: Store, assignments: Dict[int, int]
                       ) -> Tuple[Store, bool]:
    """Stamp a deploy-time key→slot layout into an arena (host-side).

    Each key hash is written into its canonical slot as a version-0
    tombstone (length -1, zero payload): reads still see it as absent,
    ``merge_stores`` treats it exactly like any occupied slot, and
    ``_locate``'s argmax probe now lands on the same slot on every
    replica that received the same layout — which is what makes the
    elementwise ``merge_stores_aligned`` path valid.

    Returns ``(store', ok)``.  ``ok`` is False when the layout cannot be
    applied — a slot already holds a DIFFERENT key, or the hash already
    lives in some other slot (dynamic writes beat the assignment): the
    caller must mark the keygroup unaligned and keep the O(S²) fallback.
    """
    keys = np.array(jax.device_get(store.keys))
    lengths = np.array(jax.device_get(store.lengths))
    occupied = {int(k): i for i, k in enumerate(keys) if k != 0}
    changed = False
    for h, slot in assignments.items():
        h = int(h)
        cur = int(keys[slot])
        if cur == h:
            continue
        if cur != 0 or h in occupied:
            return store, False
        keys[slot] = h
        lengths[slot] = -1
        occupied[h] = slot
        changed = True
    if not changed:
        return store, True
    return store._replace(keys=jnp.asarray(keys),
                          lengths=jnp.asarray(lengths)), True


def store_contents(store: Store) -> dict:
    """Host-side canonical view {key_hash: (version, length, value)} for tests."""
    out = {}
    # one transfer for the whole arena instead of four
    keys, values, lengths, versions, _ = jax.device_get(store)
    for i, k in enumerate(keys):
        if k != 0:
            out[int(k)] = (int(versions[i]), int(lengths[i]),
                           values[i].tolist())
    return out


def stores_equal(a: Store, b: Store) -> bool:
    """Exact equality of two arenas as REPLICAS: same live contents, same
    versions, same version vector — slot layout ignored (merge order may
    permute slots without changing what any read observes).  The
    determinism checks of the parallel pump compare stores with this."""
    va, vb = jax.device_get(a.vv), jax.device_get(b.vv)
    return bool((va == vb).all()) and store_contents(a) == store_contents(b)
