"""Batched invocation engine — coalescing concurrent FaaS requests.

The paper's throughput evaluation (§4.2) is bounded by per-invocation
overhead, not compute: ``Cluster.invoke`` pays a full Python round-trip and
a fresh device dispatch per request.  This engine coalesces concurrent
invocations of the same ``(function, node)`` pair into ONE device dispatch
of the deploy-time-compiled batched handler (``faas.compile_batched_handler``):
a ``jax.lax.scan`` folds the store through the requests in order (read-only
handlers take a ``jax.vmap`` instead), so per-key last-writer-wins semantics,
version stamping, and the final vector clock match N sequential ``invoke``
calls exactly.

The emulated network stays PER-REQUEST: each request keeps its own
``t_send``/arrival/response timeline, the same client→node link charges, and
the same per-op round-trip charges for remote placements — only the compute
dispatch is shared.  Timing semantics vs N sequential invokes:

* replication deliveries are folded in up to the LATEST arrival in the
  batch (a coalesced batch executes once its last member has arrived);
* asynchronous replication of a written keygroup is scheduled ONCE, with
  the post-batch snapshot, at the last writer's apply time — peers converge
  to the same contents as N per-invoke snapshots (LWW), with N× fewer
  replication messages and bytes (coalesced anti-entropy);
* downstream calls fire after each chunk's main dispatch (chunks cap at
  the largest bucket) and are themselves batched per callee.

Two APIs:

* ``engine.dispatch(fn, node, xs, t_sends, ...)`` — explicit batch, results
  in request order (what ``Cluster.invoke_batch`` delegates to);
* ``engine.submit(...)`` / ``engine.flush()`` — enqueue requests one at a
  time from independent callers; ``flush`` groups them by
  ``(function, node, client)`` and dispatches each group as one batch,
  returning results in submission order.

Batches are padded up to bucket sizes (default 1/8/64/256) so jit traces a
bounded set of shapes; padded slots are masked out of the fold and oversize
batches are folded chunk-by-chunk at the largest bucket.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BUCKETS = (1, 8, 64, 256)
MAX_CALL_DEPTH = 32     # downstream-chain guard (cycles in calls/async_calls)


@dataclasses.dataclass
class _Pending:
    ticket: int
    fn: str
    node: str
    x: Any
    t_send: float
    client: str
    payload_bytes: int


class BatchedInvocationEngine:
    def __init__(self, cluster, bucket_sizes: Sequence[int] = DEFAULT_BUCKETS):
        self.cluster = cluster
        self.buckets = tuple(sorted(set(int(b) for b in bucket_sizes)))
        self._queue: List[_Pending] = []
        self._tickets = 0
        # results of groups that dispatched before a later group's dispatch
        # raised mid-flush; delivered by the next flush()
        self._undelivered: Dict[int, Any] = {}

    # ------------------------------------------------------------- coalescing
    def submit(self, fn: str, node: str, x, t_send: float = 0.0,
               client: str = "client", payload_bytes: int = 64) -> int:
        """Enqueue one invocation; returns a ticket redeemed by ``flush``."""
        t = self._tickets
        self._tickets += 1
        self._queue.append(_Pending(t, fn, node, x, t_send, client,
                                    payload_bytes))
        return t

    def flush(self) -> Dict[int, Any]:
        """Dispatch everything queued, one batch per (fn, node, client,
        payload) group, and return {ticket: InvokeResult}.

        Coalescing is per group: submission order is preserved WITHIN a
        group, but one group's whole batch executes before the next — so
        requests of *different* functions sharing a keygroup may observe
        each other's writes in group order rather than submission order
        (the usual trade of a coalescing server).  Callers needing strict
        cross-function ordering should flush between submissions.

        The queue is validated BEFORE anything dispatches: an undeployed
        function/node raises KeyError with the whole queue left intact (no
        partial side effects, no lost tickets).  If a dispatch itself then
        raises mid-flush: the FAILING group is dropped, not requeued — its
        store effects may already have committed (e.g. a later chunk or an
        undeployed downstream callee failed), so re-running it would apply
        writes twice; at-most-once is the contract for a failing group.
        Every not-yet-dispatched group goes back on the queue, and results
        of groups that already dispatched cleanly are retained and returned
        by the NEXT flush."""
        for p in self._queue:
            nd = self.cluster.nodes.get(p.node)
            if (p.fn not in self.cluster.specs or nd is None
                    or p.fn not in nd.batched_handlers):
                raise KeyError(
                    f"cannot flush: function {p.fn!r} is not deployed at "
                    f"node {p.node!r} (queue left intact)")
        groups: Dict[Tuple, List[_Pending]] = {}
        for p in self._queue:
            groups.setdefault((p.fn, p.node, p.client, p.payload_bytes),
                              []).append(p)
        self._queue = []
        out: Dict[int, Any] = dict(self._undelivered)
        self._undelivered = {}
        items = list(groups.items())
        for gi, ((fn, node, client, payload), ps) in enumerate(items):
            try:
                results = self.dispatch(fn, node, [p.x for p in ps],
                                        [p.t_send for p in ps], client=client,
                                        payload_bytes=payload)
            except Exception:
                # requeue only groups that never dispatched; the failing
                # group's effects may have partially committed (at-most-once)
                for _, rest in items[gi + 1:]:
                    self._queue.extend(rest)
                self._undelivered = out
                raise
            for p, r in zip(ps, results):
                out[p.ticket] = r
        return out

    # --------------------------------------------------------------- dispatch
    def dispatch(self, fn_name: str, node: str, xs: Sequence,
                 t_sends: Optional[Sequence[float]] = None,
                 client: str = "client", payload_bytes: int = 64,
                 _depth: int = 0) -> List[Any]:
        """Invoke ``fn_name`` at ``node`` for every input in ``xs`` with one
        device dispatch per chunk.  Returns per-request InvokeResults in
        input order."""
        n = len(xs)
        if t_sends is None:
            t_sends = [0.0] * n
        if len(t_sends) != n:
            raise ValueError(f"{n} inputs but {len(t_sends)} send times")
        cap = self.buckets[-1]
        results: List[Any] = []
        for lo in range(0, n, cap):
            results.extend(self._dispatch_chunk(
                fn_name, node, xs[lo:lo + cap], t_sends[lo:lo + cap],
                client, payload_bytes, _depth))
        return results

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return n  # chunking caps n at the largest bucket already

    def _dispatch_chunk(self, fn_name: str, node: str, xs, t_sends,
                        client: str, payload_bytes: int, depth: int):
        from repro.core.cluster import InvokeResult
        from repro.core.keygroup import KeygroupSpec, arena_new
        from repro.core.versioning import MAX_NODES

        if depth > MAX_CALL_DEPTH:
            raise RecursionError(
                f"downstream call chain exceeded {MAX_CALL_DEPTH} levels at "
                f"{fn_name!r} — cycle in calls/async_calls?")
        c = self.cluster
        spec = c.specs[fn_name]
        nd = c.nodes[node]
        bhandler = nd.batched_handlers[fn_name]
        n = len(xs)

        link = c.net.link(client, node)
        hop_ms = c.net.one_way_ms(client, node) + link.transfer_ms(payload_bytes)
        t_arrives = [t + hop_ms for t in t_sends]

        kg, store_node, per_op_ms = c._resolve_placement(spec, node)
        if kg is not None:
            # a coalesced batch executes once its last member has arrived
            c._deliver_until(store_node, max(t_arrives))
            snd = c.nodes[store_node]
            store, clock = snd.stores[kg], snd.clock
        else:
            snd = None
            store = arena_new(KeygroupSpec(name="_tmp",
                                           value_width=spec.codec_width),
                              MAX_NODES)
            clock = nd.clock

        # pad to the bucket and run the one batched dispatch (host-side
        # numpy staging: jnp.stack over per-request device arrays costs more
        # than the dispatch itself).  Stacking is per pytree leaf so tuple/
        # dict handler inputs keep their structure, exactly as with invoke.
        bucket = self._bucket(n)
        xs_host = jax.tree.map(
            lambda *leaves: np.stack([np.asarray(l) for l in leaves]), *xs)
        if bucket > n:
            xs_host = jax.tree.map(
                lambda a: np.concatenate(
                    [a, np.repeat(a[:1], bucket - n, axis=0)]), xs_host)
        valid = np.arange(bucket) < n
        new_store, new_clock, ys, ops = bhandler(
            store, clock, jax.tree.map(jnp.asarray, xs_host),
            jnp.asarray(valid), independent=(kg is None))
        if kg is not None:
            snd.stores[kg] = new_store
            snd.clock = new_clock

        # per-request timeline: identical charges to Cluster.invoke
        compute = nd.compute_ms.get(fn_name, 0.0)
        op_net = c._op_network_ms(node, store_node, per_op_ms, ops)
        t_applieds = [t + compute + op_net for t in t_arrives]

        wrote = any(k in ("set", "delete") for k, _ in ops)
        if kg is not None and wrote:
            # ONE coalesced snapshot at the last writer's apply time
            c._schedule_replication(kg, store_node, max(t_applieds))

        # one transfer for the whole batch, then host-side row views
        ys_host = jax.tree.map(np.asarray, jax.device_get(ys))
        outputs = [jax.tree.map(lambda a: a[i], ys_host) for i in range(n)]
        chains = [[fn_name] for _ in range(n)]
        t_downs = list(t_applieds)

        # downstream fan-out, batched per callee (same gating as invoke's
        # _route_downstream; async calls always fire)
        if spec.calls or spec.async_calls:
            from repro.core.cluster import fires_sync_downstream
            fires = [fires_sync_downstream(y) for y in outputs]
            for callee in spec.calls:
                idxs = [i for i in range(n) if fires[i]]
                if not idxs:
                    continue
                target = c._nearest_deployment(callee, node)
                subs = self.dispatch(callee, target,
                                     [outputs[i] for i in idxs],
                                     [t_downs[i] for i in idxs], client=node,
                                     payload_bytes=payload_bytes,
                                     _depth=depth + 1)
                for i, sub in zip(idxs, subs):
                    chains[i].extend(sub.chain)
                    t_downs[i] = sub.t_received
            for callee in spec.async_calls:
                target = c._nearest_deployment(callee, node)
                subs = self.dispatch(callee, target, outputs, list(t_downs),
                                     client=node, payload_bytes=payload_bytes,
                                     _depth=depth + 1)
                for i, sub in zip(range(n), subs):
                    chains[i].extend(sub.chain)

        results = []
        for i in range(n):
            t_done = max(t_applieds[i], t_downs[i])
            t_received = t_done + hop_ms
            results.append(InvokeResult(
                output=outputs[i], response_ms=t_received - t_sends[i],
                t_sent=t_sends[i], t_received=t_received,
                t_applied=t_applieds[i], kv_ops=list(ops), node=node,
                chain=chains[i]))
        return results
