"""Batched invocation engine — coalescing concurrent FaaS requests.

The paper's throughput evaluation (§4.2) is bounded by per-invocation
overhead, not compute: ``Cluster.invoke`` pays a full Python round-trip and
a fresh device dispatch per request.  This engine coalesces concurrent
invocations of the same ``(function, node)`` pair into ONE device dispatch
of the deploy-time-compiled batched handler (``faas.compile_batched_handler``):
a ``jax.lax.scan`` folds the store through the requests in order (read-only
handlers take a ``jax.vmap`` instead), so per-key last-writer-wins semantics,
version stamping, and the final vector clock match N sequential ``invoke``
calls exactly.

The emulated network stays PER-REQUEST: each request keeps its own
``t_send``/arrival/response timeline, the same client→node link charges, and
the same per-op round-trip charges for remote placements — only the compute
dispatch is shared.

Three APIs:

* ``engine.dispatch(fn, node, xs, t_sends, ...)`` — explicit batch, results
  in request order (what ``Cluster.invoke_batch`` delegates to);
* ``engine.submit(...)`` / ``engine.flush()`` — enqueue requests one at a
  time from independent callers; ``flush`` drains everything queued in ONE
  flush cycle and returns results keyed by ticket;
* ``engine.submit(...)`` / ``engine.pump(until_t)`` with ``window_ms`` set —
  the background-flusher model: each ``(function, node, client)`` group
  accumulates into an arrival-time WINDOW that closes ``window_ms`` of
  virtual time after its first request arrives (or immediately, when it
  fills to ``max_batch`` — full buckets flush early); ``pump(until_t)``
  drains every window whose deadline has passed.  A request therefore never
  waits past ``window_ms``, and requests flushed at the deadline are charged
  the wait (their ``t_applied`` anchors at the window close, the batched
  analogue of a real coalescing server's arrival-time batching).  A wall-
  clock driver plugs a virtual-time source with ``use_clock`` (``pump()``
  then advances to the clock's current instant) and sleeps until
  ``next_deadline()`` instead of polling — see ``launch/faas_server.py``.

A flush cycle dispatches its per-``(fn, node)`` groups as INDEPENDENT
PARALLEL TIMELINES (§4.3's multi-node picture):

* replication deliveries fold in up to a shared high-water mark per store
  node — the latest arrival any group of the cycle brings to that node —
  before any group executes, so groups never observe a half-delivered peer;
* writes of the cycle schedule ONE coalesced replication snapshot per
  written keygroup per store node (post-cycle contents, latest apply time),
  instead of one snapshot per group;
* groups of the same cycle do NOT see each other's same-cycle writes via
  replication (parallel timelines): cross-group visibility starts at the
  next cycle, exactly like concurrent batches on distinct real nodes;
* downstream calls coalesce ACROSS caller chunks: every caller chunk of the
  cycle that fires the same ``(callee, target node)`` from the same CALLER
  NODE contributes its requests to one merged batch per wave (callers on
  different nodes keep separate batches — they pay different hops), so a
  fan-in callee (fig 8) is dispatched once per caller node per cycle
  instead of once per caller function/chunk.

Batches are padded up to bucket sizes (default 1/8/64/256) so jit traces a
bounded set of shapes; padded slots are masked out of the fold and oversize
batches are folded chunk-by-chunk at the largest bucket.

Failure contract (at-most-once): the queue (all windows for ``flush``, due
windows for ``pump``) is validated BEFORE anything dispatches — an
undeployed function/node raises KeyError with every window left intact.  If
a dispatch itself raises mid-cycle, the FAILING group is dropped, not
requeued — its store effects may already have committed; windows that never
started dispatching go back on the queue (serial pump; under the parallel
pump every group of the cycle has already started, so clean groups complete
and failing ones drop), and results of groups that completed cleanly are
retained and returned by the NEXT ``flush``/``pump``.
``discard(ticket)``/``pending()`` are the public queue-surgery API for
recovering from a poisoned request (see docs/batched_engine.md).

Concurrency (the per-frame dataflow scheduler): a flush cycle no longer
barriers per downstream wave.  Every unit of dispatch work — a top-level
window's group or a merged downstream batch — is sealed as a TASK with a
global seal sequence number and executed on its store node's LANE (the
per-store-node single-worker executors of ``use_workers(n)``).  The
readiness rule is per frame: a frame dispatches the moment (a) its input
batch is sealed and (b) its store node's prior fold has committed — lane
FIFO in seal order IS the fold clock, so a straggling store node delays
only the frames that fold into it while every other lane keeps flowing.
Downstream COMPOSITION stays wave-synchronized (which requests merge into
which batch is decided from all frames that can still fire a call — the
determinism contract: ``workers=4`` produces the identical ticket→result
map as ``workers=1``), but leaf frames — no ``calls``/``async_calls`` and
no ancestor that can still pop a callee — never gate composition: their
lanes stream to completion independently, and each top-level window's
results are handed to ``on_ready`` the moment its last frame finalizes
(mid-cycle incremental delivery; ``wave_barrier=True`` restores the old
everything-at-cycle-end behaviour for A/B comparison).  Replication
snapshots still coalesce in a serial merge after the last task commits.
Two engine locks keep ``submit`` (the client hot path) off the dispatch
path: ``_qlock`` guards the window queue/tickets/ready-results and is only
ever held for host-side bookkeeping; ``_cycle_lock`` serializes whole
flush cycles (JAX dispatches run under it, never under ``_qlock``).  See
the "Concurrency contract" section of docs/batched_engine.md for the full
lock hierarchy.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import math
import queue
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import lockdep

DEFAULT_BUCKETS = (1, 8, 64, 256)


@functools.lru_cache(maxsize=None)
def _valid_mask(bucket: int, n: int) -> jnp.ndarray:
    """Device-resident bucket-padding mask for ``n`` valid requests in a
    ``bucket``-sized chunk, cached process-wide: warm chunks stop
    allocating and transferring a fresh (bucket,) bool array per dispatch
    (shape pinning — jit's cache keys on the shape, the VALUES here are a
    bounded set too)."""
    return jnp.asarray(np.arange(bucket) < n)
MAX_CALL_DEPTH = 32     # downstream-chain guard (cycles in calls/async_calls)
MIN_PARALLEL_REQUESTS = 64      # cycles smaller than this run inline even
                                # with workers set: executor handoff adds
                                # latency a small latency-sensitive cycle
                                # (a serving loop's) cannot amortize —
                                # measured on the reference host, cycles
                                # of ~32 requests lose to inline; the
                                # win shows from ~hundreds of requests
                                # per cycle across >=2 store nodes


@dataclasses.dataclass(eq=False)        # identity semantics: ps hold arrays
class _Pending:
    ticket: int
    fn: str
    node: str
    x: Any
    t_send: float
    t_arrive: float
    client: str
    payload_bytes: int
    # reroute accounting is per-request-TERMINAL: however many times this
    # request moves off dead nodes (eviction sweeps, dispatch-time liveness
    # rechecks), it bumps ``stats.reroutes`` at most once
    rerouted: bool = False


@dataclasses.dataclass(eq=False)        # identity semantics for in/remove
class _Window:
    """One open arrival-time window of a (fn, node, client, payload) group."""
    key: Tuple[str, str, str, int]
    deadline: float                 # inf when window_ms is None
    ps: List[_Pending] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Cycle:
    """Per-flush-cycle shared state (parallel-timeline bookkeeping).

    ``hwm`` is written only by the serial collect stage and read by the
    (possibly parallel) exec stage; ``repl`` is written by concurrent group
    executions, so its updates go through ``lock`` — the merged value is a
    max, so the outcome is order-independent."""
    hwm: Dict[str, float] = dataclasses.field(default_factory=dict)
    # (kg, store_node) -> latest apply time of a write this cycle
    repl: Dict[Tuple[str, str], float] = dataclasses.field(default_factory=dict)
    lock: threading.Lock = dataclasses.field(
        default_factory=lambda: lockdep.make_lock("engine.cycle_state_lock"),
        repr=False)


@dataclasses.dataclass
class _Frame:
    """One dispatched chunk-batch inside a cycle, plus its downstream state.

    ``chains``/``t_downs`` mutate as subframes finalize; ``results`` is set
    once the frame itself finalizes (todo drained, no outstanding slots).
    """
    fn: str
    node: str
    client: str
    payload_bytes: int
    depth: int
    t_sends: List[float]
    hop_ms: float
    outputs: List[Any]
    t_applieds: List[float]
    chains: List[List[str]]
    t_downs: List[float]
    ops: List[Tuple[str, int]]
    todo: List[Tuple[str, bool]]                    # remaining (callee, async)
    fires: List[bool]                               # sync-downstream gate
    parents: List[Optional[Tuple["_Frame", int, bool]]]
    outstanding: int = 0
    results: Optional[List[Any]] = None

    @property
    def n(self) -> int:
        return len(self.t_sends)


@dataclasses.dataclass(eq=False)
class _Task:
    """One sealed unit of dispatch work on a store-key lane: a top-level
    window's group, or one merged downstream batch.  ``seq`` is the global
    seal sequence — every lane executes its tasks in ``seq`` order (the
    lane executors are single-worker, so submission order is FIFO), which
    is the per-frame readiness rule's fold clock: a task runs only after
    its store node's prior fold committed.  ``relevant`` marks tasks whose
    frames can still change downstream COMPOSITION (they have callees to
    pop, or an ancestor does) — only those gate the next wave's batch
    merge; leaf tasks stream to completion independently."""
    seq: int
    store_key: str
    args: tuple                     # _exec_group(*args)
    window: Optional[_Window]       # top-level origin (None for downstream)
    relevant: bool
    frames: Optional[List[_Frame]] = None
    error: Optional[BaseException] = None


@dataclasses.dataclass
class AtomicStats:
    """Base for stats dataclasses whose counters are bumped from multiple
    threads (parallel pump workers, client submit threads, the serving
    loop).  ``inc`` is the one mutation path — a plain ``+=`` is a
    read-modify-write race under the executor pump and silently loses
    counts (``lockcheck`` flags raw increments).  The lock is a leaf in
    ``repro.analysis.lock_order``: nothing else is ever acquired while
    holding it."""
    _lock: threading.Lock = dataclasses.field(
        default_factory=lambda: lockdep.make_lock("stats.lock"),
        repr=False, compare=False)

    def inc(self, name: str, n: int = 1) -> int:
        with self._lock:
            v = getattr(self, name) + n
            setattr(self, name, v)
            return v


@dataclasses.dataclass
class EngineStats(AtomicStats):
    submitted: int = 0
    cycles: int = 0
    windows_flushed: int = 0
    requests_flushed: int = 0
    auto_flushes: int = 0           # windows that filled to max_batch
    deadline_flushes: int = 0       # windows drained by pump at their deadline
    dispatches: int = 0             # device-level chunk dispatches (all waves)
    downstream_coalesced: int = 0   # downstream requests that rode a batch
                                    # merged across >1 caller frame
    replication_coalesced: int = 0  # per-group snapshots saved by cycle
                                    # coalescing
    reroutes: int = 0               # requests moved off a dead node to a
                                    # surviving deployment (queued windows
                                    # at eviction + frames at dispatch);
                                    # counted at most ONCE per request, no
                                    # matter how many times it moves
    dropped_dead: int = 0           # requests dropped because NO live
                                    # deployment remained (fail-fast under
                                    # the at-most-once contract)


class _NodePool:
    """The parallel pump's executor pool: ONE single-worker executor per
    store node, shared across cycles.  Same-store-node groups land on the
    same worker in submission order, so every per-store fold keeps the
    exact order the serial pump would use — which is what makes the
    parallel pump's ticket→result map identical to the serial one.  At
    most ``workers`` distinct executors exist; store nodes beyond that
    share them round-robin by first touch (deterministic given the
    engine's deterministic submission order)."""

    def __init__(self, workers: int):
        self.workers = max(1, int(workers))
        self._execs: List[ThreadPoolExecutor] = []
        self._slot: Dict[str, int] = {}
        self._lock = lockdep.make_lock("engine.pool_lock")

    def submit(self, node: str, fn, *args):
        with self._lock:
            i = self._slot.get(node)
            if i is None:
                i = self._slot[node] = len(self._slot) % self.workers
            if i >= len(self._execs):
                self._execs.append(ThreadPoolExecutor(
                    max_workers=1,
                    thread_name_prefix=f"engine-pump-{i}"))
            ex = self._execs[i]
        return ex.submit(fn, *args)

    def shutdown(self) -> None:
        with self._lock:
            execs, self._execs = self._execs, []
            self._slot.clear()
        for ex in execs:
            ex.shutdown(wait=True)


class BatchedInvocationEngine:
    def __init__(self, cluster, bucket_sizes: Sequence[int] = DEFAULT_BUCKETS,
                 window_ms: Optional[float] = None,
                 max_batch: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 workers: Optional[int] = None):
        self.cluster = cluster
        self.buckets = tuple(sorted(set(int(b) for b in bucket_sizes)))
        self.window_ms = window_ms
        self.max_batch = max_batch
        self.clock = clock
        self.workers = workers
        self.stats = EngineStats()
        self._windows: List[_Window] = []
        self._tickets = 0
        # results awaiting pickup: auto-flushed windows, plus groups that
        # dispatched cleanly before a later group raised mid-cycle
        self._ready: Dict[int, Any] = {}
        # the network model is static, so the client->node hop of a
        # (client, node, payload) triple is a constant: cache it (submit is
        # the per-request hot path of the background flusher)
        self._hops: Dict[Tuple[str, str, int], float] = {}
        # lock order: declared in repro/analysis/lock_order.py (the single
        # source both checkers and docs/batched_engine.md read).  _qlock
        # guards the queue state (_windows/_tickets/_ready) and is never
        # held across a dispatch; _cycle_lock serializes flush cycles
        # (all device dispatches) and nests _qlock/node locks inside it
        self._qlock = lockdep.make_rlock("engine.qlock")
        self._cycle_lock = lockdep.make_rlock("engine.cycle_lock")
        self._pool: Optional[_NodePool] = None
        # persistent host staging buffers for chunk stacking, keyed
        # (bucket, leaf index, leaf shape, dtype) and THREAD-LOCAL: the
        # parallel pump's lanes never share one, and a buffer is free for
        # reuse the moment its chunk dispatched (jnp.asarray copies host
        # memory into a fresh device buffer).  Warm cycles therefore make
        # zero fresh staging allocations (see tests/test_perf_paths.py)
        self._staging = threading.local()
        # cycles below this many requests run inline even with workers
        # set (handoff latency vs throughput trade); tests override it to
        # force the pool path on small streams
        self.min_parallel_requests = MIN_PARALLEL_REQUESTS
        # incremental delivery hook: called from the cycle coordinator (the
        # pump caller's thread, under _cycle_lock) with {ticket: result}
        # the moment a top-level window's last frame finalizes — delivered
        # tickets are EXCLUDED from the pump/flush return.  None keeps the
        # classic collect-everything-then-return behaviour.  The callback
        # may take locks BELOW _cycle_lock in the documented hierarchy
        # (router lock, server cond) but must never re-enter the engine's
        # flush path
        self.on_ready: Optional[Callable[[Dict[int, Any]], None]] = None
        # compat knob for A/B benchmarks: True restores the old wave
        # barrier's observable timing — every composition waits on every
        # task of the prior wave and nothing is delivered before the
        # cycle's end (values are identical either way)
        self.wave_barrier = False
        # debug/property-test hook: record (store_key, seal_seq) at the
        # moment each task starts executing, so tests can assert that
        # dispatch order respects per-store-node fold (seal) order
        self.trace_folds = False
        self.fold_trace: List[Tuple[str, int]] = []
        self._trace_lock = lockdep.make_lock("engine.trace_lock")

    def _hop_ms(self, client: str, node: str, payload_bytes: int) -> float:
        key = (client, node, payload_bytes)
        hop = self._hops.get(key)
        if hop is None:
            link = self.cluster.net.link(client, node)
            hop = (self.cluster.net.one_way_ms(client, node)
                   + link.transfer_ms(payload_bytes))
            self._hops[key] = hop
        return hop

    def configure(self, window_ms: Optional[float] = None,
                  max_batch: Optional[int] = None) -> "BatchedInvocationEngine":
        """Set the background-flusher knobs (chainable).  ``window_ms`` is
        the arrival-time window in virtual ms; ``max_batch`` caps a window
        and triggers flush-on-full."""
        if window_ms is not None and window_ms < 0:
            raise ValueError("window_ms must be >= 0")
        if max_batch is not None and max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.window_ms = window_ms
        self.max_batch = max_batch
        return self

    # ---------------------------------------------------------------- workers
    def use_workers(self, workers: Optional[int]) -> "BatchedInvocationEngine":
        """Set the parallel-pump width (chainable).  ``workers`` caps the
        number of per-store-node executors a flush cycle's exec stage may
        use; ``None``/``1`` keeps the serial in-line pump.  Changing the
        width never changes results (the determinism contract) — only how
        many independent store nodes dispatch concurrently."""
        if workers is not None and workers < 1:
            raise ValueError("workers must be >= 1")
        # _cycle_lock first: a flush cycle mid-dispatch on another thread
        # must never have its pool shut down under it
        with self._cycle_lock:
            stale = None
            with self._qlock:
                if (self._pool is not None
                        and (workers or 1) != self._pool.workers):
                    stale, self._pool = self._pool, None
                self.workers = workers
            if stale is not None:
                # pool workers never take engine locks, so the join cannot
                # deadlock; holding the cycle lock is the point (no cycle
                # mid-dispatch may have its pool yanked)
                stale.shutdown()    # lockcheck: ok[blocking-under-lock]
        return self

    def _get_pool(self) -> Optional[_NodePool]:
        """The shared executor pool, or None for the serial pump."""
        if self.workers is None or self.workers <= 1:
            return None
        with self._qlock:
            if self._pool is None:
                self._pool = _NodePool(self.workers)
            return self._pool

    def close(self) -> None:
        """Release the executor pool's threads (idempotent).  Queued
        windows and ready results survive — only the workers go away; the
        next parallel cycle would lazily rebuild them.  Waits for any
        cycle in flight (cycle lock) rather than yanking its pool."""
        with self._cycle_lock:
            with self._qlock:
                pool, self._pool = self._pool, None
            if pool is not None:
                # same contract as use_workers: workers take no engine locks
                pool.shutdown()     # lockcheck: ok[blocking-under-lock]

    # ------------------------------------------------------------------ clock
    def use_clock(self, clock: Optional[Callable[[], float]]
                  ) -> "BatchedInvocationEngine":
        """Plug a virtual-time source (a zero-arg callable returning ms).
        With a clock set, ``pump()`` with no argument advances to the
        clock's *current* time instead of infinity — the hook a wall-clock
        serving loop uses to map real time onto the virtual timeline."""
        self.clock = clock
        return self

    def now(self) -> float:
        """Current virtual time per the plugged clock.  Without one it is
        ``+inf`` — the single convention ``pump()`` (and ``Router.pump``)
        resolve an omitted ``until_t`` through: an unclocked pump drains
        everything, the pre-clock behaviour."""
        return self.clock() if self.clock is not None else math.inf

    def next_deadline(self) -> Optional[float]:
        """Earliest finite window deadline still queued, or ``None`` when no
        timed window is open.  A serving driver sleeps exactly until this
        instant instead of polling ``pump``; a new ``submit`` can only move
        the horizon EARLIER (windows never extend), so the driver re-queries
        after every enqueue."""
        with self._qlock:
            deadlines = [w.deadline for w in self._windows
                         if math.isfinite(w.deadline)]
        return min(deadlines) if deadlines else None

    # ------------------------------------------------------------- coalescing
    def submit(self, fn: str, node: str, x, t_send: float = 0.0,
               client: str = "client", payload_bytes: int = 64) -> int:
        """Enqueue one invocation; returns a ticket redeemed by ``flush`` or
        ``pump``.  With ``window_ms`` set, the request joins its group's open
        window (or opens a new one closing ``window_ms`` after this
        request's arrival); a window that fills to ``max_batch`` dispatches
        immediately (flush-on-full) and its results await the next
        ``pump``/``flush``.  Thread-safe: queue surgery happens under the
        queue lock; a flush-on-full dispatch runs OUTSIDE it (under the
        cycle lock), so concurrent submits never wait on a dispatch."""
        self.stats.inc("submitted")
        t_arrive = t_send + self._hop_ms(client, node, payload_bytes)
        full = None
        with self._qlock:
            t = self._tickets
            self._tickets += 1
            p = _Pending(t, fn, node, x, t_send, t_arrive, client,
                         payload_bytes)
            key = (fn, node, client, payload_bytes)
            w = self._open_window(key, t_arrive)
            w.ps.append(p)
            if self.max_batch is not None and len(w.ps) >= self.max_batch:
                # full bucket flushes early: the batch executes when its
                # last member arrives, no deadline wait.  Validate BEFORE
                # taking the window off the queue so a KeyError really
                # does leave it intact
                self._validate([w])
                self._windows.remove(w)
                full = w
        if full is not None:
            self.stats.inc("auto_flushes")
            out = self._run_cycle([full], [None])
            with self._qlock:
                self._ready.update(out)
        return t

    def _open_window(self, key: Tuple, t_arrive: float) -> _Window:
        for w in self._windows:
            # joinable iff this request makes the close (t_arrive <=
            # deadline) AND the close is within window_ms of ITS arrival —
            # an out-of-order early request must not inherit a later
            # opener's deadline and wait past window_ms
            if (w.key == key and t_arrive <= w.deadline
                    and (self.window_ms is None
                         or w.deadline <= t_arrive + self.window_ms)
                    and (self.max_batch is None
                         or len(w.ps) < self.max_batch)):
                return w
        deadline = (math.inf if self.window_ms is None
                    else t_arrive + self.window_ms)
        w = _Window(key=key, deadline=deadline)
        self._windows.append(w)
        return w

    def hold_results(self, results: Dict[int, Any]) -> None:
        """Put already-redeemed results back for a later ``pump``/``flush``
        pickup.  Routers draining the shared engine use this to hand back
        tickets they do not own (another router's submissions)."""
        with self._qlock:
            self._ready.update(results)

    def pending(self) -> List[Dict[str, Any]]:
        """Read-only view of queued requests (public replacement for poking
        ``_queue``): one dict per request with ticket/fn/node/client/t_send
        and the window deadline it is waiting on."""
        out = []
        with self._qlock:
            for w in self._windows:
                for p in w.ps:
                    out.append({"ticket": p.ticket, "fn": p.fn,
                                "node": p.node, "client": p.client,
                                "t_send": p.t_send, "deadline": w.deadline})
        return out

    def discard(self, ticket: int) -> bool:
        """Drop a queued request (e.g. a poisoned one after a failed flush)
        without dispatching it.  Returns whether the ticket was queued."""
        with self._qlock:
            for w in self._windows:
                for p in w.ps:
                    if p.ticket == ticket:
                        w.ps.remove(p)
                        if not w.ps:
                            self._windows.remove(w)
                        return True
        return False

    def _evict_dead(self) -> Tuple[int, int]:
        """Sweep queued windows targeting non-ROUTABLE nodes — DEAD
        (health-driven removal or an injected crash) or SUSPECT (parked by
        a minority-view partition; replicas intact but no new work) — and
        convert each pending request into either a rerouted window at the
        nearest surviving deployment or a fail-fast drop when no live
        deployment remains.  Returns ``(rerouted, dropped)``.

        Called at the top of every ``pump``/``flush`` — before
        ``_validate`` — so a crashed node never hangs the serving thread:
        rerouted requests keep their tickets (they re-enter the window
        queue with a recomputed arrival at the new target and flush on a
        later turn), dropped tickets simply vanish from ``pending()``,
        which is exactly what ``Router._fold`` / ``FaasServer.reconcile``
        read to surface ``RequestLost``.  Only liveness triggers eviction;
        an undeployed function on a LIVE node still raises the usual
        ``_validate`` KeyError with the queue left intact."""
        c = self.cluster
        rerouted = dropped = fresh = 0
        with self._qlock:
            dead = [w for w in self._windows
                    if w.key[1] in c.nodes
                    and not c.naming.is_routable(w.key[1])]
            if not dead:
                return (0, 0)
            self._windows = [w for w in self._windows if w not in dead]
            for w in dead:
                for p in w.ps:
                    try:
                        alt = c._nearest_deployment(p.fn, p.client)
                    except KeyError:
                        dropped += 1        # no live deployment: fail fast
                        continue
                    p.node = alt
                    p.t_arrive = p.t_send + self._hop_ms(
                        p.client, alt, p.payload_bytes)
                    w2 = self._open_window(
                        (p.fn, alt, p.client, p.payload_bytes), p.t_arrive)
                    w2.ps.append(p)
                    rerouted += 1
                    if not p.rerouted:      # per-request-terminal ledger: a
                        p.rerouted = True   # request that keeps moving off
                        fresh += 1          # dying nodes counts ONCE
        if fresh:
            self.stats.inc("reroutes", fresh)
        if dropped:
            self.stats.inc("dropped_dead", dropped)
        return (rerouted, dropped)

    def _validate(self, windows: Sequence[_Window]) -> None:
        for w in windows:
            for p in w.ps:
                nd = self.cluster.nodes.get(p.node)
                if (p.fn not in self.cluster.specs or nd is None
                        or p.fn not in nd.batched_handlers):
                    raise KeyError(
                        f"cannot flush: function {p.fn!r} is not deployed at "
                        f"node {p.node!r} (queue left intact)")

    def flush(self) -> Dict[int, Any]:
        """Dispatch everything queued — deadlines ignored — as one flush
        cycle, and return ``{ticket: InvokeResult}`` (plus any results held
        over from auto-flushed windows or a previously failed cycle).

        Coalescing is per ``(fn, node, client)`` group: submission order is
        preserved WITHIN a group, and groups of the cycle run as parallel
        timelines (see module docstring) — requests of *different* functions
        sharing a keygroup may observe each other's writes in group order
        rather than submission order (the usual trade of a coalescing
        server).  Callers needing strict cross-function ordering should
        flush between submissions."""
        self._evict_dead()
        with self._qlock:
            self._validate(self._windows)
            windows, self._windows = self._windows, []
        cycle_out = (self._run_cycle(windows, [None] * len(windows))
                     if windows else {})
        # held-over results are only consumed on a clean cycle (a raising
        # cycle stashes its own partial results into _ready instead)
        with self._qlock:
            out = dict(self._ready)
            self._ready = {}
        out.update(cycle_out)
        return out

    def pump(self, until_t: Optional[float] = None) -> Dict[int, Any]:
        """Advance the background flusher to virtual time ``until_t``: every
        window whose deadline has passed dispatches, all due windows in ONE
        flush cycle.  Requests flushed here are charged the wait until their
        window's close.  Returns ``{ticket: InvokeResult}`` for everything
        that completed (including earlier flush-on-full results).

        With ``until_t`` omitted, a plugged clock (``use_clock``) supplies
        the current virtual time; without one, everything drains
        (``until_t = inf``, the pre-clock behaviour)."""
        if until_t is None:
            until_t = self.now()
        self._evict_dead()
        with self._qlock:
            due = [w for w in self._windows if w.deadline <= until_t]
            self._validate(due)     # raises with the queue left intact
            if due:
                self._windows = [w for w in self._windows if w not in due]
        cycle_out = {}
        if due:
            self.stats.inc("deadline_flushes", len(due))
            floors = [w.deadline if math.isfinite(w.deadline) else None
                      for w in due]
            cycle_out = self._run_cycle(due, floors)
        with self._qlock:
            out = dict(self._ready)
            self._ready = {}
        out.update(cycle_out)
        return out

    # --------------------------------------------------------------- dispatch
    def dispatch(self, fn_name: str, node: str, xs: Sequence,
                 t_sends: Optional[Sequence[float]] = None,
                 client: str = "client", payload_bytes: int = 64) -> List[Any]:
        """Invoke ``fn_name`` at ``node`` for every input in ``xs`` with one
        device dispatch per chunk.  Returns per-request InvokeResults in
        input order.  (One explicit batch == a single-window flush cycle.)"""
        n = len(xs)
        if t_sends is None:
            t_sends = [0.0] * n
        if len(t_sends) != n:
            raise ValueError(f"{n} inputs but {len(t_sends)} send times")
        # one ledger for every invocation path: dispatch counts its
        # requests as submitted so submitted == flushed + dropped holds
        # engine-wide (the stress test asserts the exact conservation)
        self.stats.inc("submitted", n)
        w = _Window(key=(fn_name, node, client, payload_bytes),
                    deadline=math.inf)
        hop = self._hop_ms(client, node, payload_bytes)
        for i, (x, t) in enumerate(zip(xs, t_sends)):
            w.ps.append(_Pending(i, fn_name, node, x, t, t + hop, client,
                                 payload_bytes))
        # deliver=False: the caller drains this cycle synchronously, so
        # results must come back here, not stream out through on_ready
        by_ticket = self._run_cycle([w], [None], deliver=False)
        return [by_ticket[i] for i in range(n)]

    # ------------------------------------------------------------ flush cycle
    def _store_key(self, fn: str, node: str) -> str:
        """The pipeline key of a group: the store node its kv ops hit (the
        serving node itself for stateless functions, which read that
        node's clock).  Groups with the same key share a pool worker so
        their store folds keep submission order."""
        kg, store_node, _ = self.cluster._resolve_placement(
            self.cluster.specs[fn], node)
        return store_node if kg is not None else node

    def _run_cycle(self, windows: Sequence[_Window],
                   floors: Sequence[Optional[float]],
                   deliver: bool = True) -> Dict[int, Any]:
        """Dispatch ``windows`` as one cycle of parallel per-(fn, node)
        timelines and return {ticket: InvokeResult} for everything NOT
        already streamed out through ``on_ready``.

        Three stages: (1) serial collect — per-store-node delivery
        high-water marks from every window of the cycle; (2) the dataflow
        scheduler (``_CycleRun``) — tasks sealed in a deterministic global
        sequence execute on per-store-node lanes, downstream batches are
        composed as their callers' frames resolve, and completed windows
        deliver the moment their last frame finalizes; (3) serial merge —
        coalesced replication snapshots are scheduled after the last task
        commits.  Cycles are serialized by ``_cycle_lock``; stage 2 is the
        only place device dispatches happen.  ``deliver=False`` keeps all
        results in the return value (the synchronous ``dispatch`` path)."""
        with self._cycle_lock:
            c = self.cluster
            self.stats.inc("cycles")
            cycle = _Cycle()
            # ---- stage 1 (serial): shared deliver high-water mark — the
            # latest arrival any group of this cycle brings to each store
            # node (the cycle executes once its last member has arrived)
            for w, floor in zip(windows, floors):
                fn, node, _, _ = w.key
                kg, store_node, _ = c._resolve_placement(c.specs[fn], node)
                if kg is None:
                    continue
                hi = max(max(p.t_arrive for p in w.ps), floor or -math.inf)
                cycle.hwm[store_node] = max(
                    cycle.hwm.get(store_node, -math.inf), hi)

            # ---- stage 2: the per-frame dataflow scheduler
            run = _CycleRun(self, cycle, deliver)
            out = run.run(windows, floors)

            # ---- stage 3 (serial merge): ONE coalesced replication
            # snapshot per written keygroup per node, with the post-cycle
            # contents at the latest apply time.  Sorted for a
            # deterministic event order regardless of which lane
            # finished first
            for (kg, store_node) in sorted(cycle.repl):
                c._schedule_replication(kg, store_node,
                                        cycle.repl[(kg, store_node)])

            if run.errors:
                with self._qlock:
                    self._ready.update(out)
                # the lowest-seal-sequence failure: window errors in window
                # order first, then the failing wave's earliest batch
                raise min(run.errors)[1]
            return out

    def _finalize_ready(self, frames: List[_Frame]) -> bool:
        """Finalize every frame with no remaining work, cascading upward
        (finalizing a subframe may unblock and finalize its parent).
        Returns whether anything finalized."""
        any_final = False
        progressed = True
        while progressed:
            progressed = False
            for f in frames:
                if f.results is None and not f.todo and f.outstanding == 0:
                    self._finalize(f)
                    progressed = any_final = True
        return any_final

    def _finalize(self, f: _Frame) -> None:
        from repro.core.cluster import InvokeResult
        results = []
        for i in range(f.n):
            t_done = max(f.t_applieds[i], f.t_downs[i])
            t_received = t_done + f.hop_ms
            results.append(InvokeResult(
                output=f.outputs[i], response_ms=t_received - f.t_sends[i],
                t_sent=f.t_sends[i], t_received=t_received,
                t_applied=f.t_applieds[i], kv_ops=list(f.ops), node=f.node,
                chain=f.chains[i]))
        f.results = results
        for i, par in enumerate(f.parents):
            if par is None:
                continue
            pf, pi, is_async = par
            pf.chains[pi].extend(f.chains[i])
            if not is_async:
                pf.t_downs[pi] = results[i].t_received
            pf.outstanding -= 1

    # ----------------------------------------------------------- batch exec
    def _exec_group(self, fn_name: str, node: str, xs: Sequence,
                    t_sends: Sequence[float], client: str, payload_bytes: int,
                    floor: Optional[float], cycle: _Cycle, depth: int,
                    parents: Sequence,
                    pendings: Optional[Sequence[_Pending]] = None
                    ) -> List[_Frame]:
        cap = self.buckets[-1]
        frames = []
        for lo in range(0, len(xs), cap):
            frames.append(self._exec_chunk(
                fn_name, node, xs[lo:lo + cap], t_sends[lo:lo + cap], client,
                payload_bytes, floor, cycle, depth, parents[lo:lo + cap],
                pendings[lo:lo + cap] if pendings is not None else None))
        return frames

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return n  # chunking caps n at the largest bucket already

    def _stage_chunk(self, xs, bucket: int):
        """Stack per-request host inputs into PERSISTENT per-(bucket, leaf)
        staging buffers — the np.stack/np.concatenate of the old path
        allocated fresh host arrays on every chunk.  Buffers live in
        thread-local storage (the parallel pump's lanes never share one)
        and are safe to reuse the moment the chunk dispatched: the
        ``jnp.asarray`` on the dispatch path copies host memory into a
        fresh device buffer before this thread stages again.  Padded slots
        repeat the first row, exactly like the old path."""
        n = len(xs)
        leaves0, treedef = jax.tree_util.tree_flatten(xs[0])
        bufs = getattr(self._staging, "bufs", None)
        if bufs is None:
            bufs = self._staging.bufs = {}
        flat = [leaves0] + [jax.tree_util.tree_flatten(x)[0]
                            for x in xs[1:]]
        out = []
        for j, leaf0 in enumerate(leaves0):
            a0 = np.asarray(leaf0)
            key = (bucket, j, a0.shape, a0.dtype.str)
            buf = bufs.get(key)
            if buf is None:
                buf = bufs[key] = np.empty((bucket,) + a0.shape, a0.dtype)
            buf[0] = a0
            for i in range(1, n):
                buf[i] = flat[i][j]
            if bucket > n:
                buf[n:] = buf[0]
            out.append(buf)
        return jax.tree_util.tree_unflatten(treedef, out)

    def prewarm(self, buckets: Optional[Sequence[int]] = None,
                merge_ks: Sequence[int] = (1, 2, 4, 8)) -> int:
        """Pre-trace every (bucket × keygroup-geometry) serving shape so
        warm flush cycles hit ZERO jit compilations (the shape-pinning
        half of the device-resident store path; tests/test_perf_paths.py
        asserts the zero).

        Each deployed batched handler EXECUTES once per bucket against a
        throwaway zeroed clone of its store state — ``lower().compile()``
        would not populate jit's call cache, so the handlers really run —
        and the fused delivery-merge entry runs once per REPLICATED
        keygroup per K bucket in ``merge_ks``.  Returns the number of
        warm-up executions issued.  Call after ``deploy`` and before
        serving; safe to call again after later deploys."""
        from repro.configs.base import ReplicationPolicy
        from repro.core.store import merge_snapshots_fused

        c = self.cluster
        count = 0
        with self._cycle_lock:
            for node, nd in c.nodes.items():
                for fn, bh in nd.batched_handlers.items():
                    example = getattr(bh, "example", None)
                    if example is None:
                        continue    # test double without deploy metadata
                    spec = c.specs[fn]
                    kg, store_node, _ = c._resolve_placement(spec, node)
                    for b in (buckets or self.buckets):
                        xs_dev = jax.tree.map(
                            jnp.asarray, self._stage_chunk([example] * b, b))
                        if kg is not None:
                            snd = c.nodes[store_node]
                            with snd.lock:
                                store, clock = snd.stores[kg], snd.clock
                            scratch = jax.tree.map(jnp.zeros_like, store)
                            out = bh(scratch, clock, xs_dev,
                                     _valid_mask(b, b), independent=False)
                        else:
                            from repro.core.keygroup import KeygroupSpec, arena_new
                            from repro.core.versioning import MAX_NODES
                            scratch = arena_new(
                                KeygroupSpec(name="_tmp",
                                             value_width=spec.codec_width),
                                MAX_NODES)
                            out = bh(scratch, nd.clock, xs_dev,
                                     _valid_mask(b, b), independent=True)
                        jax.block_until_ready(out[:3])
                        count += 1
            for kg_name, kspec in c.policies.items():
                if kspec.policy != ReplicationPolicy.REPLICATED:
                    continue
                replicas = c.naming.replicas_of(kg_name)
                if not replicas:
                    continue
                node0 = next(iter(replicas))
                with c.nodes[node0].lock:
                    proto = c.nodes[node0].stores[kg_name]
                aligned = c._aligned.get(kg_name, False)
                for k in merge_ks:
                    acc = jax.tree.map(jnp.zeros_like, proto)
                    jax.block_until_ready(merge_snapshots_fused(
                        acc, (proto,) * k, aligned=aligned))
                    count += 1
        return count

    def _exec_chunk(self, fn_name: str, node: str, xs, t_sends, client: str,
                    payload_bytes: int, floor: Optional[float], cycle: _Cycle,
                    depth: int, parents,
                    pendings: Optional[Sequence[_Pending]] = None) -> _Frame:
        """Run the main batched dispatch of one chunk (store effects +
        per-request timeline); downstream routing is the cycle driver's job."""
        from repro.core.cluster import fires_sync_downstream
        from repro.core.keygroup import KeygroupSpec, arena_new
        from repro.core.versioning import MAX_NODES

        if depth > MAX_CALL_DEPTH:
            raise RecursionError(
                f"downstream call chain exceeded {MAX_CALL_DEPTH} levels at "
                f"{fn_name!r} — cycle in calls/async_calls?")
        c = self.cluster
        spec = c.specs[fn_name]
        n = len(xs)
        if node in c.nodes and not c.naming.is_routable(node):
            # the target died (or went SUSPECT) between collection and
            # dispatch (a pool job racing an injected crash): convert to a
            # rerouted frame at the nearest surviving deployment — nothing
            # of this chunk has committed yet, so retrying elsewhere keeps
            # at-most-once.  No
            # survivor -> KeyError, and the group drops under the cycle's
            # normal failure path (tickets vanish; the server fails them
            # fast as RequestLost)
            node = c._nearest_deployment(fn_name, client)
            if pendings is None:        # downstream frames have no ticket:
                self.stats.inc("reroutes", n)   # single-shot, count as-is
            else:
                # top-level requests carry the per-request-terminal flag: a
                # request already counted by an eviction sweep does not
                # count again when its NEW target also dies before dispatch
                fresh = [p for p in pendings if not p.rerouted]
                for p in fresh:
                    p.rerouted = True
                if fresh:
                    self.stats.inc("reroutes", len(fresh))
        nd = c.nodes[node]
        bhandler = nd.batched_handlers[fn_name]
        self.stats.inc("dispatches")

        hop_ms = self._hop_ms(client, node, payload_bytes)
        t_arrives = [t + hop_ms for t in t_sends]
        if floor is not None:
            # the window closed at ``floor``: early arrivals waited for it
            t_arrives = [max(t, floor) for t in t_arrives]

        kg, store_node, per_op_ms = c._resolve_placement(spec, node)
        if kg is not None:
            # fold deliveries up to the cycle's shared high-water mark for
            # this store node (never below this chunk's own last arrival)
            hw = max(max(t_arrives), cycle.hwm.get(store_node, -math.inf))
            c._deliver_until(store_node, hw)
            snd = c.nodes[store_node]
        else:
            snd = None

        # pad to the bucket and run the one batched dispatch (host-side
        # numpy staging: jnp.stack over per-request device arrays costs more
        # than the dispatch itself).  Stacking is per pytree leaf so tuple/
        # dict handler inputs keep their structure, exactly as with invoke;
        # the staging buffers and the padding mask are persistent (see
        # _stage_chunk/_valid_mask) so a warm chunk allocates nothing fresh
        # on the host
        bucket = self._bucket(n)
        xs_host = self._stage_chunk(xs, bucket)
        valid = _valid_mask(bucket, n)

        if kg is not None:
            # hold the STORE node's lock across read-dispatch-write so the
            # fold is atomic against any other toucher of this store
            # (per-node pool workers already serialize engine work; the
            # lock also covers a sequential ``invoke`` racing the pump)
            with snd.lock:
                store, clock = snd.stores[kg], snd.clock
                new_store, new_clock, ys, ops = bhandler(
                    store, clock, jax.tree.map(jnp.asarray, xs_host),
                    valid, independent=False)
                snd.stores[kg] = new_store
                snd.clock = new_clock
        else:
            store = arena_new(KeygroupSpec(name="_tmp",
                                           value_width=spec.codec_width),
                              MAX_NODES)
            clock = nd.clock
            new_store, new_clock, ys, ops = bhandler(
                store, clock, jax.tree.map(jnp.asarray, xs_host),
                valid, independent=True)

        # per-request timeline: identical charges to Cluster.invoke
        compute = nd.compute_ms.get(fn_name, 0.0)
        op_net = c._op_network_ms(node, store_node, per_op_ms, ops)
        t_applieds = [t + compute + op_net for t in t_arrives]

        wrote = any(k in ("set", "delete") for k, _ in ops)
        if kg is not None and wrote:
            # defer to the cycle: ONE coalesced snapshot per (kg, node).
            # The stats bump moves OUTSIDE cycle.lock: it takes the stats
            # lock, and cycle.lock is a leaf in LOCK_ORDER (the checkers
            # flag lock acquisition under a leaf)
            rkey = (kg, store_node)
            with cycle.lock:
                coalesced = rkey in cycle.repl
                cycle.repl[rkey] = max(cycle.repl.get(rkey, -math.inf),
                                       max(t_applieds))
            if coalesced:
                self.stats.inc("replication_coalesced")

        # one transfer for the whole batch, then host-side row views
        ys_host = jax.tree.map(np.asarray, jax.device_get(ys))
        outputs = [jax.tree.map(lambda a: a[i], ys_host) for i in range(n)]
        fires = ([fires_sync_downstream(y) for y in outputs]
                 if spec.calls else [True] * n)
        todo = ([(cal, False) for cal in spec.calls]
                + [(cal, True) for cal in spec.async_calls])
        return _Frame(
            fn=fn_name, node=node, client=client, payload_bytes=payload_bytes,
            depth=depth, t_sends=list(t_sends), hop_ms=hop_ms,
            outputs=outputs, t_applieds=t_applieds,
            chains=[[fn_name] for _ in range(n)], t_downs=list(t_applieds),
            ops=list(ops), todo=todo, fires=fires, parents=list(parents))


class _CycleRun:    # lockcheck: single-threaded — counters below are
    # coordinator-thread-only: _seal/_process/_drop_fifo all run on the
    # pump caller's thread (workers only _execute and enqueue to done_q)
    """One flush cycle's dataflow scheduler, driven by the pump caller's
    thread under the engine's cycle lock (the coordinator).

    Execution is PER-FRAME: every task (a top-level window group or a
    merged downstream batch) is sealed with a global sequence number and
    handed to its store node's lane — a single-worker executor, so lane
    order IS seal order, which is the fold-clock half of the readiness
    rule (a frame dispatches once its store node's prior fold committed).
    Composition stays deterministic: the next wave of downstream batches
    is merged only once every COMPOSITION-RELEVANT task has committed —
    one whose frames (or their ancestors) can still pop a callee.  Leaf
    tasks never gate composition, so a straggling store node delays only
    the frames that fold into it; completed top-level windows deliver the
    moment their last frame finalizes (``engine.on_ready``).

    Serial mode (no pool / one store key / cycle under
    ``min_parallel_requests``) runs the same seal sequence from a deque on
    the coordinator itself — identical values, no handoff latency."""

    def __init__(self, eng: "BatchedInvocationEngine", cycle: _Cycle,
                 deliver: bool):
        self.eng = eng
        self.cycle = cycle
        self.deliver = deliver
        self.pool: Optional[_NodePool] = None
        self.fifo: "collections.deque[_Task]" = collections.deque()
        self.done_q: "queue.SimpleQueue[_Task]" = queue.SimpleQueue()
        self.next_seq = 0
        self.inflight = 0               # sealed, not yet processed
        self.pending_relevant = 0       # composition-relevant in flight
        self.frames_by_seq: Dict[int, List[_Frame]] = {}
        self.tops: List[_Task] = []     # completed-but-undelivered windows
        self.errors: List[Tuple[int, BaseException]] = []
        self.aborted = False            # downstream failure: stop composing
        self.out: Dict[int, Any] = {}   # undelivered {ticket: result}

    # -------------------------------------------------------------- main loop
    def run(self, windows: Sequence[_Window],
            floors: Sequence[Optional[float]]) -> Dict[int, Any]:
        eng = self.eng
        c = eng.cluster
        keys = [eng._store_key(w.key[0], w.key[1]) for w in windows]
        total = sum(len(w.ps) for w in windows)
        pool = eng._get_pool()
        # one mode per cycle: lanes would race an inline dispatch on the
        # same store, so either every task rides the pool or none does
        if (pool is not None and len(set(keys)) > 1
                and total >= eng.min_parallel_requests):
            self.pool = pool
        for w, floor, key in zip(windows, floors, keys):
            fn, node, client, payload = w.key
            spec = c.specs[fn]
            args = (fn, node, [p.x for p in w.ps], [p.t_send for p in w.ps],
                    client, payload, floor, self.cycle, 0,
                    [None] * len(w.ps), list(w.ps))
            self._seal(args, key, window=w,
                       relevant=bool(eng.wave_barrier or spec.calls
                                     or spec.async_calls))
        while True:
            self._drain_completed()
            if self.pending_relevant or self.fifo:
                self._wait_one()
                continue
            if self.aborted:
                break
            try:
                reqs = self._compose()
            except Exception as e:      # no live deployment of a callee
                self.errors.append((self.next_seq, e))
                break
            if not reqs:
                break
            self._seal_wave(reqs)
        # every composition is done: drain the remaining leaf lanes —
        # each window still delivers the moment its lane commits
        while self.inflight:
            self._wait_one()
        self._finalize_and_deliver()
        if not self.errors:
            stuck = [f for f in self._frames() if f.results is None]
            if stuck:
                raise RuntimeError(
                    f"flush cycle deadlocked with {len(stuck)} unfinalized "
                    f"frames (first: {stuck[0].fn!r}) — engine invariant bug")
        return self.out

    # ------------------------------------------------------------ lane plumbing
    def _seal(self, args: tuple, store_key: str, window: Optional[_Window],
              relevant: bool) -> _Task:
        t = _Task(seq=self.next_seq, store_key=store_key, args=args,
                  window=window, relevant=relevant)
        self.next_seq += 1
        self.inflight += 1
        if relevant:
            self.pending_relevant += 1
        if self.pool is None:
            self.fifo.append(t)
        else:
            self.pool.submit(store_key, self._pool_body, t)
        return t

    def _execute(self, t: _Task) -> None:
        eng = self.eng
        if eng.trace_folds:
            with eng._trace_lock:
                eng.fold_trace.append((t.store_key, t.seq))
        try:
            t.frames = eng._exec_group(*t.args)
        except Exception as e:      # recorded, not raised: the lane's later
            t.error = e             # tasks still run (at-most-once)

    def _pool_body(self, t: _Task) -> None:
        self._execute(t)
        self.done_q.put(t)

    def _drain_completed(self) -> None:
        if self.pool is None:
            return
        while True:
            try:
                t = self.done_q.get_nowait()
            except queue.Empty:
                return
            self._process(t)

    def _wait_one(self) -> None:
        if self.pool is None:
            t = self.fifo.popleft()
            self._execute(t)
        else:
            t = self.done_q.get()
        self._process(t)

    def _drop_fifo(self) -> List[_Task]:
        dropped = []
        while self.fifo:
            s = self.fifo.popleft()
            self.inflight -= 1
            if s.relevant:
                self.pending_relevant -= 1
            dropped.append(s)
        return dropped

    def _process(self, t: _Task) -> None:
        self.inflight -= 1
        if t.relevant:
            self.pending_relevant -= 1
        if t.error is not None:
            self.errors.append((t.seq, t.error))
            if t.window is None:
                # a downstream batch failed: no further wave composes (the
                # wave loop always aborted here); serially, the unexecuted
                # rest of the wave is dropped outright
                self.aborted = True
                if self.pool is None:
                    self._drop_fifo()
            elif self.pool is None:
                # serial top-level contract: windows that never started
                # dispatching go back on the queue intact
                requeue = self._drop_fifo()
                if requeue:
                    with self.eng._qlock:
                        self.eng._windows.extend(s.window for s in requeue)
            return
        self.frames_by_seq[t.seq] = t.frames
        if t.window is not None:
            self.tops.append(t)
        self._finalize_and_deliver()

    # --------------------------------------------------------------- finalize
    def _frames(self) -> List[_Frame]:
        """Every committed frame in seal order — the deterministic
        iteration order composition (and its fold order) hangs on."""
        out: List[_Frame] = []
        for seq in sorted(self.frames_by_seq):
            out.extend(self.frames_by_seq[seq])
        return out

    def _finalize_and_deliver(self) -> None:
        self.eng._finalize_ready(self._frames())
        self._deliver_tops()

    def _deliver_tops(self) -> None:
        for t in [t for t in self.tops
                  if all(f.results is not None for f in t.frames)]:
            self.tops.remove(t)
            self._deliver_window(t)

    def _deliver_window(self, t: _Task) -> None:
        eng = self.eng
        w = t.window
        rs: List[Any] = []
        for f in t.frames:
            rs.extend(f.results)
        eng.stats.inc("windows_flushed")
        eng.stats.inc("requests_flushed", len(w.ps))
        res = {p.ticket: r for p, r in zip(w.ps, rs)}
        cb = eng.on_ready
        if self.deliver and cb is not None and not eng.wave_barrier:
            try:
                cb(res)
                return          # streamed out: not in the cycle's return
            except Exception:
                pass            # a broken callback must not lose results:
                                # fall back to the classic return path
        self.out.update(res)

    # ------------------------------------------------------------ composition
    def _compose(self) -> Optional[Dict[Tuple, List]]:
        """Merge the next wave's downstream batches: fire the next callee
        of each unblocked frame, coalescing same-(callee, target, caller
        node, payload) requests across caller frames.  Returns ``None``
        when nothing can move any more (the cycle's chains are done)."""
        eng = self.eng
        c = eng.cluster
        frames = self._frames()
        while True:
            finalized = eng._finalize_ready(frames)
            if finalized:
                self._deliver_tops()
            reqs: Dict[Tuple, List[Tuple[Any, float, Tuple]]] = {}
            popped = False
            for f in frames:
                if f.results is not None or f.outstanding:
                    continue
                while f.todo:
                    callee, is_async = f.todo[0]
                    idxs = (list(range(f.n)) if is_async
                            else [i for i in range(f.n) if f.fires[i]])
                    if not idxs:
                        f.todo.pop(0)       # nobody fires: skip this callee
                        popped = True
                        continue
                    f.todo.pop(0)
                    popped = True
                    target = c._nearest_deployment(callee, f.node)
                    lst = reqs.setdefault(
                        (callee, target, f.node, f.payload_bytes), [])
                    for i in idxs:
                        lst.append((f.outputs[i], f.t_downs[i],
                                    (f, i, is_async)))
                    f.outstanding = len(idxs)
                    break                   # one callee per frame per wave
            if reqs:
                return reqs
            # no fires this pass: a frame may still have drained its todo
            # by skipping (all callees filtered) — loop once more so the
            # finalize pass picks it up; quiesce when nothing moves
            if not finalized and not popped:
                return None

    def _seal_wave(self, reqs: Dict[Tuple, List]) -> None:
        eng = self.eng
        c = eng.cluster
        for (callee, target, caller, payload), lst in reqs.items():
            callers = {id(slot[0]) for _, _, slot in lst}
            if len(callers) > 1:
                eng.stats.inc("downstream_coalesced", len(lst))
            depth = 1 + max(slot[0].depth for _, _, slot in lst)
            spec = c.specs[callee]
            relevant = bool(
                eng.wave_barrier or spec.calls or spec.async_calls
                or any(self._chain_may_pop(slot[0]) for _, _, slot in lst))
            args = (callee, target, [x for x, _, _ in lst],
                    [t for _, t, _ in lst], caller, payload, None,
                    self.cycle, depth, [slot for _, _, slot in lst])
            self._seal(args, eng._store_key(callee, target), window=None,
                       relevant=relevant)

    @staticmethod
    def _chain_may_pop(f: _Frame) -> bool:
        """Whether finalizing a new child of ``f`` could still change
        downstream composition: some frame on the ancestor chain has a
        callee left to pop.  When nothing up the chain can pop, the child
        batch is a pure leaf — its lane streams to completion without
        gating the next wave (the straggler-independence rule)."""
        seen = set()
        stack: List[_Frame] = [f]
        while stack:
            g = stack.pop()
            if id(g) in seen:
                continue
            seen.add(id(g))
            if g.todo:
                return True
            for par in g.parents:
                if par is not None:
                    stack.append(par[0])
        return False
