"""Lamport-style versioning shared by the store, CRDTs and replication.

A *packed version* totally orders writes across nodes:

    packed = lamport_clock * MAX_NODES + node_id

so comparing packed ints implements last-writer-wins with deterministic
node-id tie-breaking — exactly the conflict-resolution default FReD offers,
expressible as a single elementwise ``maximum`` (making the LWW register a
bona-fide CRDT, see ``crdt.py``).
"""
from __future__ import annotations

import jax.numpy as jnp

# Upper bound on cluster size for version packing.  64 keeps packed versions
# within int32 for ~33M writes per key, ample for tests and benchmarks; the
# TPU-scale path uses per-keygroup step counters instead.
MAX_NODES = 64

VERSION_DTYPE = jnp.int32


def pack_version(clock, node_id):
    return clock * MAX_NODES + node_id


def unpack_clock(packed):
    return packed // MAX_NODES


def unpack_node(packed):
    return packed % MAX_NODES


def fnv1a(key: str) -> int:
    """Stable 31-bit FNV-1a hash for string keys (0 is reserved for 'empty')."""
    h = 0x811C9DC5
    for ch in key.encode("utf-8"):
        h ^= ch
        h = (h * 0x01000193) & 0xFFFFFFFF
    h &= 0x7FFFFFFF
    return h if h != 0 else 1
