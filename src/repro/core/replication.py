"""Anti-entropy replication engine.

The paper's data plane: replicas exchange updates peer-to-peer and converge
via merge (LWW/CRDT).  Two execution contexts share the same merge code:

* **Logical nodes** (CPU benchmarks, the Cluster simulator): replica states
  are separate pytrees; ``anti_entropy_round`` merges every pair (all-to-all)
  or a gossip ring.
* **TPU pods** (the real target): replica states live on the ``pod`` mesh
  axis.  ``replicate_pod_axis`` runs under ``shard_map``; the exchange is an
  ``all_gather`` (full anti-entropy) or ``ppermute`` ring (gossip round) over
  the pod axis, followed by the same merges.  Crucially this is a SEPARATE
  jitted step from train/serve — replication stays off the hot path, which
  is the paper's whole point.

Delta compression (int8) for large tensor keygroups lives in
``optim/compression.py`` and is applied by the caller before exchange.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, List, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.keygroup import TensorKeygroup, merge_tensor_keygroups
from repro.core.store import Store, merge_stores, merge_stores_aligned


# ---------------------------------------------------------------------------
# Logical-node anti-entropy (benchmarks / simulator)
# ---------------------------------------------------------------------------

def anti_entropy_round(replicas: List[Any], merge: Callable[[Any, Any], Any],
                       topology: str = "full") -> List[Any]:
    """One anti-entropy round over logical replicas.

    topology="full": every replica merges every other (converges in 1 round).
    topology="ring": replica i merges from (i-1) mod N (converges in N-1).
    """
    n = len(replicas)
    if n <= 1:
        return list(replicas)
    if topology == "full":
        out = []
        for i in range(n):
            acc = replicas[i]
            for j in range(n):
                if j != i:
                    acc = merge(acc, replicas[j])
            out.append(acc)
        return out
    if topology == "ring":
        return [merge(replicas[i], replicas[(i - 1) % n]) for i in range(n)]
    raise ValueError(f"unknown topology {topology!r}")


def converge(replicas: List[Any], merge: Callable[[Any, Any], Any],
             topology: str = "full") -> List[Any]:
    """Run rounds until convergence is guaranteed by topology."""
    rounds = 1 if topology == "full" else max(1, len(replicas) - 1)
    for _ in range(rounds):
        replicas = anti_entropy_round(replicas, merge, topology)
    return replicas


# ---------------------------------------------------------------------------
# Pod-axis anti-entropy (TPU scale, inside shard_map)
# ---------------------------------------------------------------------------

def _merge_gathered(gathered: Any, merge: Callable[[Any, Any], Any], n: int) -> Any:
    """Fold-merge replicas stacked on a leading axis of size n."""
    take = lambda tree, i: jax.tree.map(lambda x: x[i], tree)
    acc = take(gathered, 0)
    for i in range(1, n):
        acc = merge(acc, take(gathered, i))
    return acc


def replicate_pod_axis(state: Any, merge: Callable[[Any, Any], Any],
                       axis_name: str = "pod", num_pods: int = 2,
                       topology: str = "full") -> Any:
    """Anti-entropy over the pod mesh axis.  MUST run inside shard_map with
    ``axis_name`` in scope.  ``state`` is this pod's replica (pytree).

    full: all_gather everyone's replica, fold-merge  (1 round to converge)
    ring: ppermute from the previous pod, merge once (gossip round)
    """
    if topology == "full":
        gathered = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis_name, axis=0), state)
        return _merge_gathered(gathered, merge, num_pods)
    if topology == "ring":
        perm = [((i + 1) % num_pods, i) for i in range(num_pods)]
        neighbour = jax.tree.map(
            lambda x: jax.lax.ppermute(x, axis_name, perm), state)
        return merge(state, neighbour)
    raise ValueError(f"unknown topology {topology!r}")


def make_pod_replicate_step(mesh, merge: Callable[[Any, Any], Any],
                            state_specs: Any, num_pods: int,
                            topology: str = "full"):
    """Build the jitted off-hot-path replication step for a pod mesh.

    ``state_specs`` are the *intra-pod* PartitionSpecs of the replica state
    (no 'pod' entry: the state is replicated across pods, sharded within).
    """
    from jax.experimental.shard_map import shard_map

    fn = functools.partial(replicate_pod_axis, merge=merge,
                           axis_name="pod", num_pods=num_pods,
                           topology=topology)
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=(state_specs,),
                             out_specs=state_specs, check_rep=False))


# Convenience merges for the two keygroup flavours --------------------------

def merge_arena(a: Store, b: Store) -> Store:
    return merge_stores(a, b)


def merge_arena_aligned(a: Store, b: Store) -> Store:
    """Slot-aligned arena merge for pod-axis replication.

    When every replica carries the keygroup's canonical slot layout
    (deploy-time ``store_assign_slots`` — the Cluster tracks this per
    keygroup), pass THIS as the merge to ``make_pod_replicate_step``:
    inside shard_map it lowers to the elementwise ``enoki_merge_rows``
    Pallas kernel (O(S·V)) instead of ``merge_stores``'s O(S²) probe.
    Unaligned or dynamic-key arenas must keep ``merge_arena``."""
    return merge_stores_aligned(a, b)


def merge_tensor(a: TensorKeygroup, b: TensorKeygroup) -> TensorKeygroup:
    return merge_tensor_keygroups(a, b)
