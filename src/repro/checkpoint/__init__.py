from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.serializer import deserialize_tree, serialize_tree

__all__ = ["CheckpointManager", "deserialize_tree", "serialize_tree"]
