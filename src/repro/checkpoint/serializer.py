"""Pytree <-> bytes: msgpack framing + zstd compression + content hash.

Layout: a msgpack map {path: {dtype, shape, data}} with an integrity footer.
bfloat16 has no numpy wire type, so it travels as uint16 bit patterns with
dtype tag 'bfloat16'.

``zstandard`` is optional: environments without it fall back to stdlib
``zlib``.  Decompression sniffs the frame magic so either side can read
blobs produced by the other (zstd frames start with 28 B5 2F FD).
"""
from __future__ import annotations

import hashlib
import zlib
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ModuleNotFoundError:          # degrade gracefully to stdlib zlib
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def compress_bytes(data: bytes, level: int = 3) -> bytes:
    """zstd when available, zlib otherwise (same framing either way).
    zstd levels go to 22; clamp for zlib's 0..9 range."""
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(data)
    return zlib.compress(data, min(level, 9))


def decompress_bytes(blob: bytes) -> bytes:
    """Inverse of ``compress_bytes``; raises ``IOError`` on a corrupted blob.

    A blob whose zstd magic bytes are corrupted falls through the sniff to
    the zlib branch and a truncated frame fails inside either decompressor —
    both are checkpoint corruption, not programming errors, so they surface
    as the same ``IOError`` family as the sha256 integrity check instead of
    a raw ``zlib.error``/``ZstdError``."""
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise IOError("blob is zstd-compressed but zstandard is not "
                          "installed; re-save with zlib or install zstandard")
        try:
            return zstandard.ZstdDecompressor().decompress(blob)
        except Exception as e:
            raise IOError(f"checkpoint blob corrupted: zstd frame failed to "
                          f"decompress ({e})") from e
    try:
        return zlib.decompress(blob)
    except zlib.error as e:
        raise IOError(f"checkpoint blob corrupted: not a valid zstd or zlib "
                      f"frame ({e})") from e


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def _encode_leaf(x) -> Dict[str, Any]:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(arr.shape),
                "data": arr.view(np.uint16).tobytes()}
    return {"dtype": arr.dtype.str, "shape": list(arr.shape),
            "data": arr.tobytes()}


def _decode_leaf(rec: Dict[str, Any]) -> np.ndarray:
    shape = tuple(rec["shape"])
    if rec["dtype"] == "bfloat16":
        return np.frombuffer(rec["data"], np.uint16).reshape(shape).view(
            jnp.bfloat16)
    return np.frombuffer(rec["data"], np.dtype(rec["dtype"])).reshape(shape)


def serialize_tree(tree: Any, level: int = 3) -> bytes:
    flat = {}
    jax.tree_util.tree_map_with_path(
        lambda path, leaf: flat.setdefault(_path_str(path),
                                           _encode_leaf(leaf)), tree)
    raw = msgpack.packb(flat, use_bin_type=True)
    digest = hashlib.sha256(raw).hexdigest().encode()
    framed = msgpack.packb({"payload": raw, "sha256": digest},
                           use_bin_type=True)
    return compress_bytes(framed, level)


def deserialize_tree(blob: bytes, template: Any) -> Any:
    framed = msgpack.unpackb(decompress_bytes(blob), raw=False)
    raw = framed["payload"]
    if hashlib.sha256(raw).hexdigest().encode() != framed["sha256"]:
        raise IOError("checkpoint integrity check failed (sha256 mismatch)")
    flat = msgpack.unpackb(raw, raw=False)

    def restore(path, leaf):
        rec = flat[_path_str(path)]
        arr = _decode_leaf(rec)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {_path_str(path)}: "
                             f"{arr.shape} vs {leaf.shape}")
        return arr

    return jax.tree_util.tree_map_with_path(restore, template)
