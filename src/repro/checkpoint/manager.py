"""Checkpoint manager: async double-buffered saves, retention, resharding
restore.

Saves run on a background thread (training never blocks on serialization);
a save is atomic (write to .tmp, fsync, rename).  ``restore`` device_puts
onto ANY target sharding — restoring onto a different mesh shape (elastic
re-mesh after a pod loss) works because the wire format is host numpy.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Callable, List, Optional

import jax

from repro.analysis import lockdep
from repro.checkpoint.serializer import deserialize_tree, serialize_tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._lock = lockdep.make_lock("checkpoint.lock")

    # -- paths --------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:010d}.msgpack.zst")

    def steps(self) -> List[int]:
        out = []
        for f in os.listdir(self.directory):
            if f.startswith("ckpt_") and f.endswith(".msgpack.zst"):
                out.append(int(f[len("ckpt_"):-len(".msgpack.zst")]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        # snapshot to host BEFORE handing to the writer thread so training
        # can mutate device state immediately (double buffering)
        host_state = jax.tree.map(lambda x: jax.device_get(x), state)
        self.wait()

        def write():
            blob = serialize_tree(host_state)
            tmp = self._path(step) + ".tmp"
            with open(tmp, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp, self._path(step))
            self._retain()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        with self._lock:
            steps = self.steps()
            for s in steps[:-self.keep]:
                try:
                    os.remove(self._path(s))
                except FileNotFoundError:
                    pass

    # -- restore ------------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with open(self._path(step), "rb") as f:
            tree = deserialize_tree(f.read(), template)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree
