"""Jit-compilation accounting for the shape-pinning guarantees.

The device-resident store path promises that a warm serving loop never
retraces: every (bucket × keygroup-geometry) shape is executed once at
deploy time (``engine.prewarm``) and the staging buffers / padding masks
are persistent.  ``CompileCounter`` is the measurement side of that
promise — it counts XLA compile requests via ``jax.monitoring`` events
while active, so a test can wrap warm flush cycles and assert the count
stays ZERO (tests/test_perf_paths.py).

Counting events (not cache sizes) catches every compile path: a fresh
``jax.jit`` trace, a new shape on a cached jit, and nested pallas_call
lowering all emit compile-request events; warm cache-hit dispatches emit
none.
"""
from __future__ import annotations

import jax

# every XLA compile request fires monitoring events whose names carry
# this substring (jax 0.4.x: '/jax/compilation_cache/compile_requests_*');
# warm dispatches fire none
COMPILE_EVENT_SUBSTR = "compile_requests"


class CompileCounter:
    """Context manager counting XLA compile requests while active.

    ``events`` is monotone within the block; ``events == 0`` on exit means
    every dispatch inside hit jit's cache.  Listener registration is
    process-global in jax, so instances must not be nested concurrently
    across threads (tests use one at a time).
    """

    def __init__(self):
        self.events = 0
        self._cb = None

    def _on_event(self, name, *args, **kwargs):
        if COMPILE_EVENT_SUBSTR in name:
            self.events += 1

    def __enter__(self) -> "CompileCounter":
        self._cb = self._on_event
        jax.monitoring.register_event_listener(self._cb)
        return self

    def __exit__(self, *exc) -> bool:
        try:
            # jax exposes registration but not (yet) deregistration in the
            # public monitoring API; fall back to leaving the listener in
            # place (it only increments a dead counter) if the private
            # helper moves
            from jax._src import monitoring as _monitoring
            _monitoring._unregister_event_listener_by_callback(self._cb)
        except Exception:
            pass
        return False
