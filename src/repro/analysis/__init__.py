"""Concurrency-contract analysis for the Enoki reproduction.

Three pieces, one contract:

- ``lock_order`` — the machine-readable ``LOCK_ORDER`` declaration: the
  partial order over every lock in the serving stack (previously prose in
  ``core/engine.py`` and ``docs/batched_engine.md``), plus the tables the
  checkers share (guarded counters, dispatch/blocking call names).  The
  hierarchy block in ``docs/batched_engine.md`` is generated from it.
- ``lockcheck`` — the static half: an AST lint over ``src/`` that flags
  out-of-order acquisitions (``with``-nesting plus an intramodule
  call-graph approximation), device dispatches lexically under the
  engine's queue lock, raw ``+=`` on shared counters, and blocking calls
  under non-leaf locks.  Run as ``python -m repro.analysis.lockcheck src/``.
- ``lockdep`` — the runtime half: ordered-lock wrappers the serving-stack
  locks opt into.  When enabled (the concurrency test suites do, via a
  conftest fixture) every acquire is checked against ``LOCK_ORDER`` with
  the per-thread held set, and a cross-thread acquisition graph is
  accumulated; cycles fail the test run.

See ``docs/concurrency_checks.md`` for the contract and the suppression
syntax.  This package must stay importable without ``repro.core`` (the
core locks import ``lockdep`` at module load).
"""
