"""AST lock-discipline lint for the serving stack.

Usage::

    PYTHONPATH=src python -m repro.analysis.lockcheck src/

Reads the ``LOCK_ORDER`` declaration (``lock_order.py``) and walks every
module's ``with``-nesting plus an INTRAMODULE call-graph approximation.
Four rule families:

``order``
    Acquiring a lock that the declared partial order does not allow under
    the currently-held set — including anything under a leaf lock.  Held
    sets propagate through ``self.method()`` calls, module-level function
    calls, and method calls whose name is defined by exactly one class in
    the module (the call-graph approximation; cross-module calls are the
    runtime validator's job).
``dispatch-under-qlock``
    A device-dispatch call (``_exec_*``, jitted entry points,
    ``jax.*``/``jnp.*`` chains, engine/cluster dispatch verbs) LEXICALLY
    inside a ``with self._qlock`` block — the queue lock must never be
    held across a dispatch.
``stats-raw-increment`` / ``guarded-field`` / ``shared-counter``
    Raw ``+=`` on an ``AtomicStats`` field (must use ``.inc``); raw
    ``+=`` on a declared guarded field outside its declared lock; raw
    ``+=`` on any attribute of a threaded class with no lock held at all.
``blocking-under-lock``
    ``sleep`` / ``Future.result`` / ``join`` / ``shutdown`` /
    ``Condition.wait`` lexically under a non-leaf lock.  ``x.wait()``
    while lexically holding ``with x:`` is the sanctioned
    condition-variable pattern and is exempt.

Suppressions (see ``docs/concurrency_checks.md``)::

    ... # lockcheck: ok[rule-name] — reason
    class Foo:  # lockcheck: single-threaded — reason

Static analysis over-approximates: same-name nesting is assumed
reentrant (the runtime validator distinguishes instances), unresolvable
lock expressions are skipped, and only intramodule calls are followed.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import pathlib
import sys
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import lock_order as spec

PRAGMA = "# lockcheck:"


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class _Held:
    """One lock on the abstract held stack.  ``inherited`` marks entries
    that arrived through the call graph — order checks use the full
    stack, the lexical rules (dispatch/blocking) only the local part."""
    __slots__ = ("name", "text", "inherited")

    def __init__(self, name: str, text: str, inherited: bool) -> None:
        self.name = name
        self.text = text
        self.inherited = inherited

    def as_inherited(self) -> "_Held":
        return _Held(self.name, self.text, True)


class _Module:
    def __init__(self, path: str, src: str, tree: ast.Module) -> None:
        self.path = path
        self.functions: Dict[str, ast.AST] = {}
        self.classes: Dict[str, Dict[str, ast.AST]] = {}
        self.method_owners: Dict[str, List[str]] = {}
        class_linenos: Dict[str, int] = {}
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node
            elif isinstance(node, ast.ClassDef):
                meths = {}
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        meths[sub.name] = sub
                        self.method_owners.setdefault(sub.name,
                                                      []).append(node.name)
                self.classes[node.name] = meths
                class_linenos[node.name] = node.lineno
        self.line_rules, self.st_lines = self._parse_pragmas(src)
        self.st_classes = {c for c, ln in class_linenos.items()
                           if ln in self.st_lines}

    @staticmethod
    def _parse_pragmas(src: str) -> Tuple[Dict[int, Set[str]], Set[int]]:
        line_rules: Dict[int, Set[str]] = {}
        st_lines: Set[int] = set()
        for i, line in enumerate(src.splitlines(), 1):
            if PRAGMA not in line:
                continue
            tail = line.split(PRAGMA, 1)[1].strip()
            if tail.startswith("single-threaded"):
                st_lines.add(i)
            elif tail.startswith("ok"):
                rest = tail[len("ok"):]
                if rest.startswith("[") and "]" in rest:
                    rules = rest[1:rest.index("]")]
                    line_rules.setdefault(i, set()).update(
                        r.strip() for r in rules.split(","))
                else:
                    line_rules.setdefault(i, set()).add("*")
        return line_rules, st_lines


class _Checker:
    def __init__(self, mod: _Module) -> None:
        self.mod = mod
        self.findings: List[Finding] = []
        self._emitted: Set[Tuple[int, str, str]] = set()
        self._memo: Set[Tuple[int, Tuple[str, ...]]] = set()
        self._pending: List[Tuple[ast.AST, Optional[str]]] = []

    # ------------------------------------------------------------- entry
    def run(self) -> List[Finding]:
        for fn in self.mod.functions.values():
            self._pending.append((fn, None))
        for cls, meths in self.mod.classes.items():
            for fn in meths.values():
                self._pending.append((fn, cls))
        done: Set[int] = set()
        while self._pending:
            fn, cls = self._pending.pop()
            if id(fn) in done:
                continue
            done.add(id(fn))
            self._check_fn(fn, cls, ())
        return self.findings

    def _check_fn(self, fn: ast.AST, cls: Optional[str],
                  held: Tuple[_Held, ...]) -> None:
        key = (id(fn), tuple(sorted(h.name for h in held)))
        if key in self._memo:
            return
        self._memo.add(key)
        self._stmts(fn.body, cls, held)

    # --------------------------------------------------------- statements
    def _stmts(self, body: Iterable[ast.stmt], cls: Optional[str],
               held: Tuple[_Held, ...]) -> None:
        for st in body:
            self._stmt(st, cls, held)

    def _stmt(self, st: ast.stmt, cls: Optional[str],
              held: Tuple[_Held, ...]) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: runs later, on whoever calls it — walk with an
            # empty held set, same class context (closures keep ``self``)
            self._pending.append((st, cls))
            return
        if isinstance(st, ast.ClassDef):
            return
        if isinstance(st, (ast.With, ast.AsyncWith)):
            new_held = held
            for item in st.items:
                self._exprs(item.context_expr, cls, new_held)
                name = self._resolve_lock(item.context_expr, cls)
                if name is None:
                    continue
                if any(h.name == name for h in new_held):
                    continue            # same-name: assumed reentrant
                for h in new_held:
                    if not spec.allowed(h.name, name):
                        self._emit(
                            st.lineno, "order",
                            f"acquires {name!r} while holding {h.name!r} "
                            f"(held: {[x.name for x in new_held]}) — not "
                            f"allowed by LOCK_ORDER")
                text = ast.unparse(item.context_expr)
                new_held = new_held + (_Held(name, text, False),)
            self._stmts(st.body, cls, new_held)
            return
        if isinstance(st, ast.AugAssign):
            self._augassign(st, cls, held)
            self._exprs(st.value, cls, held)
            return
        for _, value in ast.iter_fields(st):
            if isinstance(value, list):
                for v in value:
                    if isinstance(v, ast.stmt):
                        self._stmt(v, cls, held)
                    elif isinstance(v, ast.excepthandler):
                        self._stmts(v.body, cls, held)
                    elif isinstance(v, ast.expr):
                        self._exprs(v, cls, held)
            elif isinstance(value, ast.expr):
                self._exprs(value, cls, held)

    # -------------------------------------------------------- expressions
    def _exprs(self, expr: ast.expr, cls: Optional[str],
               held: Tuple[_Held, ...]) -> None:
        stack: List[ast.AST] = [expr]
        while stack:
            node = stack.pop()
            if isinstance(node, ast.Lambda):
                continue                # runs later, unknown held set
            if isinstance(node, ast.Call):
                self._call(node, cls, held)
            stack.extend(ast.iter_child_nodes(node))

    @staticmethod
    def _dotted(func: ast.expr) -> Tuple[Optional[str], List[str]]:
        """(root name, attribute chain) of a call target, or (None, [])
        when the root is not a plain name."""
        attrs: List[str] = []
        node = func
        while isinstance(node, ast.Attribute):
            attrs.append(node.attr)
            node = node.value
        attrs.reverse()
        if isinstance(node, ast.Name):
            return node.id, attrs
        return None, attrs

    def _call(self, node: ast.Call, cls: Optional[str],
              held: Tuple[_Held, ...]) -> None:
        root, attrs = self._dotted(node.func)
        callee = attrs[-1] if attrs else root
        lex = [h for h in held if not h.inherited]

        if callee is not None and lex:
            # dispatch under the queue lock (lexical only)
            if any(h.name == "engine.qlock" for h in lex):
                if (callee.startswith(spec.DISPATCH_CALL_PREFIXES)
                        or callee in spec.DISPATCH_CALL_NAMES
                        or (root in spec.JAX_ROOTS and attrs)):
                    self._emit(node.lineno, "dispatch-under-qlock",
                               f"{ast.unparse(node.func)}() dispatches "
                               f"while engine.qlock is held")
            # blocking call under a non-leaf lock (lexical only)
            if callee in spec.BLOCKING_CALL_NAMES:
                nonleaf = [h for h in lex if h.name not in spec.LEAF_LOCKS]
                if nonleaf and not self._is_cond_self_wait(node, callee,
                                                          lex):
                    self._emit(node.lineno, "blocking-under-lock",
                               f"{ast.unparse(node.func)}() blocks while "
                               f"holding {[h.name for h in nonleaf]}")

        # order propagation through the intramodule call graph
        for target, tcls in self._resolve_call(node, cls):
            inherited = tuple(h.as_inherited() for h in held)
            self._check_fn(target, tcls, inherited)

    @staticmethod
    def _is_cond_self_wait(node: ast.Call, callee: str,
                           lex: List[_Held]) -> bool:
        if callee not in ("wait", "wait_for"):
            return False
        if not isinstance(node.func, ast.Attribute):
            return False
        recv = ast.unparse(node.func.value)
        return any(h.text == recv for h in lex)

    def _resolve_call(self, node: ast.Call, cls: Optional[str]
                      ) -> List[Tuple[ast.AST, Optional[str]]]:
        f = node.func
        if isinstance(f, ast.Name):
            t = self.mod.functions.get(f.id)
            return [(t, None)] if t is not None else []
        if isinstance(f, ast.Attribute):
            meth = f.attr
            if isinstance(f.value, ast.Name) and f.value.id == "self" and cls:
                t = self.mod.classes.get(cls, {}).get(meth)
                return [(t, cls)] if t is not None else []
            # non-self receiver: follow only when exactly one class in
            # this module defines the method AND the caller's own class
            # doesn't (else ``self.router.submit`` would bind to the
            # caller's unrelated ``submit``)
            if cls is not None and meth in self.mod.classes.get(cls, {}):
                return []
            owners = self.mod.method_owners.get(meth, [])
            if len(owners) == 1 and meth not in self.mod.functions:
                ocls = owners[0]
                return [(self.mod.classes[ocls][meth], ocls)]
        return []

    # --------------------------------------------------------- aug-assign
    def _augassign(self, st: ast.AugAssign, cls: Optional[str],
                   held: Tuple[_Held, ...]) -> None:
        t = st.target
        if not isinstance(t, ast.Attribute):
            return
        recv = t.value
        stats_recv = ((isinstance(recv, ast.Attribute)
                       and recv.attr == "stats")
                      or (isinstance(recv, ast.Name) and recv.id == "stats"))
        if stats_recv:
            self._emit(st.lineno, "stats-raw-increment",
                       f"raw '+=' on stats field {ast.unparse(t)!r} — "
                       f"use AtomicStats.inc")
            return
        if not (isinstance(recv, ast.Name) and recv.id == "self" and cls):
            return
        guard = spec.GUARDED_FIELDS.get((cls, t.attr))
        if guard is not None:
            if not any(h.name == guard for h in held):
                self._emit(st.lineno, "guarded-field",
                           f"self.{t.attr} += requires {guard!r} held "
                           f"(held: {[h.name for h in held]})")
            return
        if (cls in spec.THREADED_CLASSES
                and cls not in self.mod.st_classes
                and not held
                and st.lineno not in self.mod.st_lines):
            self._emit(st.lineno, "shared-counter",
                       f"unlocked '+=' on self.{t.attr} in threaded class "
                       f"{cls} — guard it, use AtomicStats.inc, or "
                       f"annotate '# lockcheck: single-threaded'")

    # ------------------------------------------------------ lock resolving
    def _resolve_lock(self, expr: ast.expr,
                      cls: Optional[str]) -> Optional[str]:
        if not isinstance(expr, ast.Attribute):
            return None
        attr = expr.attr
        name = spec.LOCK_ATTRS.get(attr)
        if name is not None:
            return name
        if attr == "_lock":
            return spec.CLASS_LOCK_ATTRS.get(cls) if cls else None
        if attr == "lock":
            recv = expr.value
            hint = None
            if isinstance(recv, ast.Name):
                hint = recv.id
            elif isinstance(recv, ast.Attribute):
                hint = recv.attr
            if hint is not None:
                if hint == "q" or "queue" in hint:
                    return "cluster.delivery_lock"
                if "cycle" in hint:
                    return "engine.cycle_state_lock"
            return "cluster.node_lock"
        return None

    # -------------------------------------------------------------- emit
    def _emit(self, line: int, rule: str, message: str) -> None:
        suppressed = self.mod.line_rules.get(line, set())
        if rule in suppressed or "*" in suppressed:
            return
        key = (line, rule, message)
        if key in self._emitted:
            return
        self._emitted.add(key)
        self.findings.append(Finding(self.mod.path, line, rule, message))


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------


def check_source(src: str, path: str = "<string>") -> List[Finding]:
    tree = ast.parse(src, filename=path)
    mod = _Module(path, src, tree)
    findings = _Checker(mod).run()
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def iter_py_files(paths: Iterable[str]) -> List[pathlib.Path]:
    out: List[pathlib.Path] = []
    for p in paths:
        pp = pathlib.Path(p)
        if pp.is_dir():
            out.extend(sorted(pp.rglob("*.py")))
        elif pp.suffix == ".py":
            out.append(pp)
    return out


def check_paths(paths: Iterable[str]) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(check_source(f.read_text(), str(f)))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="AST lock-discipline lint (LOCK_ORDER contract)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    args = ap.parse_args(argv)
    findings = check_paths(args.paths)
    for f in findings:
        print(f)
    n_files = len(iter_py_files(args.paths))
    if findings:
        print(f"lockcheck: {len(findings)} finding(s) in {n_files} files",
              file=sys.stderr)
        return 1
    print(f"lockcheck: OK ({n_files} files clean)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
