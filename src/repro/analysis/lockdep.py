"""Runtime lock-order validator (lockdep debug mode).

The serving-stack locks are constructed through the factories below
(``make_lock`` / ``make_rlock`` / ``make_condition``).  Disabled — the
default — they return plain ``threading`` primitives: zero overhead, no
behaviour change.  Enabled (``lockdep.enable()``, done by the conftest
fixture across the concurrency test suites), they return ordered
wrappers that on every acquire:

1. check the declared partial order (``lock_order.allowed``) against the
   calling thread's held-lock stack and raise ``LockOrderViolation`` on
   an out-of-order acquisition (also recorded, so a violation swallowed
   by an executor still fails the test at teardown);
2. record the (held -> acquired) name edge into a process-wide
   acquisition graph.  ``verify()`` reports recorded violations plus any
   cycle in that graph — the cross-THREAD check: two threads may each be
   locally consistent while jointly forming an A->B / B->A deadlock.

Locks whose names are not in ``lock_order.LOCKS`` are record-only: no
order is asserted, but their edges still feed the cycle check.

Reentrancy is by identity: re-acquiring the SAME object (RLocks do) is
fine; nesting two *distinct* instances of the same name (two store-node
locks, say) has no defined order and is a violation.

``enable()`` must run before the instrumented objects are constructed —
already-built plain locks stay plain.  The conftest fixture enables
lockdep before each test body, so clusters/servers built inside the test
get wrapped locks.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import lock_order

__all__ = [
    "LockOrderViolation", "LockdepReport", "enable", "disable", "enabled",
    "verify", "make_lock", "make_rlock", "make_condition",
    "OrderedLock", "OrderedRLock", "OrderedCondition",
]

_MAX_VIOLATIONS = 200

_state_lock = threading.Lock()          # guards _edges/_violations
_enabled = False
_raise_on_violation = True
_edges: Set[Tuple[str, str]] = set()
_violations: List[str] = []
_tls = threading.local()


class LockOrderViolation(AssertionError):
    """An acquisition that breaks the declared LOCK_ORDER."""


class _Entry:
    __slots__ = ("name", "obj")

    def __init__(self, name: str, obj) -> None:
        self.name = name
        self.obj = obj


def _stack() -> List[_Entry]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _report(msg: str) -> None:
    with _state_lock:
        if len(_violations) < _MAX_VIOLATIONS:
            _violations.append(msg)
    if _raise_on_violation:
        raise LockOrderViolation(msg)


def _check_order(obj) -> None:
    if not _enabled:
        return
    stack = _stack()
    for e in stack:
        if e.obj is obj:
            return                      # reentrant: same instance
    held = [e.name for e in stack]
    for e in stack:
        if e.name == obj.name:
            _report(f"lockdep: nested two instances of {obj.name!r} "
                    f"(thread {threading.current_thread().name}, "
                    f"held: {held})")
        elif not lock_order.allowed(e.name, obj.name):
            _report(f"lockdep: acquired {obj.name!r} while holding "
                    f"{e.name!r} — violates LOCK_ORDER "
                    f"(thread {threading.current_thread().name}, "
                    f"held: {held})")
        if e.name != obj.name:
            key = (e.name, obj.name)
            if key not in _edges:       # racy fast-path read is fine:
                with _state_lock:       # the slow path re-adds idempotently
                    _edges.add(key)


def _push(obj) -> None:
    _stack().append(_Entry(obj.name, obj))


def _pop(obj) -> None:
    st = _stack()
    for i in range(len(st) - 1, -1, -1):
        if st[i].obj is obj:
            del st[i]
            return


class _OrderedBase:
    __slots__ = ("name", "_lock")

    def __init__(self, name: str, lock) -> None:
        self.name = name
        self._lock = lock

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        _check_order(self)
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            _push(self)
        return ok

    def release(self) -> None:
        self._lock.release()
        _pop(self)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name}>"


class OrderedLock(_OrderedBase):
    def __init__(self, name: str) -> None:
        super().__init__(name, threading.Lock())

    def locked(self) -> bool:
        return self._lock.locked()


class OrderedRLock(_OrderedBase):
    def __init__(self, name: str) -> None:
        super().__init__(name, threading.RLock())


class OrderedCondition:
    """An ordered ``threading.Condition``.  ``wait`` releases the
    underlying lock, so the held entry is popped for the duration of the
    wait and re-pushed on wake — a waiter is NOT holding the cond for
    ordering purposes."""

    __slots__ = ("name", "_cond")

    def __init__(self, name: str) -> None:
        self.name = name
        self._cond = threading.Condition()

    def acquire(self, *args, **kw) -> bool:
        _check_order(self)
        ok = self._cond.acquire(*args, **kw)
        if ok:
            _push(self)
        return ok

    def release(self) -> None:
        self._cond.release()
        _pop(self)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def _drop_entries(self) -> int:
        st = _stack()
        n = 0
        for i in range(len(st) - 1, -1, -1):
            if st[i].obj is self:
                del st[i]
                n += 1
        return n

    def wait(self, timeout: Optional[float] = None) -> bool:
        n = self._drop_entries()
        try:
            return self._cond.wait(timeout)
        finally:
            for _ in range(n):
                _push(self)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        n = self._drop_entries()
        try:
            return self._cond.wait_for(predicate, timeout)
        finally:
            for _ in range(n):
                _push(self)

    def notify(self, n: int = 1) -> None:
        self._cond.notify(n)

    def notify_all(self) -> None:
        self._cond.notify_all()

    def __repr__(self) -> str:
        return f"<OrderedCondition {self.name}>"


# --------------------------------------------------------------------------
# factories — what the serving stack actually calls
# --------------------------------------------------------------------------


def make_lock(name: str):
    return OrderedLock(name) if _enabled else threading.Lock()


def make_rlock(name: str):
    return OrderedRLock(name) if _enabled else threading.RLock()


def make_condition(name: str):
    return OrderedCondition(name) if _enabled else threading.Condition()


# --------------------------------------------------------------------------
# session control
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LockdepReport:
    violations: List[str]
    edges: Set[Tuple[str, str]]

    def problems(self) -> List[str]:
        out = list(self.violations)
        cyc = _find_cycle(self.edges)
        if cyc:
            out.append("lockdep: acquisition-graph cycle: "
                       + " -> ".join(cyc))
        return out


def enable(raise_on_violation: bool = True) -> None:
    """Start a lockdep session: clear recorded state, instrument every
    lock the factories build from here on."""
    global _enabled, _raise_on_violation
    with _state_lock:
        _edges.clear()
        _violations.clear()
    _raise_on_violation = raise_on_violation
    _enabled = True


def disable() -> LockdepReport:
    """End the session; wrapped locks keep working but stop checking."""
    global _enabled
    _enabled = False
    with _state_lock:
        return LockdepReport(list(_violations), set(_edges))


def enabled() -> bool:
    return _enabled


def verify() -> List[str]:
    """Everything wrong so far: recorded order violations plus any cycle
    in the cross-thread acquisition graph."""
    with _state_lock:
        report = LockdepReport(list(_violations), set(_edges))
    return report.problems()


def _find_cycle(edges: Set[Tuple[str, str]]) -> Optional[List[str]]:
    adj: Dict[str, List[str]] = {}
    for a, b in edges:
        if a != b:
            adj.setdefault(a, []).append(b)
    WHITE, GRAY, BLACK = 0, 1, 2
    color: Dict[str, int] = {}
    parent: Dict[str, str] = {}

    for root in sorted(adj):
        if color.get(root, WHITE) != WHITE:
            continue
        stack = [(root, iter(sorted(adj.get(root, ()))))]
        color[root] = GRAY
        while stack:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                c = color.get(nxt, WHITE)
                if c == GRAY:           # back edge: walk parents for path
                    path = [nxt, node]
                    cur = node
                    while cur != nxt and cur in parent:
                        cur = parent[cur]
                        path.append(cur)
                    path.reverse()
                    return path
                if c == WHITE:
                    color[nxt] = GRAY
                    parent[nxt] = node
                    stack.append((nxt, iter(sorted(adj.get(nxt, ())))))
                    advanced = True
                    break
            if not advanced:
                color[node] = BLACK
                stack.pop()
    return None
