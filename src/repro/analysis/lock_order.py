"""The machine-readable lock hierarchy of the serving stack.

This module is the SINGLE SOURCE for the lock partial order that used to
live as prose in ``core/engine.py`` and ``docs/batched_engine.md``.  Both
checkers read it — ``lockcheck`` (static AST lint) and ``lockdep``
(runtime ordered-lock validator) — and the hierarchy block in
``docs/batched_engine.md`` is generated from it
(``python -m repro.analysis.lock_order --write``; a tier0 test fails on
drift).

The order is a partial order (a DAG of direct ``ORDER_EDGES``), not a
total one: two locks with no path between them are simply never nested.
The documented ``on_ready`` delta — the engine's mid-cycle delivery path
takes ``router.lock`` then ``server.cond`` *with the cycle lock held*,
the reverse of the submit-side prose order — is a pair of declared edges
(``engine.cycle_lock -> router.lock`` / ``-> server.cond``) rather than a
blanket suppression: it is deadlock-free precisely because no fold path
ever acquires the cycle lock from under the router lock or the cond, so
the reverse edges must NOT exist, and both checkers enforce exactly that.

Leaf locks protect a few fields each and never wrap another acquisition:
anything may take them, nothing may be taken under them.

This module must not import ``repro.core`` (the core locks import the
validator at module load).
"""
from __future__ import annotations

import argparse
import pathlib
from typing import Dict, FrozenSet, Optional, Tuple

# --------------------------------------------------------------------------
# the locks: canonical name -> (attribute in the code, what it guards)
# --------------------------------------------------------------------------

LOCKS: Dict[str, Tuple[str, str]] = {
    "server.pump_lock": (
        "FaasServer._pump_lock",
        "whole pump turns (fold -> deliver -> fail-lost)"),
    "server.cond": (
        "FaasServer._cond",
        "future table, orphans, deadline wake-ups"),
    "router.lock": (
        "Router._lock",
        "sessions / in-flight tickets / hedge pairs (host-side folds only)"),
    "engine.cycle_lock": (
        "engine._cycle_lock",
        "serializes flush cycles (all device dispatches)"),
    "engine.qlock": (
        "engine._qlock",
        "window queue, tickets, ready results (never held across dispatch)"),
    "membership.lock": (
        "ElasticMembership._lock",
        "outermost lock of a membership transition"),
    "cluster.node_lock": (
        "_Node.lock",
        "one node's store/clock rebinds (read-dispatch-write)"),
    "cluster.outbox_lock": (
        "Cluster._outbox_lock",
        "per-link replication outboxes + fencing epochs (ack/retry)"),
    "health.lock": (
        "HealthMonitor._lock",
        "heartbeat records and per-observer reachability views"),
    # ---- leaves ----------------------------------------------------------
    "cluster.delivery_lock": (
        "_DeliveryQueue.lock",
        "one node's pending replication deliveries"),
    "network.fault_lock": (
        "FaultPlane._lock",
        "fault specs, named partitions, per-link send counters"),
    "cluster.repl_lock": (
        "Cluster._repl_lock",
        "replication_bytes accounting"),
    "engine.cycle_state_lock": (
        "_Cycle.lock",
        "per-cycle coalesced replication map"),
    "engine.pool_lock": (
        "_NodePool._lock",
        "executor slot table of the parallel pump"),
    "engine.trace_lock": (
        "engine._trace_lock",
        "fold_trace debug recording"),
    "stats.lock": (
        "AtomicStats._lock",
        "counter read-modify-writes (every stats dataclass)"),
    "naming.lock": (
        "NamingService._lock",
        "control-plane registry (pure dict ops)"),
    "checkpoint.lock": (
        "CheckpointManager._lock",
        "writer-thread handoff"),
}

#: Locks that never wrap another acquisition.  Anything may take a leaf;
#: nothing may be acquired while holding one.
LEAF_LOCKS: FrozenSet[str] = frozenset({
    "cluster.delivery_lock",
    "cluster.repl_lock",
    "network.fault_lock",
    "engine.cycle_state_lock",
    "engine.pool_lock",
    "engine.trace_lock",
    "stats.lock",
    "naming.lock",
    "checkpoint.lock",
})

#: Direct outer -> inner edges (the transitive closure is what ``allowed``
#: answers).  The third element annotates WHY the edge exists; edges born
#: from the mid-cycle delivery path carry the "on_ready" tag.
ORDER_EDGES: Tuple[Tuple[str, str, Optional[str]], ...] = (
    ("server.pump_lock", "server.cond", None),
    ("server.pump_lock", "router.lock", None),
    ("server.pump_lock", "engine.cycle_lock", None),
    ("server.cond", "router.lock", None),
    ("router.lock", "engine.qlock", None),
    ("engine.cycle_lock", "engine.qlock", None),
    ("engine.cycle_lock", "cluster.node_lock", None),
    ("engine.cycle_lock", "router.lock", "on_ready"),
    ("engine.cycle_lock", "server.cond", "on_ready"),
    ("membership.lock", "cluster.node_lock", None),
    # bump_fence / drop_pending_deliveries run inside membership
    # transitions; the drain acks (outbox surgery) under the node lock
    ("membership.lock", "cluster.outbox_lock", None),
    ("cluster.node_lock", "cluster.outbox_lock", None),
    ("cluster.node_lock", "cluster.delivery_lock", None),
    # the transport pump pushes arrivals into the target's delivery queue
    # while walking the link's outbox
    ("cluster.outbox_lock", "cluster.delivery_lock", None),
)

# --------------------------------------------------------------------------
# shared checker tables
# --------------------------------------------------------------------------

#: (class name, attribute) -> the lock that must be held to ``+=`` it.
#: These are the deliberate raw-increment sites: hot-path counters whose
#: guard is an existing lock rather than ``AtomicStats.inc``.
GUARDED_FIELDS: Dict[Tuple[str, str], str] = {
    ("BatchedInvocationEngine", "_tickets"): "engine.qlock",
    ("FaasServer", "_submit_gen"): "server.cond",
    ("Cluster", "replication_bytes"): "cluster.repl_lock",
}

#: Classes whose instances are touched from more than one thread: a bare
#: ``self.<attr> += 1`` with no lock held is a lost-update race unless the
#: site (or class) carries a ``# lockcheck: single-threaded`` annotation.
THREADED_CLASSES: FrozenSet[str] = frozenset({
    "BatchedInvocationEngine",
    "_CycleRun",
    "_NodePool",
    "Router",
    "FaasServer",
    "Cluster",
    "_Node",
    "_DeliveryQueue",
    "ElasticMembership",
    "NamingService",
    "FaultPlane",
    "HealthMonitor",
})

#: Lock-attribute names that identify a lock unambiguously, module-wide.
LOCK_ATTRS: Dict[str, str] = {
    "_qlock": "engine.qlock",
    "_cycle_lock": "engine.cycle_lock",
    "_pump_lock": "server.pump_lock",
    "_cond": "server.cond",
    "_repl_lock": "cluster.repl_lock",
    "_trace_lock": "engine.trace_lock",
    "_outbox_lock": "cluster.outbox_lock",
}

#: ``self._lock`` resolves by ENCLOSING CLASS (many classes reuse the
#: attribute name).  Classes absent here have untracked ``_lock``s — the
#: lint skips them rather than guessing.
CLASS_LOCK_ATTRS: Dict[str, str] = {
    "Router": "router.lock",
    "AtomicStats": "stats.lock",
    "RouterStats": "stats.lock",
    "NamingService": "naming.lock",
    "ElasticMembership": "membership.lock",
    "_NodePool": "engine.pool_lock",
    "CheckpointManager": "checkpoint.lock",
    "FaultPlane": "network.fault_lock",
    "HealthMonitor": "health.lock",
}

#: Calls that reach a device dispatch / the JAX runtime — forbidden
#: lexically under ``engine.qlock`` (the queue lock must never be held
#: across a dispatch; ``submit`` would wait on the flush in flight).
DISPATCH_CALL_NAMES: FrozenSet[str] = frozenset({
    "dispatch", "invoke", "invoke_batch", "pump", "flush", "_run_cycle",
    "merge_stores_jit", "merge_snapshots_fused", "arena_clone",
    "block_until_ready", "device_get", "device_put",
    "jit",
})
DISPATCH_CALL_PREFIXES: Tuple[str, ...] = ("_exec_",)
JAX_ROOTS: FrozenSet[str] = frozenset({"jax", "jnp", "pl", "pallas"})

#: Method/function names that block the calling thread — forbidden under
#: any non-leaf lock (a ``Condition.wait`` on the very condition being
#: held is the one sanctioned pattern; the lint special-cases it).
BLOCKING_CALL_NAMES: FrozenSet[str] = frozenset({
    "sleep", "result", "join", "wait", "wait_for", "shutdown",
})

# --------------------------------------------------------------------------
# order queries
# --------------------------------------------------------------------------


def _closure() -> Dict[str, FrozenSet[str]]:
    adj: Dict[str, set] = {}
    for a, b, _ in ORDER_EDGES:
        adj.setdefault(a, set()).add(b)
    out: Dict[str, FrozenSet[str]] = {}
    for start in LOCKS:
        seen: set = set()
        stack = list(adj.get(start, ()))
        while stack:
            n = stack.pop()
            if n in seen:
                continue
            seen.add(n)
            stack.extend(adj.get(n, ()))
        out[start] = frozenset(seen)
    return out


_REACHABLE = _closure()


def allowed(outer: str, inner: str) -> bool:
    """May ``inner`` be acquired while ``outer`` is held?

    Unknown names are permitted (record-only for the runtime validator);
    ``outer == inner`` is NOT answered here — reentrancy is an instance
    property the callers decide (the static lint assumes same-name
    nesting is a reentrant RLock; the runtime validator compares
    identity and treats two distinct peers as a violation).
    """
    if outer not in LOCKS or inner not in LOCKS:
        return True
    if outer in LEAF_LOCKS:
        return False
    if inner in LEAF_LOCKS:
        return True
    return inner in _REACHABLE.get(outer, frozenset())


def assert_dag() -> None:
    """Validate the declaration itself: known endpoints, no outgoing
    edges from leaves, and an acyclic edge set."""
    for a, b, _ in ORDER_EDGES:
        if a not in LOCKS or b not in LOCKS:
            raise AssertionError(f"LOCK_ORDER edge with unknown lock: "
                                 f"{a!r} -> {b!r}")
        if a in LEAF_LOCKS:
            raise AssertionError(f"leaf lock {a!r} has an outgoing edge")
    for name, reach in _REACHABLE.items():
        if name in reach:
            raise AssertionError(f"LOCK_ORDER cycle through {name!r}")


assert_dag()

# --------------------------------------------------------------------------
# docs generation (docs/batched_engine.md hierarchy block)
# --------------------------------------------------------------------------

DOC_BEGIN = ("<!-- LOCK_ORDER:begin — generated from "
             "src/repro/analysis/lock_order.py; edit the spec and run "
             "`python -m repro.analysis.lock_order --write` -->")
DOC_END = "<!-- LOCK_ORDER:end -->"


def _topo_nonleaf() -> list:
    """Deterministic topological order of the non-leaf locks (Kahn,
    alphabetical tie-break)."""
    nodes = sorted(n for n in LOCKS if n not in LEAF_LOCKS)
    indeg = {n: 0 for n in nodes}
    for a, b, _ in ORDER_EDGES:
        if b in indeg:
            indeg[b] += 1
    order, ready = [], sorted(n for n in nodes if indeg[n] == 0)
    while ready:
        n = ready.pop(0)
        order.append(n)
        for a, b, _ in ORDER_EDGES:
            if a == n and b in indeg:
                indeg[b] -= 1
                if indeg[b] == 0:
                    ready.append(b)
        ready.sort()
    return order


def render_doc_block() -> str:
    """The generated hierarchy block, markers included."""
    lines = [DOC_BEGIN, "", "```text"]
    for name in _topo_nonleaf():
        attr, desc = LOCKS[name]
        lines.append(f"{name:<20} {attr:<26} {desc}")
        succ = sorted((b, note) for a, b, note in ORDER_EDGES if a == name)
        if succ:
            parts = [b + (f" [{note}]" if note else "") for b, note in succ]
            lines.append(f"{'':20} > may nest: " + ", ".join(parts))
    lines.append("")
    lines.append("leaf locks (anything may take one; nothing is ever "
                 "acquired under one):")
    for name in sorted(LEAF_LOCKS):
        attr, desc = LOCKS[name]
        lines.append(f"  {name:<22} {attr:<24} {desc}")
    lines.append("```")
    lines.append("")
    lines.append(DOC_END)
    return "\n".join(lines)


def _default_doc_path() -> pathlib.Path:
    return (pathlib.Path(__file__).resolve().parents[3]
            / "docs" / "batched_engine.md")


def extract_doc_block(text: str) -> Optional[str]:
    i, j = text.find(DOC_BEGIN), text.find(DOC_END)
    if i < 0 or j < 0:
        return None
    return text[i:j + len(DOC_END)]


def check_docs(path: Optional[pathlib.Path] = None) -> bool:
    """True when the docs hierarchy block matches the spec."""
    path = path or _default_doc_path()
    return extract_doc_block(path.read_text()) == render_doc_block()


def sync_docs(path: Optional[pathlib.Path] = None) -> None:
    path = path or _default_doc_path()
    text = path.read_text()
    current = extract_doc_block(text)
    if current is None:
        raise SystemExit(f"{path}: LOCK_ORDER markers not found")
    path.write_text(text.replace(current, render_doc_block()))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="check or regenerate the docs lock-hierarchy block")
    ap.add_argument("--write", action="store_true",
                    help="rewrite the block in docs/batched_engine.md")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when the docs block drifted (default)")
    ap.add_argument("--path", type=pathlib.Path, default=None)
    args = ap.parse_args(argv)
    if args.write:
        sync_docs(args.path)
        print("LOCK_ORDER docs block regenerated")
        return 0
    if check_docs(args.path):
        print("LOCK_ORDER docs block up to date")
        return 0
    print("LOCK_ORDER docs block drifted from lock_order.py — run "
          "`python -m repro.analysis.lock_order --write`")
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
