"""Heartbeat-based health monitoring with per-observer reachability views.

Nodes (pods/hosts) report (step, wall_time) heartbeats; the monitor keeps
TWO pictures of them:

* the legacy global view (``_beats``: last heartbeat each node SENT) —
  ``dead_nodes``/``stragglers``/``fleet_step`` read it, unchanged;
* per-observer reachability views (``_views``: the last heartbeat each
  OBSERVER received from each node).  A heartbeat reaches an observer only
  if the cluster's ``FaultPlane`` (when attached) says the pair is not
  partitioned, so a partition makes the victim silent to one side of the
  cut while the other side keeps hearing it.

``verdict(node)`` aggregates the views: a node silent to a QUORUM of live
observers (majority by default) is "dead"; silent to at least one but
fewer than quorum — the signature of a partition, not a crash — is
"suspect"; otherwise "alive".  ``ElasticMembership.poll`` drives its
ALIVE/SUSPECT/DEAD transitions off these verdicts.

Heartbeats are treated as small and frequent: partitions block them, but
per-link drop/jitter faults do not (a lost heartbeat is re-sent long
before any timeout; modelling individual losses would only add noise to
the suspicion signal).

Resurrection contract: ``dead_nodes``/``verdict`` are PURE — they never
touch the naming service (the old getter marked nodes dead in naming as a
side effect, and nothing ever cleared it).  Naming liveness is owned by
``ElasticMembership``: a crash marks dead, and only ``restore`` may
revive — a late ``beat()`` from a node already declared dead must NOT
silently flip naming back.  ``resurrect`` (called by restore) clears the
node's stale beat/view records so the restored node is not instantly
re-condemned by its pre-crash silence.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

from repro.analysis import lockdep
from repro.core.naming import NamingService

# verdict values (string-compatible with runtime/elastic.py's states)
ALIVE = "alive"
SUSPECT = "suspect"
DEAD = "dead"


@dataclasses.dataclass
class Heartbeat:
    step: int
    t: float


class HealthMonitor:
    def __init__(self, naming: Optional[NamingService] = None,
                 timeout_s: float = 30.0, lag_steps: int = 50,
                 plane=None, quorum: Optional[int] = None):
        self.naming = naming
        self.timeout_s = timeout_s
        self.lag_steps = lag_steps
        #: optional core.network.FaultPlane: gates which observers a
        #: heartbeat reaches (partitioned pairs hear nothing)
        self.plane = plane
        #: observers that must agree on silence to confirm a death;
        #: None = majority of live observers (floor(n/2) + 1)
        self.quorum = quorum
        self._lock = lockdep.make_lock("health.lock")
        self._beats: Dict[str, Heartbeat] = {}
        # observer -> {node: last heartbeat RECEIVED from node}
        self._views: Dict[str, Dict[str, Heartbeat]] = {}

    # ----------------------------------------------------------------- feeds
    def beat(self, node: str, step: int, t: Optional[float] = None) -> None:
        hb = Heartbeat(step=step, t=t if t is not None else time.monotonic())
        with self._lock:
            self._beats[node] = hb
            for obs in self._observers():
                if obs == node:
                    continue
                if self.plane is not None and self.plane.partitioned(obs,
                                                                     node):
                    continue
                self._views.setdefault(obs, {})[node] = hb

    def resurrect(self, node: str) -> None:
        """Forget ``node``'s beat and every observer's view of it — called
        by ``ElasticMembership.restore`` so a freshly restored node is
        judged on heartbeats it sends AFTER the restore, not condemned
        again by its pre-crash silence."""
        with self._lock:
            self._beats.pop(node, None)
            for view in self._views.values():
                view.pop(node, None)

    def _observers(self) -> List[str]:
        """Who receives heartbeats: every live registered node when a
        naming service is attached (suspects still observe), else every
        node that has ever beaten (bare monitors)."""
        if self.naming is not None:
            return self.naming.alive_nodes()
        return list(self._beats)

    # -------------------------------------------------------------- verdicts
    def dead_nodes(self, now: Optional[float] = None) -> List[str]:
        """Nodes whose last SENT heartbeat timed out.  PURE: unlike the
        historical version this never marks anything dead in naming —
        declaring a death (and reviving from one) is the membership's
        call, not a getter side effect."""
        now = now if now is not None else time.monotonic()
        with self._lock:
            return [n for n, hb in self._beats.items()
                    if now - hb.t > self.timeout_s]

    def unreachable(self, observer: str, node: str,
                    now: Optional[float] = None) -> bool:
        """Whether ``observer``'s view of ``node`` has timed out (or never
        existed while the node demonstrably beats)."""
        now = now if now is not None else time.monotonic()
        with self._lock:
            if node not in self._beats:
                return False        # never beat: no evidence either way
            hb = self._views.get(observer, {}).get(node)
            return hb is None or now - hb.t > self.timeout_s

    def verdict(self, node: str, now: Optional[float] = None
                ) -> str:
        """Aggregate the observers: ``dead`` when >= quorum of live
        observers find ``node`` silent, ``suspect`` when at least one
        (but fewer than quorum) does, else ``alive``."""
        state, _, _ = self.verdict_detail(node, now)
        return state

    def verdict_detail(self, node: str, now: Optional[float] = None
                       ) -> Tuple[str, int, int]:
        """``(verdict, silent_observers, total_observers)``."""
        now = now if now is not None else time.monotonic()
        with self._lock:
            if node not in self._beats:
                return (ALIVE, 0, 0)    # never beat: cannot be judged
            obs = [o for o in self._observers() if o != node]
            if not obs:
                # nobody else to ask: fall back to the global timeout
                dead = now - self._beats[node].t > self.timeout_s
                return (DEAD if dead else ALIVE, int(dead), 0)
            silent = 0
            for o in obs:
                hb = self._views.get(o, {}).get(node)
                if hb is None or now - hb.t > self.timeout_s:
                    silent += 1
            q = self.quorum if self.quorum is not None \
                else len(obs) // 2 + 1
            if silent >= q:
                return (DEAD, silent, len(obs))
            if silent > 0:
                return (SUSPECT, silent, len(obs))
            return (ALIVE, 0, len(obs))

    # ------------------------------------------------------------ stragglers
    def stragglers(self) -> List[str]:
        with self._lock:
            if not self._beats:
                return []
            steps = sorted(hb.step for hb in self._beats.values())
            median = steps[len(steps) // 2]
            return [n for n, hb in self._beats.items()
                    if median - hb.step > self.lag_steps]

    def fleet_step(self) -> int:
        with self._lock:
            return min((hb.step for hb in self._beats.values()), default=0)
