"""Heartbeat-based health monitoring (control plane).

Nodes (pods/hosts) report (step, wall_time) heartbeats; the monitor flags
nodes as dead after ``timeout_s`` of silence and as stragglers when their
reported step lags the fleet median by more than ``lag_steps``.  Feeds the
naming service's liveness view (router and elastic re-mesh read from it).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.core.naming import NamingService


@dataclasses.dataclass
class Heartbeat:
    step: int
    t: float


class HealthMonitor:
    def __init__(self, naming: Optional[NamingService] = None,
                 timeout_s: float = 30.0, lag_steps: int = 50):
        self.naming = naming
        self.timeout_s = timeout_s
        self.lag_steps = lag_steps
        self._beats: Dict[str, Heartbeat] = {}

    def beat(self, node: str, step: int, t: Optional[float] = None) -> None:
        self._beats[node] = Heartbeat(step=step, t=t if t is not None
                                      else time.monotonic())

    def dead_nodes(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.monotonic()
        dead = [n for n, hb in self._beats.items()
                if now - hb.t > self.timeout_s]
        if self.naming is not None:
            for n in dead:
                self.naming.mark_dead(n)
        return dead

    def stragglers(self) -> List[str]:
        if not self._beats:
            return []
        steps = sorted(hb.step for hb in self._beats.values())
        median = steps[len(steps) // 2]
        return [n for n, hb in self._beats.items()
                if median - hb.step > self.lag_steps]

    def fleet_step(self) -> int:
        return min((hb.step for hb in self._beats.values()), default=0)
