"""Straggler mitigation policies.

Enoki's asynchronous replication IS the training-side straggler story: a pod
that misses an anti-entropy round merges late with bounded staleness instead
of stalling the fleet (contrast synchronous DP, where the slowest pod sets
the step time).  ``StragglerPolicy`` tracks per-pod round participation and
decides merge admission; serving-side hedging lives in core/router.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set


@dataclasses.dataclass
class StragglerPolicy:
    max_staleness_rounds: int = 2     # a pod may lag this many rounds
    quorum_frac: float = 0.5          # proceed when this fraction arrived

    def __post_init__(self):
        self.last_round: Dict[str, int] = {}

    def report(self, pod: str, round_id: int) -> None:
        self.last_round[pod] = max(self.last_round.get(pod, -1), round_id)

    def can_proceed(self, round_id: int, expected: List[str]) -> bool:
        """Anti-entropy may fold in whoever arrived once a quorum is in."""
        arrived = sum(1 for p in expected
                      if self.last_round.get(p, -1) >= round_id)
        return arrived >= max(1, int(len(expected) * self.quorum_frac))

    def too_stale(self, pod: str, round_id: int) -> bool:
        """A pod beyond the staleness bound must restore from peers
        (checkpoint/keygroup) instead of merging its divergent state."""
        return round_id - self.last_round.get(pod, -1) \
            > self.max_staleness_rounds

    def laggards(self, round_id: int, expected: List[str]) -> List[str]:
        return [p for p in expected
                if self.last_round.get(p, -1) < round_id]
