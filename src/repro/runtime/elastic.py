"""Elastic membership: node join/leave/crash as first-class serving events.

Two layers live here:

* ``ElasticMembership`` — the recovery state machine over a ``Cluster``.
  Nodes move ALIVE -> DEAD (crash or health timeout) -> ALIVE (restore with
  keygroup catch-up) or ALIVE -> LEFT (graceful leave with replica
  hand-off); JOINING nodes register empty and serve only after deploy.
  A crash rebalances the dead node's keygroups to surviving replicas —
  falling back to checkpoint-restore (``checkpoint/manager.py``) and then
  to a fresh arena when no live replica holds the state — and drops the
  replication deliveries still on the wire TO the dead node, so the
  engine's dead-node eviction can fail the affected tickets fast
  (at-most-once) instead of hanging the serving thread.

* mesh re-meshing helpers (``degraded_mesh_config``/``make_mesh``/
  ``remesh``) — the accelerator-fleet analogue: the ``pod`` axis shrinks
  (replication domain — Enoki keygroups survive on peer replicas), the
  intra-pod ``data``×``model`` grid is preserved.  ``remesh`` moves live
  state onto the new mesh via device_put with re-derived shardings.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis import lockdep
from repro.configs.base import MeshConfig, ReplicationPolicy
from repro.core.engine import AtomicStats
from repro.core.keygroup import arena_new
from repro.core.store import arena_clone
from repro.core.versioning import MAX_NODES

# -- membership states ------------------------------------------------------
ALIVE = "alive"
SUSPECT = "suspect"  # silent to a MINORITY of observers (partition, not
                     # crash): no rebalance, replicas intact, not routable
DEAD = "dead"       # crashed or quorum-confirmed silent; restorable
LEFT = "left"       # graceful departure; data handed off first


@dataclasses.dataclass
class MembershipStats(AtomicStats):
    crashes: int = 0
    restores: int = 0
    joins: int = 0
    leaves: int = 0
    rebalanced: int = 0             # keygroups re-homed off a dead node
    re_replicated: int = 0          # copies made to restore min_replicas
    checkpoint_restores: int = 0    # sole-replica keygroups revived from disk
    fresh_restores: int = 0         # ...or lost entirely (fresh arena)
    caught_up: int = 0              # keygroups caught up on rejoin
    dropped_deliveries: int = 0     # replication events lost with a crash
    suspects: int = 0               # ALIVE -> SUSPECT transitions
    false_suspects: int = 0         # SUSPECT -> ALIVE (reachability returned)
    epoch_rejections: int = 0       # stale-fencing-epoch deliveries rejected


class ElasticMembership:
    """The recovery state machine over a ``Cluster`` (see module docstring).

    Transitions:

        join    —  register a brand-new empty node (ALIVE once deployed to)
        crash   —  ALIVE -> DEAD: liveness off FIRST (the router's candidate
                   filter and the engine's dead-node eviction key off it),
                   then handlers stashed (a restore models restart-with-the-
                   same-binary, so nothing recompiles), on-the-wire
                   deliveries TO the node dropped, and every keygroup it
                   hosted rebalanced to the surviving replicas — checkpoint
                   or fresh-arena fallback when it held the last copy
        restore —  DEAD -> ALIVE: catch the node's keygroups up from a live
                   peer's replication-log view BEFORE flipping liveness, so
                   it never serves a stale read
        leave   —  ALIVE -> LEFT: hand sole replicas off, then depart

    ``poll`` bridges the health plane: any node a ``HealthMonitor`` newly
    reports dead is crashed through the same path as an injected kill.
    """

    def __init__(self, cluster, monitor=None,
                 checkpoint_dir: Optional[str] = None,
                 min_replicas: int = 1):
        self.cluster = cluster
        self.monitor = monitor
        self.checkpoint_dir = checkpoint_dir
        self.min_replicas = max(1, int(min_replicas))
        self.stats = MembershipStats()
        self.state: Dict[str, str] = {n: ALIVE for n in cluster.nodes}
        # restart-with-same-binary stash: (handlers, batched, compute_ms)
        self._stash: Dict[str, Tuple[dict, dict, dict]] = {}
        # which keygroups each dead node hosted at crash time (rejoin set)
        self._hosted: Dict[str, Set[str]] = {}
        self._ckpt_mgrs: Dict[str, Any] = {}
        # outermost lock of a membership transition; cluster node/queue
        # locks nest inside it, and nothing here is called under them
        self._lock = lockdep.make_rlock("membership.lock")
        # back-reference: the drain reports stale-epoch rejections here
        cluster.membership = self

    # ------------------------------------------------------------ checkpoints
    def _ckpt(self, node: str):
        if self.checkpoint_dir is None:
            return None
        mgr = self._ckpt_mgrs.get(node)
        if mgr is None:
            from repro.checkpoint.manager import CheckpointManager
            mgr = CheckpointManager(os.path.join(self.checkpoint_dir, node))
            self._ckpt_mgrs[node] = mgr
        return mgr

    def checkpoint(self, node: str, step: int = 0) -> bool:
        """Persist ``node``'s keygroup stores (atomic, blocking).  The
        crash path restores from the latest of these when the node held
        the LAST live copy of a keygroup."""
        mgr = self._ckpt(node)
        if mgr is None:
            return False
        with self._lock:
            nd = self.cluster.nodes[node]
            with nd.lock:
                stores = dict(nd.stores)
            mgr.save(step, stores, blocking=True)
        return True

    def _restore_from_checkpoint(self, node: str, kg: str):
        """The dead node's latest checkpointed copy of ``kg``, or None."""
        mgr = self._ckpt(node)
        if mgr is None or mgr.latest_step() is None:
            return None
        kspec = self.cluster.policies[kg]
        template = {kg: arena_new(kspec, MAX_NODES)}
        try:
            return mgr.restore(template)[kg]
        except (KeyError, ValueError, IOError):
            return None         # kg not in the checkpoint (or corrupted)

    # ------------------------------------------------------------ transitions
    def join(self, name: str, kind: str = "edge") -> None:
        """Register a NEW empty node.  It serves a function only after a
        ``cluster.deploy`` (which compiles handlers and places keygroups);
        until then the router never picks it."""
        with self._lock:
            self.cluster.add_node(name, kind)
            self.state[name] = ALIVE
            self.stats.inc("joins")

    def crash(self, node: str) -> Dict[str, str]:
        """Kill ``node`` and rebalance.  Returns ``{keygroup: new_home}``
        for every keygroup whose LAST live copy was here (re-homed to a
        survivor via checkpoint/fresh restore); keygroups with surviving
        replicas just lose this member."""
        with self._lock:
            rehomed = self._down(node)
            if rehomed is None:
                return {}
            self.stats.inc("crashes")
            return rehomed

    def _down(self, node: str) -> Optional[Dict[str, str]]:
        """The shared take-a-node-dark path of ``crash`` and ``leave``.
        Returns the rehome map, or None when the node was not ALIVE (a
        SUSPECT node quorum-confirmed dead crashes through here too)."""
        c = self.cluster
        with self._lock:
            if self.state.get(node) not in (ALIVE, SUSPECT):
                return None
            self.state[node] = DEAD
            # 1. liveness off first: router candidates, engine eviction and
            #    _nearest_deployment all read it (mark_dead also clears any
            #    suspect flag)
            c.naming.mark_dead(node)
            nd = c.nodes[node]
            with nd.lock:
                self._stash[node] = (dict(nd.handlers),
                                     dict(nd.batched_handlers),
                                     dict(nd.compute_ms))
                nd.handlers.clear()
                nd.batched_handlers.clear()
                lost = dict(nd.stores)
                nd.stores.clear()
            # 2. what was on the wire TO the node dies with it
            self.stats.inc("dropped_deliveries",
                           c.drop_pending_deliveries(node))
            # 3. rebalance its keygroups — each bumps its fencing epoch
            #    FIRST, so any snapshot the dead node (or a peer) stamped
            #    before this crash is rejected at delivery instead of
            #    resurrecting pre-crash state past the rebalance
            self._hosted[node] = set(lost)
            rehomed: Dict[str, str] = {}
            for kg in sorted(lost):
                c.bump_fence(kg)
                c.naming.remove_replica(kg, node)
                target = self._rebalance(node, kg)
                if target is not None:
                    rehomed[kg] = target
            return rehomed

    def _alive_targets(self, near: str) -> List[str]:
        """ROUTABLE nodes sorted nearest-first from ``near`` (cloud nodes
        break RTT ties last, so edge keygroups prefer edge survivors).
        Suspect nodes are excluded: re-homing state onto a node the
        majority cannot reach would strand it."""
        c = self.cluster
        alive = [n for n in c.naming.routable_nodes() if n in c.nodes]
        return sorted(alive, key=lambda n: (c.net.rtt_ms(near, n),
                                            c.nodes[n].kind == "cloud", n))

    def _rebalance(self, dead: str, kg: str) -> Optional[str]:
        """Re-home ``kg`` after ``dead`` lost its copy: pick a survivor,
        restore state (live replica > checkpoint > fresh arena), re-home
        the owner of owner-placed policies, and top the replica set back
        up to ``min_replicas``.  Returns the new home when the dead node
        held the last copy, else None."""
        c = self.cluster
        kspec = c.policies[kg]
        live = [r for r in c.naming.replicas_of(kg)
                if c.naming.is_alive(r)]
        new_home: Optional[str] = None
        if not live:
            targets = self._alive_targets(dead)
            if kspec.policy == ReplicationPolicy.CLOUD_CENTRAL:
                # cloud-central state belongs on a cloud node when one lives
                clouds = [n for n in targets if c.nodes[n].kind == "cloud"]
                targets = clouds + [n for n in targets if n not in clouds]
            if not targets:
                return None     # whole cluster down: nothing to re-home to
            new_home = targets[0]
            store = self._restore_from_checkpoint(dead, kg)
            if store is not None:
                self.stats.inc("checkpoint_restores")
            else:
                # blank_arena, not arena_new: the rebuilt replica must
                # carry the keygroup's canonical slot layout to stay
                # merge-aligned with its peers
                store = c.blank_arena(kg, kspec)
                self.stats.inc("fresh_restores")
            tnd = c.nodes[new_home]
            with tnd.lock:
                tnd.stores[kg] = store
            c.naming.add_replica(kg, new_home)
            live = [new_home]
            self.stats.inc("rebalanced")
        if kspec.owner == dead:
            # owner-placed policies must point at a live store
            owner = new_home or live[0]
            c.policies[kg] = dataclasses.replace(kspec, owner=owner)
            rec = c.naming.keygroup(kg)
            if rec is not None:
                rec.spec = c.policies[kg]
        # top the replica set back up (REPLICATED only — owner policies
        # keep a single placed copy by design)
        if c.policies[kg].policy == ReplicationPolicy.REPLICATED:
            for cand in self._alive_targets(live[0]):
                if len(live) >= self.min_replicas:
                    break
                if cand in live:
                    continue
                src = c.nodes[live[0]]
                with src.lock:
                    # clone, never share: replicas with aliased arenas
                    # break under buffer donation (TPU/GPU folds
                    # invalidate the donated input)
                    snapshot = arena_clone(src.stores[kg])
                cnd = c.nodes[cand]
                with cnd.lock:
                    cnd.stores[kg] = snapshot
                c.naming.add_replica(kg, cand)
                live.append(cand)
                self.stats.inc("re_replicated")
        return new_home

    def restore(self, node: str, t: float = float("inf")) -> List[str]:
        """Bring a DEAD node back: re-install its stashed handlers, catch
        its keygroups up from a live peer's view of the replication log as
        of ``t``, and only THEN mark it alive.  Returns the keygroups
        caught up."""
        c = self.cluster
        with self._lock:
            if self.state.get(node) != DEAD:
                raise ValueError(f"{node!r} is not dead (state="
                                 f"{self.state.get(node)!r})")
            nd = c.nodes[node]
            handlers, batched, compute = self._stash.pop(
                node, ({}, {}, {}))
            with nd.lock:
                nd.handlers.update(handlers)
                nd.batched_handlers.update(batched)
                nd.compute_ms.update(compute)
            caught = []
            for kg in sorted(self._hosted.pop(node, set())):
                kspec = c.policies[kg]
                if (kspec.policy != ReplicationPolicy.REPLICATED
                        and kspec.owner != node):
                    continue    # owner re-homed while we were down: the
                                # store stays there (placement stability)
                peers = [r for r in c.naming.replicas_of(kg)
                         if r != node and c.naming.is_alive(r)]
                if peers:
                    # catch-up: fold the peer's pending deliveries up to
                    # ``t`` first, so the snapshot we copy reflects the
                    # replication log, then take it wholesale
                    src = min(peers, key=lambda p: c.net.rtt_ms(node, p))
                    c._deliver_until(src, t)
                    snd = c.nodes[src]
                    with snd.lock:
                        snapshot = arena_clone(snd.stores[kg])
                else:
                    snapshot = (self._restore_from_checkpoint(node, kg)
                                or c.blank_arena(kg, kspec))
                with nd.lock:
                    nd.stores[kg] = snapshot
                c.naming.add_replica(kg, node)
                caught.append(kg)
                self.stats.inc("caught_up")
            # liveness LAST: the node is fully caught up before the
            # router's candidate filter can see it.  The health monitor
            # forgets the node's pre-crash silence — the resurrection
            # contract: only THIS path revives a node; a stray beat from a
            # dead node never flips naming back by itself, and a restored
            # node is not instantly re-condemned by stale views.
            if self.monitor is not None:
                resurrect = getattr(self.monitor, "resurrect", None)
                if resurrect is not None:
                    resurrect(node)
            c.naming.mark_alive(node)
            self.state[node] = ALIVE
            self.stats.inc("restores")
            return caught

    def leave(self, node: str, t: float = float("inf")) -> None:
        """Graceful departure: every keygroup this node is the last (or
        owner) copy of is handed off to a survivor FIRST — deliveries up
        to ``t`` folded in, so nothing on the wire is lost — then the node
        goes dark through the crash path (which now finds every keygroup
        safely replicated elsewhere)."""
        c = self.cluster
        with self._lock:
            if self.state.get(node) != ALIVE:
                return
            nd = c.nodes[node]
            c._deliver_until(node, t)       # fold what already arrived
            with nd.lock:
                hosted = dict(nd.stores)
            for kg in sorted(hosted):
                kspec = c.policies[kg]
                others = [r for r in c.naming.replicas_of(kg)
                          if r != node and c.naming.is_alive(r)]
                if others and kspec.owner != node:
                    continue
                targets = [n for n in self._alive_targets(node)
                           if n != node and n not in others]
                if not targets:
                    continue    # last node standing: crash path persists it
                target = targets[0]
                tnd = c.nodes[target]
                with nd.lock:
                    snapshot = arena_clone(nd.stores[kg])
                with tnd.lock:
                    tnd.stores[kg] = snapshot
                c.naming.add_replica(kg, target)
                if kspec.owner == node:
                    c.policies[kg] = dataclasses.replace(kspec, owner=target)
                    rec = c.naming.keygroup(kg)
                    if rec is not None:
                        rec.spec = c.policies[kg]
            self._down(node)
            self.state[node] = LEFT
            self.stats.inc("leaves")

    # ------------------------------------------------------------ health plane
    def suspect(self, node: str) -> bool:
        """ALIVE -> SUSPECT: a minority of observers finds the node silent
        (partition signature).  The node drops out of the routable set —
        the router stops picking it and the engine reroutes its queued
        windows — but NOTHING is torn down: replicas stay, replication
        keeps queueing to its outboxes, no rebalance fires.  Clears by
        ``unsuspect`` (reachability returns) or hardens into a crash when
        a quorum confirms the silence."""
        with self._lock:
            if self.state.get(node) != ALIVE:
                return False
            self.state[node] = SUSPECT
            self.cluster.naming.mark_suspect(node)
            self.stats.inc("suspects")
            return True

    def unsuspect(self, node: str) -> bool:
        """SUSPECT -> ALIVE: the partition healed (or the suspicion was
        wrong) — the node becomes routable again with no catch-up needed,
        because nothing was torn down and its outbox backlog delivers on
        the healed links."""
        with self._lock:
            if self.state.get(node) != SUSPECT:
                return False
            self.state[node] = ALIVE
            self.cluster.naming.clear_suspect(node)
            self.stats.inc("false_suspects")
            return True

    def poll(self, now: Optional[float] = None) -> List[str]:
        """Drive ALIVE/SUSPECT/DEAD off the health monitor's per-observer
        verdicts: quorum-confirmed silence crashes the node (same path as
        an injected kill — within ONE poll of the views timing out), a
        minority view parks it SUSPECT, and a clean bill un-suspects it.
        Monitors without per-observer views (anything exposing only
        ``dead_nodes``) degrade to the historical crash-on-timeout.  A
        serving loop calls this each wakeup; returns the nodes crashed
        this call."""
        if self.monitor is None:
            return []
        crashed = []
        verdict = getattr(self.monitor, "verdict", None)
        if verdict is None:                     # legacy monitor shape
            for n in self.monitor.dead_nodes(now):
                with self._lock:
                    if self.state.get(n) == ALIVE:
                        self.crash(n)
                        crashed.append(n)
            return crashed
        for n, st in list(self.state.items()):
            if st not in (ALIVE, SUSPECT):
                continue
            v = verdict(n, now)
            if v == DEAD:
                with self._lock:
                    if self.state.get(n) in (ALIVE, SUSPECT):
                        self.crash(n)
                        crashed.append(n)
            elif v == SUSPECT and st == ALIVE:
                self.suspect(n)
            elif v == ALIVE and st == SUSPECT:
                self.unsuspect(n)
        return crashed

    def alive(self) -> List[str]:
        return [n for n, s in self.state.items() if s == ALIVE]


def degraded_mesh_config(cfg: MeshConfig, alive_pods: int) -> MeshConfig:
    """New mesh config after pod failures.  Single-pod meshes degrade by
    shrinking ``data`` (we keep ``model`` intact: TP groups are tightly
    coupled; losing part of one means losing the pod)."""
    if "pod" in cfg.axes:
        i = cfg.axes.index("pod")
        shape = list(cfg.shape)
        if alive_pods < 1:
            raise ValueError("no pods left")
        shape[i] = alive_pods
        if alive_pods == 1:
            # collapse the pod axis entirely
            shape = [s for j, s in enumerate(shape) if j != i]
            axes = tuple(a for a in cfg.axes if a != "pod")
            return MeshConfig(shape=tuple(shape), axes=axes)
        return MeshConfig(shape=tuple(shape), axes=cfg.axes)
    return cfg


def make_mesh(cfg: MeshConfig) -> Mesh:
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat(cfg.shape, cfg.axes)


def remesh(state: Any, old_specs: Any, new_mesh: Mesh) -> Any:
    """Re-place a pytree onto a new mesh.  PartitionSpecs referencing axes
    the new mesh lacks (e.g. 'pod' after collapse) are stripped."""
    names = set(new_mesh.axis_names)

    def fix_spec(spec: P) -> P:
        return P(*[(a if a in names else None) for a in spec])

    def place(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, fix_spec(spec)))

    return jax.tree.map(place, state, old_specs,
                        is_leaf=lambda x: isinstance(x, P))
