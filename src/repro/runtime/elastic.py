"""Elastic re-meshing after pod loss / fleet resize.

The policy: the ``pod`` axis shrinks (replication domain — Enoki keygroups
survive on peer replicas), the intra-pod ``data``×``model`` grid is
preserved.  ``remesh`` moves live state onto the new mesh via device_put
with re-derived shardings; state that only existed on dead pods is restored
from peer keygroup replicas (caller) or from the last checkpoint.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig


def degraded_mesh_config(cfg: MeshConfig, alive_pods: int) -> MeshConfig:
    """New mesh config after pod failures.  Single-pod meshes degrade by
    shrinking ``data`` (we keep ``model`` intact: TP groups are tightly
    coupled; losing part of one means losing the pod)."""
    if "pod" in cfg.axes:
        i = cfg.axes.index("pod")
        shape = list(cfg.shape)
        if alive_pods < 1:
            raise ValueError("no pods left")
        shape[i] = alive_pods
        if alive_pods == 1:
            # collapse the pod axis entirely
            shape = [s for j, s in enumerate(shape) if j != i]
            axes = tuple(a for a in cfg.axes if a != "pod")
            return MeshConfig(shape=tuple(shape), axes=axes)
        return MeshConfig(shape=tuple(shape), axes=cfg.axes)
    return cfg


def make_mesh(cfg: MeshConfig) -> Mesh:
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat(cfg.shape, cfg.axes)


def remesh(state: Any, old_specs: Any, new_mesh: Mesh) -> Any:
    """Re-place a pytree onto a new mesh.  PartitionSpecs referencing axes
    the new mesh lacks (e.g. 'pod' after collapse) are stripped."""
    names = set(new_mesh.axis_names)

    def fix_spec(spec: P) -> P:
        return P(*[(a if a in names else None) for a in spec])

    def place(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, fix_spec(spec)))

    return jax.tree.map(place, state, old_specs,
                        is_leaf=lambda x: isinstance(x, P))
