"""Failure injection for integration tests (the chaos-monkey role).

Operates on the Cluster simulator and on logical pod replica lists: kill a
node (liveness + handler removal), corrupt or drop a keygroup replica,
partition links.  Recovery paths under test: router failover to surviving
deployments, keygroup restore from peer replicas (Enoki replication doubling
as fault tolerance), checkpoint fallback, elastic re-mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.cluster import Cluster
from repro.core.network import Link
from repro.core.store import arena_clone


@dataclasses.dataclass
class FailureInjector:
    cluster: Cluster
    #: optional ElasticMembership (runtime/elastic.py) — when set, kills
    #: route through the full recovery state machine (keygroup rebalance,
    #: checkpoint fallback, delivery-queue drop) instead of the bare
    #: liveness flip, and ``restore_node`` becomes available
    membership: Optional[object] = None

    def kill_node(self, node: str) -> None:
        """Mark dead + drop its handlers: requests must fail over.  With a
        membership attached this is a full crash (rebalance + drop of
        on-the-wire deliveries); bare injectors keep the historical
        minimal kill."""
        if self.membership is not None:
            self.membership.crash(node)
            return
        self.cluster.naming.mark_dead(node)
        self.cluster.nodes[node].handlers.clear()
        self.cluster.nodes[node].batched_handlers.clear()

    def restore_node(self, node: str, t: float = float("inf")) -> None:
        """Bring a killed node back through the membership's catch-up path
        (requires ``membership``)."""
        if self.membership is None:
            raise RuntimeError("restore_node needs a membership "
                               "(FailureInjector(cluster, membership=...))")
        self.membership.restore(node, t)

    def lose_keygroup(self, node: str, kg: str) -> None:
        """Simulate storage loss of one replica."""
        self.cluster.nodes[node].stores.pop(kg, None)
        self.cluster.naming.remove_replica(kg, node)

    def restore_keygroup_from_peer(self, node: str, kg: str) -> bool:
        """Enoki recovery: re-replicate from any surviving replica (§2)."""
        peers = self.cluster.naming.replicas_of(kg)
        alive = set(self.cluster.naming.alive_nodes())
        peers = [p for p in peers if p != node and p in alive]
        if not peers:
            return False
        src = self.cluster.nodes[peers[0]]
        with src.lock:
            # clone, never alias: a shared arena breaks under buffer
            # donation (the peer's next fold would invalidate our copy)
            snapshot = arena_clone(src.stores[kg])
        self.cluster.nodes[node].stores[kg] = snapshot
        self.cluster.naming.add_replica(kg, node)
        return True

    def partition(self, a: str, b: str) -> None:
        """Sever the a<->b link (infinite latency)."""
        self.cluster.net.links[(a, b)] = Link(rtt_ms=float("inf"),
                                              bandwidth_mbps=0.0)
        self.cluster.net.links[(b, a)] = Link(rtt_ms=float("inf"),
                                              bandwidth_mbps=0.0)

    def heal(self, a: str, b: str, link: Optional[Link] = None) -> None:
        link = link or Link(rtt_ms=20.0, bandwidth_mbps=100.0)
        self.cluster.net.links[(a, b)] = link
        self.cluster.net.links[(b, a)] = link
