"""Failure injection for integration tests (the chaos-monkey role).

Operates on the Cluster simulator and on logical pod replica lists: kill a
node (liveness + handler removal), corrupt or drop a keygroup replica,
partition links.  Recovery paths under test: router failover to surviving
deployments, keygroup restore from peer replicas (Enoki replication doubling
as fault tolerance), checkpoint fallback, elastic re-mesh.

Network faults route through the cluster's ``FaultPlane``
(core/network.py): a partition is a NAMED, heal-able cut the replication
transport retries across — snapshots scheduled mid-partition park in their
link outbox and deliver after ``heal`` — instead of the historical
``inf``-latency link swap, whose events stranded at ``arrival=inf``
forever.  Per-link loss/duplication/jitter faults ride the same plane.

``chaos_schedule``/``run_chaos`` form the seeded chaos harness: a
deterministic event schedule (per-round link faults, one multi-round
partition, one crash+restore after the heal) interleaved with a
round-structured write workload, built so a fault-free twin run with the
same seed produces BYTE-IDENTICAL final stores — the invariant the
partition-tolerance suite asserts.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.cluster import Cluster
from repro.core.network import Link
from repro.core.store import arena_clone


@dataclasses.dataclass
class FailureInjector:
    cluster: Cluster
    #: optional ElasticMembership (runtime/elastic.py) — when set, kills
    #: route through the full recovery state machine (keygroup rebalance,
    #: checkpoint fallback, delivery-queue drop) instead of the bare
    #: liveness flip, and ``restore_node`` becomes available
    membership: Optional[object] = None

    def kill_node(self, node: str) -> None:
        """Mark dead + drop its handlers: requests must fail over.  With a
        membership attached this is a full crash (rebalance + drop of
        on-the-wire deliveries); bare injectors keep the historical
        minimal kill."""
        if self.membership is not None:
            self.membership.crash(node)
            return
        self.cluster.naming.mark_dead(node)
        self.cluster.nodes[node].handlers.clear()
        self.cluster.nodes[node].batched_handlers.clear()

    def restore_node(self, node: str, t: float = float("inf")) -> None:
        """Bring a killed node back through the membership's catch-up path
        (requires ``membership``)."""
        if self.membership is None:
            raise RuntimeError("restore_node needs a membership "
                               "(FailureInjector(cluster, membership=...))")
        self.membership.restore(node, t)

    def lose_keygroup(self, node: str, kg: str) -> None:
        """Simulate storage loss of one replica."""
        self.cluster.nodes[node].stores.pop(kg, None)
        self.cluster.naming.remove_replica(kg, node)

    def restore_keygroup_from_peer(self, node: str, kg: str) -> bool:
        """Enoki recovery: re-replicate from any surviving replica (§2)."""
        peers = self.cluster.naming.replicas_of(kg)
        alive = set(self.cluster.naming.alive_nodes())
        peers = [p for p in peers if p != node and p in alive]
        if not peers:
            return False
        src = self.cluster.nodes[peers[0]]
        with src.lock:
            # clone, never alias: a shared arena breaks under buffer
            # donation (the peer's next fold would invalidate our copy)
            snapshot = arena_clone(src.stores[kg])
        self.cluster.nodes[node].stores[kg] = snapshot
        self.cluster.naming.add_replica(kg, node)
        return True

    # ------------------------------------------------------- network faults
    @staticmethod
    def _pair_name(a: str, b: str) -> str:
        return "cut:" + "|".join(sorted((a, b)))

    def partition(self, a: str, b: str) -> str:
        """Sever the a<->b link through the fault plane.  Replication
        scheduled across the cut parks in its outbox (retried, never
        stranded) and delivers after ``heal`` — unlike the historical
        ``inf``-latency link swap this is fully recoverable."""
        return self.cluster.faults.partition(
            {a}, {b}, name=self._pair_name(a, b))

    def heal(self, a: str, b: str, link: Optional[Link] = None) -> None:
        """Undo ``partition(a, b)``.  ``link`` optionally re-parameterizes
        the physical link (rtt/bandwidth) at the same time."""
        self.cluster.faults.heal(self._pair_name(a, b))
        if link is not None:
            self.cluster.net.links[(a, b)] = link
            self.cluster.net.links[(b, a)] = link

    def partition_groups(self, *groups: Set[str],
                         name: Optional[str] = None) -> str:
        """Split the cluster into named groups (every cross-group link is
        cut); returns the partition's name for ``cluster.faults.heal``."""
        return self.cluster.faults.partition(*groups, name=name)

    def heal_all(self) -> None:
        self.cluster.faults.heal()

    def set_link_fault(self, a: str, b: str, drop_p: float = 0.0,
                       dup_p: float = 0.0, jitter_ms: float = 0.0) -> None:
        """Make the a<->b link lossy: replication transmissions drop with
        ``drop_p`` (retried with backoff), duplicate with ``dup_p``
        (deduped at the receiver), and arrive up to ``jitter_ms`` late."""
        self.cluster.faults.set_fault(a, b, drop_p=drop_p, dup_p=dup_p,
                                      jitter_ms=jitter_ms)

    def clear_link_fault(self, a: str, b: str) -> None:
        self.cluster.faults.clear_fault(a, b)


# ---------------------------------------------------------------------------
# seeded chaos harness
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault action, applied at the START of ``round``."""
    round: int
    action: str          # fault | clear_faults | partition | heal |
                         # crash | restore
    a: str = ""
    b: str = ""
    drop_p: float = 0.0
    dup_p: float = 0.0
    jitter_ms: float = 0.0


@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """A deterministic chaos schedule plus the workload shape it implies.

    ``quiet_rounds`` is derived from the SCHEDULE, not from runtime state:
    the victim skips writing exactly while it is partitioned or crashed,
    so a fault-free twin run (``apply_faults=False``) issues the identical
    write sequence — the precondition for byte-identical convergence."""
    seed: int
    rounds: int
    nodes: Tuple[str, ...]
    victim: str
    events: Tuple[ChaosEvent, ...]
    quiet_rounds: frozenset     # rounds in which the victim must not write

    def events_at(self, r: int) -> List[ChaosEvent]:
        return [e for e in self.events if e.round == r]

    def writers_for(self, r: int) -> List[str]:
        return [n for n in self.nodes
                if n != self.victim or r not in self.quiet_rounds]


def chaos_schedule(seed: int, rounds: int, nodes: Tuple[str, ...],
                   victim: str) -> ChaosPlan:
    """Build the seeded schedule: per-round lossy-link faults (drop_p <=
    0.2, duplication, small jitter) sampled from ``random.Random(seed)``,
    ONE multi-round partition isolating ``victim``, and ONE crash+restore
    of the victim after the heal.  Same seed => same schedule, always."""
    if rounds < 8:
        raise ValueError("chaos_schedule needs >= 8 rounds to fit the "
                         "partition and crash windows")
    rng = random.Random(seed)
    others = [n for n in nodes if n != victim]
    events: List[ChaosEvent] = []

    # the one multi-round partition: victim cut off for [p0, p1)
    p0 = rounds // 4
    p1 = rounds // 2
    events.append(ChaosEvent(round=p0, action="partition", a=victim))
    events.append(ChaosEvent(round=p1, action="heal"))
    # the one crash/restore, strictly after the heal so the partition and
    # the crash exercise DIFFERENT recovery paths
    c0 = p1 + 1
    c1 = min(rounds - 1, c0 + max(1, rounds // 6))
    events.append(ChaosEvent(round=c0, action="crash", a=victim))
    events.append(ChaosEvent(round=c1, action="restore", a=victim))
    quiet = frozenset(list(range(p0, p1)) + list(range(c0, c1)))

    # per-round lossy-link churn on the surviving links
    for r in range(rounds):
        if rng.random() < 0.4:
            a, b = rng.sample(list(nodes), 2)
            events.append(ChaosEvent(
                round=r, action="fault", a=a, b=b,
                drop_p=round(rng.uniform(0.05, 0.2), 3),
                dup_p=round(rng.uniform(0.0, 0.2), 3),
                jitter_ms=round(rng.uniform(0.0, 3.0), 3)))
        elif rng.random() < 0.3:
            events.append(ChaosEvent(round=r, action="clear_faults"))

    return ChaosPlan(seed=seed, rounds=rounds, nodes=tuple(nodes),
                     victim=victim, events=tuple(events), quiet_rounds=quiet)


def run_chaos(cluster: Cluster, membership, injector: FailureInjector,
              plan: ChaosPlan, write: Callable[[str, int, float], None],
              probe: Optional[Callable[[int, float], None]] = None,
              round_ms: float = 1000.0, apply_faults: bool = True) -> float:
    """Drive one chaos run: apply the round's events, DRAIN the transport
    (so every writer holds all deliverable prior-round snapshots before
    stamping new versions — the ordering that keeps a faulty run's version
    vectors identical to its fault-free twin's), then issue the round's
    writes via ``write(node, round, t)`` and optional ``probe(round, t)``.

    ``apply_faults=False`` runs the fault-free twin: network events
    (fault/partition/heal) are skipped, but crash/restore still apply so
    the two runs share membership history and write sequence.  Per round,
    network events apply FIRST (so a heal's backlog rides this round's
    drain), then the transport drains, then crash/restore — quiescing the
    survivor links before a crash bumps the fencing epoch keeps every
    inter-survivor snapshot deliverable, which is what makes the faulty
    run's version clocks match the twin's.  Returns the final virtual
    time after the closing drain."""
    for r in range(plan.rounds):
        t = r * round_ms
        evs = plan.events_at(r)
        if apply_faults:
            for ev in evs:
                if ev.action == "partition":
                    cut = {n for n in plan.nodes if n != ev.a}
                    injector.partition_groups({ev.a}, cut,
                                              name="chaos-cut")
                elif ev.action == "heal":
                    cluster.faults.heal("chaos-cut")
                elif ev.action == "fault":
                    injector.set_link_fault(ev.a, ev.b, drop_p=ev.drop_p,
                                            dup_p=ev.dup_p,
                                            jitter_ms=ev.jitter_ms)
                elif ev.action == "clear_faults":
                    cluster.faults.clear_faults()
        cluster.drain_transport(t)
        for ev in evs:
            if ev.action == "crash":
                injector.kill_node(ev.a)
            elif ev.action == "restore":
                injector.restore_node(ev.a, t=t)
        for node in plan.writers_for(r):
            if membership is not None and \
                    membership.state.get(node) == "dead":
                continue        # crashed victim cannot write
            write(node, r, t)
        if probe is not None:
            probe(r, t)
    # closing drain: clear residual faults first so every retrying outbox
    # entry can complete, then flush until the transport is idle
    if apply_faults:
        cluster.faults.clear_faults()
        cluster.faults.heal()
    t_end = plan.rounds * round_ms
    cluster.drain_transport(t_end)
    return t_end
