from repro.runtime.elastic import (ElasticMembership, MembershipStats,
                                   degraded_mesh_config, remesh)
from repro.runtime.failure import (ChaosEvent, ChaosPlan, FailureInjector,
                                   chaos_schedule, run_chaos)
from repro.runtime.health import HealthMonitor
from repro.runtime.straggler import StragglerPolicy

__all__ = ["ElasticMembership", "MembershipStats", "degraded_mesh_config",
           "remesh", "ChaosEvent", "ChaosPlan", "FailureInjector",
           "chaos_schedule", "run_chaos", "HealthMonitor",
           "StragglerPolicy"]
