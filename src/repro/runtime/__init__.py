from repro.runtime.elastic import degraded_mesh_config, remesh
from repro.runtime.failure import FailureInjector
from repro.runtime.health import HealthMonitor
from repro.runtime.straggler import StragglerPolicy

__all__ = ["degraded_mesh_config", "remesh", "FailureInjector",
           "HealthMonitor", "StragglerPolicy"]
