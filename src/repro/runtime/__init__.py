from repro.runtime.elastic import (ElasticMembership, MembershipStats,
                                   degraded_mesh_config, remesh)
from repro.runtime.failure import FailureInjector
from repro.runtime.health import HealthMonitor
from repro.runtime.straggler import StragglerPolicy

__all__ = ["ElasticMembership", "MembershipStats", "degraded_mesh_config",
           "remesh", "FailureInjector", "HealthMonitor", "StragglerPolicy"]
