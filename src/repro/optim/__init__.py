from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.compression import (int8_compress, int8_decompress,
                                     topk_compress, topk_decompress)
from repro.optim.diloco import (diloco_init, diloco_local_delta,
                                diloco_outer_update)
from repro.optim.schedule import warmup_cosine

__all__ = [
    "adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
    "int8_compress", "int8_decompress", "topk_compress", "topk_decompress",
    "diloco_init", "diloco_local_delta", "diloco_outer_update",
    "warmup_cosine",
]
