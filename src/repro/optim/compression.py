"""Anti-entropy payload compression (gradient/delta compression tricks).

Replication rounds across pods move parameter deltas over the slow inter-pod
DCN — exactly the paper's constrained edge-cloud link (§4.2).  Two standard
compressors, both pure jnp and usable inside the jitted replicate step:

* int8 symmetric quantisation (per-tensor scale): 4× over fp32, unbiased
  under stochastic rounding (deterministic rounding used here; bias is
  absorbed by the outer optimizer's error tolerance).
* top-k sparsification (magnitude): keeps the k largest entries; the
  residual should be fed back by the caller (error feedback) to stay
  convergent.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Int8Payload(NamedTuple):
    q: jnp.ndarray        # int8, same shape
    scale: jnp.ndarray    # () fp32


def int8_compress(x: jnp.ndarray) -> Int8Payload:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return Int8Payload(q=q, scale=scale.astype(jnp.float32))


def int8_decompress(p: Int8Payload) -> jnp.ndarray:
    return p.q.astype(jnp.float32) * p.scale


def tree_int8_compress(tree: Any) -> Any:
    return jax.tree.map(int8_compress, tree)


def tree_int8_decompress(tree: Any) -> Any:
    return jax.tree.map(int8_decompress, tree,
                        is_leaf=lambda x: isinstance(x, Int8Payload))


class TopKPayload(NamedTuple):
    values: jnp.ndarray   # (k,) fp32
    indices: jnp.ndarray  # (k,) int32 into the flattened tensor
    shape: tuple          # static


def topk_compress(x: jnp.ndarray, k: int) -> Tuple[TopKPayload, jnp.ndarray]:
    """Returns (payload, residual) — residual is the error-feedback term."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = min(k, flat.shape[0])
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(x.shape)
    return TopKPayload(values=vals, indices=idx.astype(jnp.int32),
                       shape=tuple(x.shape)), residual


def topk_decompress(p: TopKPayload) -> jnp.ndarray:
    import numpy as np
    size = int(np.prod(p.shape))
    flat = jnp.zeros((size,), jnp.float32).at[p.indices].set(p.values)
    return flat.reshape(p.shape)


def compressed_bytes(tree: Any) -> int:
    """Wire size of a compressed payload tree (replication accounting)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += leaf.size * leaf.dtype.itemsize
    return total
