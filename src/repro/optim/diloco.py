"""DiLoCo-style outer optimisation — the Enoki REPLICATED policy for the
training keygroup (DESIGN.md §2).

Each pod is an Enoki "edge node": it trains on pod-local data against
pod-local parameters (all hot-path reads/writes local).  Every R inner steps
the anti-entropy round runs ``diloco_outer_update`` inside the pod-axis
replication step: pods exchange *deltas* (outer_params − local_params),
average them, and apply an outer Nesterov step to the shared outer params,
which are then re-adopted locally.  Staleness = R inner steps — the paper's
"price of replication", measured in steps instead of milliseconds.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def diloco_init(params: Any) -> Dict[str, Any]:
    f32 = lambda t: jax.tree.map(lambda p: p.astype(jnp.float32), t)
    return {
        "outer_params": f32(params),      # the replicated keygroup contents
        "momentum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params),
        "round": jnp.zeros((), jnp.int32),
    }


def diloco_local_delta(outer_params: Any, local_params: Any) -> Any:
    """The anti-entropy payload: what this pod learned since the last round."""
    return jax.tree.map(
        lambda o, l: o - l.astype(jnp.float32), outer_params, local_params)


def diloco_outer_update(state: Dict[str, Any], mean_delta: Any,
                        outer_lr: float = 0.7, outer_momentum: float = 0.9
                        ) -> Tuple[Any, Dict[str, Any]]:
    """Nesterov outer step on the averaged delta.  Returns (new_local_params
    as fp32, new_state); callers cast to the model dtype."""
    mom = jax.tree.map(lambda m, d: outer_momentum * m + d,
                       state["momentum"], mean_delta)
    new_outer = jax.tree.map(
        lambda p, m, d: p - outer_lr * (outer_momentum * m + d),
        state["outer_params"], mom, mean_delta)
    new_state = {"outer_params": new_outer, "momentum": mom,
                 "round": state["round"] + 1}
    return new_outer, new_state
