"""Adafactor (Shazeer & Stern, 2018): factored second moments.

For a (..., R, C) weight the second moment is stored as row/col exponential
averages over the last two dims — O(R+C) instead of O(R·C).  This is what
makes the 1T-param kimi-k2 trainable within HBM (EXPERIMENTS.md §Dry-run):
AdamW moments alone would be 8 TB fp32.  1-D leaves fall back to full
moments.  No momentum (beta1=0), update clipping d=1.0, relative step off
(we drive lr from the shared schedule).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import clip_by_global_norm


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params: Any) -> Dict[str, Any]:
    def per_leaf(p):
        if _factored(p.shape):
            return {
                "row": jnp.zeros(p.shape[:-1], jnp.float32),          # (..., R)
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"full": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(per_leaf, params),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads: Any, state: Dict[str, Any], params: Any,
                     lr, weight_decay: float = 0.0, decay: float = 0.8,
                     eps: float = 1e-30, clip_threshold: float = 1.0,
                     grad_clip: float = 1.0
                     ) -> Tuple[Any, Dict[str, Any], dict]:
    grads32, gnorm = clip_by_global_norm(grads, grad_clip)
    count = state["count"] + 1
    # time-dependent decay as in the paper: 1 - t^{-0.8}
    beta2 = 1.0 - count.astype(jnp.float32) ** (-decay)

    def per_leaf(g, v, p):
        g2 = g * g + eps
        if _factored(g.shape):
            row = beta2 * v["row"] + (1 - beta2) * g2.mean(axis=-1)
            col = beta2 * v["col"] + (1 - beta2) * g2.mean(axis=-2)
            row_mean = row.mean(axis=-1, keepdims=True)
            vhat = (row / jnp.maximum(row_mean, eps))[..., None] * \
                col[..., None, :]
            new_v = {"row": row, "col": col}
        else:
            vhat = beta2 * v["full"] + (1 - beta2) * g2
            new_v = {"full": vhat}
        u = g / jnp.sqrt(jnp.maximum(vhat, eps))
        # update clipping: RMS(u) <= d
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * u - lr * weight_decay * p32
        return new_p.astype(p.dtype), new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads32)
    flat_v = treedef.flatten_up_to(state["v"])
    out = [per_leaf(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_params, {"v": new_v, "count": count}, {"grad_norm": gnorm}
