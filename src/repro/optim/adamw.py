"""AdamW with decoupled weight decay and global-norm gradient clipping.

Mixed precision: model params may be bf16; the optimizer carries an fp32
master copy inside its state ('master'), moments in fp32.  Updates are
computed in fp32 and cast back to the model dtype — the standard production
recipe.  Pure pytree functions; sharding comes from opt_state_specs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_init(params: Any, keep_master: bool = True) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), t)
    state = {"m": zeros(params), "v": zeros(params),
             "count": jnp.zeros((), jnp.int32)}
    if keep_master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(grads: Any, state: Dict[str, Any], params: Any,
                 lr, weight_decay: float = 0.1, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 grad_clip: float = 1.0) -> Tuple[Any, Dict[str, Any], dict]:
    grads32, gnorm = clip_by_global_norm(grads, grad_clip)
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g,
                     state["m"], grads32)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                     state["v"], grads32)
    master = state.get("master") or jax.tree.map(
        lambda p: p.astype(jnp.float32), params)

    def step(p32, mm, vv):
        upd = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
        return p32 - lr * (upd + weight_decay * p32)

    new_master = jax.tree.map(step, master, m, v)
    new_params = jax.tree.map(lambda p, nm: nm.astype(p.dtype),
                              params, new_master)
    new_state = {"m": m, "v": v, "count": count}
    if "master" in state:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm}
