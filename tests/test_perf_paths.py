"""Correctness of the §Perf optimized paths vs their baselines (subprocess:
needs >1 host device for the shard_map meshes)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch, reduced
    from repro.models import moe as moe_mod
    from repro.models.attention import (attn_init, decode_self_attention,
                                        decode_self_attention_sharded,
                                        blockwise_attention, qscan_attention,
                                        reference_attention)

    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((2, 2), ("data", "model"))

    # --- EP MoE == auto MoE (values + gradients) --------------------------
    arch = reduced(get_arch("kimi-k2-1t-a32b"))
    params = moe_mod.moe_init(jax.random.PRNGKey(0), arch)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, arch.d_model)) * 0.5
    y_auto, aux_a = jax.jit(lambda p, x: moe_mod.moe_apply(p, x, arch))(
        params, x)
    f_ep = jax.jit(lambda p, x: moe_mod.moe_apply_ep(p, x, arch, mesh),
                   in_shardings=(None,
                                 NamedSharding(mesh, P("data", None, None))))
    y_ep, aux_e = f_ep(params, x)
    assert float(jnp.abs(y_auto - y_ep).max()) < 1e-4, "EP MoE mismatch"
    assert abs(float(aux_a) - float(aux_e)) < 1e-6
    g = jax.grad(lambda p: moe_mod.moe_apply_ep(p, x, arch, mesh)[0].sum())(
        params)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
    print("EP_MOE_OK")

    # --- flash-decode == plain decode attention ---------------------------
    arch2 = reduced(get_arch("qwen1.5-110b"))
    ap = attn_init(jax.random.PRNGKey(2), arch2)
    B, S = 2, 32
    ck = jax.random.normal(jax.random.PRNGKey(3),
                           (B, S, arch2.num_kv_heads, 32)) * 0.5
    cv = jax.random.normal(jax.random.PRNGKey(4),
                           (B, S, arch2.num_kv_heads, 32)) * 0.5
    x1 = jax.random.normal(jax.random.PRNGKey(5), (B, 1, arch2.d_model)) * 0.1
    ln = jnp.asarray(17, jnp.int32)
    y0, k0, v0 = jax.jit(lambda: decode_self_attention(ap, x1, ck, cv, ln,
                                                       arch2))()
    y1, k1, v1 = jax.jit(lambda: decode_self_attention_sharded(
        ap, x1, ck, cv, ln, arch2, mesh))()
    assert float(jnp.abs(y0 - y1).max()) < 1e-4, "flash-decode mismatch"
    assert bool(jnp.all(k0 == k1)) and bool(jnp.all(v0 == v1))
    print("FLASH_DECODE_OK")

    # --- qscan == blockwise == reference ----------------------------------
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(128), (2, 128))
    ref = reference_attention(q, k, v, pos, pos, causal=True)
    for fn, name in [(blockwise_attention, "blockwise"),
                     (qscan_attention, "qscan")]:
        out = fn(q, k, v, pos, pos, causal=True)
        err = float(jnp.abs(ref - out).max())
        assert err < 1e-4, f"{name}: {err}"
    print("ATTENTION_VARIANTS_OK")
""")


@pytest.mark.slow
def test_perf_paths_match_baselines(tmp_path):
    script = tmp_path / "perf_paths.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    for marker in ("EP_MOE_OK", "FLASH_DECODE_OK", "ATTENTION_VARIANTS_OK"):
        assert marker in res.stdout
