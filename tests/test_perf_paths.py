"""Correctness of the §Perf optimized paths vs their baselines (subprocess:
needs >1 host device for the shard_map meshes), plus the stabilized
wall-clock throughput regression for the batched invocation path."""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch, reduced
    from repro.models import moe as moe_mod
    from repro.models.attention import (attn_init, decode_self_attention,
                                        decode_self_attention_sharded,
                                        blockwise_attention, qscan_attention,
                                        reference_attention)

    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((2, 2), ("data", "model"))

    # --- EP MoE == auto MoE (values + gradients) --------------------------
    arch = reduced(get_arch("kimi-k2-1t-a32b"))
    params = moe_mod.moe_init(jax.random.PRNGKey(0), arch)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, arch.d_model)) * 0.5
    y_auto, aux_a = jax.jit(lambda p, x: moe_mod.moe_apply(p, x, arch))(
        params, x)
    f_ep = jax.jit(lambda p, x: moe_mod.moe_apply_ep(p, x, arch, mesh),
                   in_shardings=(None,
                                 NamedSharding(mesh, P("data", None, None))))
    y_ep, aux_e = f_ep(params, x)
    assert float(jnp.abs(y_auto - y_ep).max()) < 1e-4, "EP MoE mismatch"
    assert abs(float(aux_a) - float(aux_e)) < 1e-6
    g = jax.grad(lambda p: moe_mod.moe_apply_ep(p, x, arch, mesh)[0].sum())(
        params)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
    print("EP_MOE_OK")

    # --- flash-decode == plain decode attention ---------------------------
    arch2 = reduced(get_arch("qwen1.5-110b"))
    ap = attn_init(jax.random.PRNGKey(2), arch2)
    B, S = 2, 32
    ck = jax.random.normal(jax.random.PRNGKey(3),
                           (B, S, arch2.num_kv_heads, 32)) * 0.5
    cv = jax.random.normal(jax.random.PRNGKey(4),
                           (B, S, arch2.num_kv_heads, 32)) * 0.5
    x1 = jax.random.normal(jax.random.PRNGKey(5), (B, 1, arch2.d_model)) * 0.1
    ln = jnp.asarray(17, jnp.int32)
    y0, k0, v0 = jax.jit(lambda: decode_self_attention(ap, x1, ck, cv, ln,
                                                       arch2))()
    y1, k1, v1 = jax.jit(lambda: decode_self_attention_sharded(
        ap, x1, ck, cv, ln, arch2, mesh))()
    assert float(jnp.abs(y0 - y1).max()) < 1e-4, "flash-decode mismatch"
    assert bool(jnp.all(k0 == k1)) and bool(jnp.all(v0 == v1))
    print("FLASH_DECODE_OK")

    # --- qscan == blockwise == reference ----------------------------------
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(128), (2, 128))
    ref = reference_attention(q, k, v, pos, pos, causal=True)
    for fn, name in [(blockwise_attention, "blockwise"),
                     (qscan_attention, "qscan")]:
        out = fn(q, k, v, pos, pos, causal=True)
        err = float(jnp.abs(ref - out).max())
        assert err < 1e-4, f"{name}: {err}"
    print("ATTENTION_VARIANTS_OK")
""")


def test_batched_invoke_throughput_regression():
    """The §4.2 hot-path claim, asserted against a STABILIZED baseline:
    one fused ``invoke_batch`` dispatch must beat N sequential ``invoke``
    round-trips by a healthy margin.  Raw single-run ratios on this host
    spread ~4x with load (the ROADMAP's parallel_sweep complaint); the
    warmup + interleaved-repeats + median-of-K methodology from
    ``benchmarks.common`` shrinks that enough to pin a real bound instead
    of the old anything-goes ``> 1.0``-style check."""
    import jax
    import numpy as np
    from benchmarks.common import interleaved_repeats, median_ops
    from repro.core import Cluster, enoki_function, get_function
    from repro.core.faas import registry

    if "perfthr_acc" not in registry():
        @enoki_function(name="perfthr_acc", keygroups=["perfthrkg"],
                        codec_width=8)
        def perfthr_acc(kv, x):
            cur, _ = kv.get("acc")
            kv.set("acc", cur + x)
            return cur[:1] + x[:1]

    c = Cluster({"edge": "edge"}, measure_compute=False)
    c.deploy(get_function("perfthr_acc"), ["edge"])
    x = np.ones((8,), np.float32)
    n = 64

    def block():
        jax.block_until_ready(c.nodes["edge"].stores["perfthrkg"])

    def sequential() -> int:
        for i in range(n):
            c.invoke("perfthr_acc", "edge", x, t_send=float(i))
        block()
        return n

    def batched() -> int:
        c.invoke_batch("perfthr_acc", "edge", [x] * n)
        block()
        return n

    samples = interleaved_repeats(
        {"sequential": sequential, "batched": batched},
        repeats=5, warmup=1)
    med = median_ops(samples)
    ratio = med["batched"] / med["sequential"]
    # observed 10-20x on this host; 2.5x leaves room for a loaded CI
    # worker while still catching a real regression to per-request
    # dispatch (ratio ~1)
    assert ratio >= 2.5, (
        f"batched/sequential median ratio {ratio:.2f} "
        f"(batched {med['batched']:.0f} ops/s, "
        f"sequential {med['sequential']:.0f} ops/s, "
        f"samples {samples})")


def test_zero_recompiles_warm_serving():
    """Shape-pinning guarantee: after deploy-time ``engine.prewarm()`` and
    one settling round, a warm replicated serving loop over EVERY batch
    bucket — staging, padding masks, scan-folds, replication flush and the
    fused K-way delivery merges — records ZERO XLA compile requests
    (``jax.monitoring`` events via analysis.jitprof), and the persistent
    staging-buffer set stays fixed."""
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.jitprof import CompileCounter
    from repro.core import Cluster, enoki_function, get_function
    from repro.core.engine import DEFAULT_BUCKETS
    from repro.core.faas import registry

    if "warm_acc" not in registry():
        @enoki_function(name="warm_acc", keygroups=["warmkg"], codec_width=8)
        def warm_acc(kv, x):
            cur, _ = kv.get("acc")
            kv.set("acc", cur + x)
            return cur + x

    c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                measure_compute=False)
    c.deploy(get_function("warm_acc"), ["edge", "edge2", "cloud"],
             example_input=jnp.ones((8,), jnp.float32))
    eng = c.engine
    assert eng.prewarm() > 0

    x = np.ones((8,), np.float32)

    def round_all():
        for node in c.nodes:
            for b in DEFAULT_BUCKETS:
                c.invoke_batch("warm_acc", node, [x] * b)
        c.flush_replication(1e12)

    round_all()                     # settling round: staging buffers land
    n_bufs = len(eng._staging.bufs)
    assert n_bufs == len(DEFAULT_BUCKETS)   # one per (bucket, input leaf)
    with CompileCounter() as cc:
        for _ in range(3):
            round_all()
    assert cc.events == 0, (
        f"{cc.events} compile requests during warm serving rounds")
    assert len(eng._staging.bufs) == n_bufs, "staging buffers not reused"


@pytest.mark.slow
def test_perf_paths_match_baselines(tmp_path):
    script = tmp_path / "perf_paths.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    for marker in ("EP_MOE_OK", "FLASH_DECODE_OK", "ATTENTION_VARIANTS_OK"):
        assert marker in res.stdout
