"""Unit tests for the HLO cost walker (launch/roofline.py) against
hand-checkable compiled programs."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.roofline import analyze_hlo_text, pod_crossing_bytes
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))

    # 1. trip-count awareness: L scanned matmuls must count L times
    L, B, D = 7, 16, 64
    def step(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), 0
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()
    f = jax.jit(step, in_shardings=(
        NamedSharding(mesh, P(None, None, "model")),
        NamedSharding(mesh, P("data", None))))
    txt = f.lower(jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                  jax.ShapeDtypeStruct((B, D), jnp.float32)) \
        .compile().as_text()
    a = analyze_hlo_text(txt)
    # per-device dot: the walker resolves the scan's trip count statically,
    # so the cost must be L * one-layer flops EXACTLY (measured: 114688 =
    # 7 * 2*16*64*64/8) — bounded two-sided with a 2x fusion allowance,
    # and no loop may fall back to the unknown-trip-count estimate
    one_layer = 2 * B * D * D / 8           # most conservative (8 devices)
    assert L * one_layer * 0.9 <= a["flops_per_device"] <= L * one_layer * 2.0, a
    assert a["unknown_trip_counts"] == 0, a
    print("TRIPCOUNT_OK", a["flops_per_device"])

    # 2. pod-crossing classification: an all-reduce over ("pod",) crosses,
    # over ("model",) does not
    from repro.parallel.sharding import shard_map_compat

    def pod_sum(x):
        return shard_map_compat(lambda v: jax.lax.psum(v, "pod"), mesh=mesh,
                                in_specs=P("pod"), out_specs=P(),
                                check_vma=False, axis_names={"pod"})(x)
    t1 = jax.jit(pod_sum).lower(
        jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile().as_text()
    assert pod_crossing_bytes(t1, pod_size=4) > 0, "pod psum must cross"

    def model_sum(x):
        return shard_map_compat(lambda v: jax.lax.psum(v, "model"), mesh=mesh,
                                in_specs=P("model"), out_specs=P(),
                                check_vma=False, axis_names={"model"})(x)
    t2 = jax.jit(model_sum).lower(
        jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile().as_text()
    assert pod_crossing_bytes(t2, pod_size=4) == 0, "model psum is intra-pod"
    print("POD_CLASSIFY_OK")

    # 3. sparse access: updating one row of a big buffer in a scan must not
    # charge the whole buffer per step
    N = 1024
    def writer(buf):
        def body(buf, i):
            return jax.lax.dynamic_update_index_in_dim(
                buf, jnp.ones((128,)), i, 0), 0
        buf, _ = jax.lax.scan(body, buf, jnp.arange(N, dtype=jnp.int32))
        return buf
    t3 = jax.jit(writer).lower(
        jax.ShapeDtypeStruct((N, 128), jnp.float32)).compile().as_text()
    a3 = analyze_hlo_text(t3)
    # per step the DUS touches one 512-byte row (plus indices/carries),
    # NOT the whole 512 KiB buffer.  Measured: ~2.66 MB total = ~5 rows'
    # worth per step; the bound allows 32x per-row overhead, still ~60x
    # tighter than charging the full buffer each step.
    row_bytes = 128 * 4
    assert N * row_bytes <= a3["bytes_per_device"] <= 32 * N * row_bytes, \
        f"sparse DUS miscounted: {a3}"
    assert a3["unknown_trip_counts"] == 0, a3
    print("SPARSE_OK", a3["bytes_per_device"])
""")


def test_serving_path_costs():
    """Pin the compiled cost of the device-resident serving path.

    ``benchmarks.roofline_table.serving_costs`` walks the REAL deployed
    entry points — the batched scan-fold per bucket and the coalesced
    K-way delivery merge per snapshot bucket.  Baselines (CPU, 64x8 f32
    arena, codec_width 8): scan bytes 8.5e3/1.0e5/7.6e5 at buckets
    1/8/64; aligned merge 1.1e4/7.6e4/1.5e5 at K=1/4/8; fallback merge
    2.2e5 at K=4.  The assertions pin the SHAPE of those numbers with
    margin, so a regression that reintroduces O(S^2) probing, loses a
    static trip count, or makes cost super-linear in bucket/K fails here.
    """
    from benchmarks.roofline_table import serving_costs

    rows = serving_costs()
    by = {(r["program"], r["size"]): r for r in rows}

    # every scan/merge loop must have a statically-known trip count —
    # an unknown count means the walker (and the roofline) is guessing
    for r in rows:
        assert r["unknown_trips"] == 0, r

    # scan-fold cost is ~linear in the batch bucket (measured 64/8 ratio
    # 7.57): super-linear growth would mean the fold re-reads the arena
    # per request instead of threading it through the carry
    scan8 = by[("jit_scan", "bucket=8")]["bytes"]
    scan64 = by[("jit_scan", "bucket=64")]["bytes"]
    assert 4.0 <= scan64 / scan8 <= 12.0, (scan8, scan64)

    # the slot-aligned elementwise merge must beat the O(S^2) argmax-probe
    # fallback decisively (measured 2.9x cheaper at K=4)
    al4 = by[("merge/aligned", "K=4")]["bytes"]
    fb4 = by[("merge/fallback", "K=4")]["bytes"]
    assert al4 < 0.6 * fb4, (al4, fb4)

    # coalesced K-way merge is ~linear in K (measured K8/K4 = 1.92):
    # doubling the folded snapshots may not much more than double cost
    al8 = by[("merge/aligned", "K=8")]["bytes"]
    assert al4 < al8 <= 3.0 * al4, (al4, al8)


@pytest.mark.slow
def test_walker_properties(tmp_path):
    script = tmp_path / "walker.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    for marker in ("TRIPCOUNT_OK", "POD_CLASSIFY_OK", "SPARSE_OK"):
        assert marker in res.stdout
