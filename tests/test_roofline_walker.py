"""Unit tests for the HLO cost walker (launch/roofline.py) against
hand-checkable compiled programs."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.roofline import analyze_hlo_text, pod_crossing_bytes
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))

    # 1. trip-count awareness: L scanned matmuls must count L times
    L, B, D = 7, 16, 64
    def step(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), 0
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()
    f = jax.jit(step, in_shardings=(
        NamedSharding(mesh, P(None, None, "model")),
        NamedSharding(mesh, P("data", None))))
    txt = f.lower(jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                  jax.ShapeDtypeStruct((B, D), jnp.float32)) \
        .compile().as_text()
    a = analyze_hlo_text(txt)
    # per-device dot: (B/4? data=2,pod auto...) -> just check the L scaling:
    # flops must be >= L * one-layer flops at any consistent sharding
    one_layer = 2 * B * D * D / 8           # most conservative (8 devices)
    assert a["flops_per_device"] >= L * one_layer * 0.9, a
    print("TRIPCOUNT_OK", a["flops_per_device"])

    # 2. pod-crossing classification: an all-reduce over ("pod",) crosses,
    # over ("model",) does not
    from repro.parallel.sharding import shard_map_compat

    def pod_sum(x):
        return shard_map_compat(lambda v: jax.lax.psum(v, "pod"), mesh=mesh,
                                in_specs=P("pod"), out_specs=P(),
                                check_vma=False, axis_names={"pod"})(x)
    t1 = jax.jit(pod_sum).lower(
        jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile().as_text()
    assert pod_crossing_bytes(t1, pod_size=4) > 0, "pod psum must cross"

    def model_sum(x):
        return shard_map_compat(lambda v: jax.lax.psum(v, "model"), mesh=mesh,
                                in_specs=P("model"), out_specs=P(),
                                check_vma=False, axis_names={"model"})(x)
    t2 = jax.jit(model_sum).lower(
        jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile().as_text()
    assert pod_crossing_bytes(t2, pod_size=4) == 0, "model psum is intra-pod"
    print("POD_CLASSIFY_OK")

    # 3. sparse access: updating one row of a big buffer in a scan must not
    # charge the whole buffer per step
    N = 1024
    def writer(buf):
        def body(buf, i):
            return jax.lax.dynamic_update_index_in_dim(
                buf, jnp.ones((128,)), i, 0), 0
        buf, _ = jax.lax.scan(body, buf, jnp.arange(N, dtype=jnp.int32))
        return buf
    t3 = jax.jit(writer).lower(
        jax.ShapeDtypeStruct((N, 128), jnp.float32)).compile().as_text()
    a3 = analyze_hlo_text(t3)
    full_per_step = N * 128 * 4
    assert a3["bytes_per_device"] < N * full_per_step * 0.5, \
        f"sparse DUS overcounted: {a3}"
    print("SPARSE_OK", a3["bytes_per_device"])
""")


@pytest.mark.slow
def test_walker_properties(tmp_path):
    script = tmp_path / "walker.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    for marker in ("TRIPCOUNT_OK", "POD_CLASSIFY_OK", "SPARSE_OK"):
        assert marker in res.stdout
