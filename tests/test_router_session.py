"""Router correctness: hedging must not double-apply writes (read-only
gate from the deploy-time op trace), session tokens must observe the STORE
node's clock under remote placements, and the batched submit/pump/flush
path must fold results back into sessions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.tier0  # fast pre-commit subset

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster, Router, enoki_function, get_function
from repro.core.store import store_contents

jax.config.update("jax_platform_name", "cpu")


@enoki_function(name="rtr_counter", keygroups=["rtrcnt"], codec_width=4)
def rtr_counter(kv, x):
    cur, found = kv.get("c")
    new = jnp.where(found, cur[0] + 1.0, 1.0)
    kv.set("c", jnp.stack([new, 0.0, 0.0, 0.0]))
    return jnp.stack([new])


@enoki_function(name="rtr_peek", keygroups=["rtrcnt"], codec_width=4)
def rtr_peek(kv, x):
    cur, found = kv.get("c")
    return cur[:1]


def _cluster():
    return Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                   measure_compute=False)


def _count(c, node):
    contents = store_contents(c.nodes[node].stores["rtrcnt"])
    return list(contents.values())[0][2][0] if contents else 0.0


# ---------------------------------------------------------------------------
# hedging vs mutating handlers
# ---------------------------------------------------------------------------

def test_hedge_on_mutating_counter_does_not_change_count():
    """Regression for the hedged-duplicate-write bug: a hedged invoke of a
    mutating function must leave the count identical to the unhedged run —
    the hedge is suppressed, not fired."""
    c_hedged = _cluster()
    c_hedged.deploy(get_function("rtr_counter"), ["edge", "edge2"],
                    policy=ReplicationPolicy.REPLICATED)
    hedged = Router(c_hedged, hedge_after_ms=0.0)   # every request "slow"
    r = hedged.invoke("rtr_counter", jnp.zeros((1,)))

    c_plain = _cluster()
    c_plain.deploy(get_function("rtr_counter"), ["edge", "edge2"],
                   policy=ReplicationPolicy.REPLICATED)
    plain = Router(c_plain)
    r_plain = plain.invoke("rtr_counter", jnp.zeros((1,)))

    assert float(np.asarray(r.output)[0]) == float(np.asarray(r_plain.output)[0]) == 1.0
    assert hedged.stats.hedges_suppressed == 1
    assert hedged.stats.hedges_fired == 0
    c_hedged.flush_replication()
    c_plain.flush_replication()
    for node in ("edge", "edge2"):
        assert _count(c_hedged, node) == _count(c_plain, node) == 1.0


def test_hedge_still_fires_for_read_only_handlers():
    c = _cluster()
    c.deploy(get_function("rtr_counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    c.deploy(get_function("rtr_peek"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    assert c.is_read_only("rtr_peek")
    assert not c.is_read_only("rtr_counter")
    router = Router(c, hedge_after_ms=0.0)
    router.invoke("rtr_counter", jnp.zeros((1,)))      # suppressed
    router.invoke("rtr_peek", jnp.zeros((1,)))         # hedges
    assert router.stats.hedges_fired == 1
    assert router.stats.hedges_suppressed == 1
    # the hedged read did not touch state anywhere
    c.flush_replication()
    assert _count(c, "edge") == _count(c, "edge2") == 1.0


# ---------------------------------------------------------------------------
# session clocks under remote placements
# ---------------------------------------------------------------------------

def test_session_reads_your_writes_under_cloud_central():
    """Under CLOUD_CENTRAL the write lands at the CLOUD store while the
    client talks to an edge node: the session must record the cloud node's
    clock (pre-fix it recorded the edge node's — which never advanced — so
    the token silently demanded nothing)."""
    c = _cluster()
    c.deploy(get_function("rtr_counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.CLOUD_CENTRAL, owner="cloud")
    router = Router(c)
    r = router.invoke("rtr_counter", jnp.zeros((1,)), session_id="s1")
    assert r.node == "edge"                       # served by the edge
    session = router.sessions["s1"]
    cloud, edge = c.nodes["cloud"], c.nodes["edge"]
    req = session.requirement()
    # the clock that advanced is the CLOUD (store) node's — the serving
    # edge's own clock never moves under a remote placement (the pre-fix
    # bug recorded THAT clock, i.e. zero, so the token demanded nothing)
    assert int(cloud.clock) > 0
    assert int(edge.clock) == 0
    # the write stamp pairs the serving node's id with the store's clock
    assert req[edge.node_id] == int(cloud.clock)
    assert req.sum() == req[edge.node_id]         # nothing bogus recorded
    # reads-your-writes: the actual store can serve the session
    assert session.can_read_from(np.asarray(c.store_of("rtrcnt", "cloud").vv))
    # and a follow-up through the same session sees its own write
    r2 = router.invoke("rtr_counter", jnp.zeros((1,)), session_id="s1",
                       t_send=r.t_received)
    assert float(np.asarray(r2.output)[0]) == 2.0


def test_session_observes_store_node_under_peer_fetch():
    c = _cluster()
    # function runs at the edge; its keygroup lives at the (non-deployment)
    # owner edge2, so every invocation is a remote placement
    c.deploy(get_function("rtr_counter"), ["edge"],
             policy=ReplicationPolicy.PEER_FETCH, owner="edge2")
    router = Router(c)
    r = router.invoke("rtr_counter", jnp.zeros((1,)), session_id="s")
    assert r.node == "edge"                       # served locally...
    owner = c.nodes["edge2"]                      # ...state at the owner
    req = router.sessions["s"].requirement()
    assert int(owner.clock) > 0
    assert req[c.nodes["edge"].node_id] == int(owner.clock)
    assert router.sessions["s"].can_read_from(
        np.asarray(c.store_of("rtrcnt", "edge2").vv))


# ---------------------------------------------------------------------------
# session routing under remote placements (Router.pick placement fix)
# ---------------------------------------------------------------------------

def test_pick_resolves_placement_no_bogus_redirect_under_peer_fetch():
    """Regression for the pick-placement bug: under PEER_FETCH every
    candidate's kv ops hit the OWNER store, so a session that wrote is
    satisfiable at the nearest candidate — pre-fix, pick checked the
    candidate's own (empty) local stores, never found the version vector,
    and either fell through or bogusly redirected to the owner replica."""
    c = _cluster()
    c.deploy(get_function("rtr_counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.PEER_FETCH, owner="edge2")
    router = Router(c)
    r = router.invoke("rtr_counter", jnp.zeros((1,)), session_id="s")
    assert r.node == "edge"                        # nearest candidate serves
    session = router.sessions["s"]
    # the satisfying vv lives at the owner; the nearest candidate resolves
    # to it, so the session read routes to edge with NO consistency redirect
    assert router.pick("rtr_counter", session) == "edge"
    assert router.stats.redirects_for_consistency == 0
    # and reads-your-writes holds end to end through that pick
    r2 = router.invoke("rtr_counter", jnp.zeros((1,)), session_id="s",
                       t_send=r.t_received)
    assert r2.node == "edge"
    assert float(np.asarray(r2.output)[0]) == 2.0


def test_pick_resolves_placement_under_cloud_central():
    """Same fix for CLOUD_CENTRAL: candidates hold no replica at all (the
    store is at the cloud), yet every candidate satisfies a session once
    the cloud vv dominates — the session read must stay at the nearest
    edge instead of falling through 'unsatisfied'."""
    c = _cluster()
    c.deploy(get_function("rtr_counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.CLOUD_CENTRAL, owner="cloud")
    router = Router(c)
    router.invoke("rtr_counter", jnp.zeros((1,)), session_id="s")
    session = router.sessions["s"]
    assert session.requirement().sum() > 0         # the token demands the write
    assert router.pick("rtr_counter", session) == "edge"
    assert router.stats.redirects_for_consistency == 0


def test_pick_still_redirects_to_fresher_replica_under_replicated():
    """The REPLICATED redirect path is unchanged: while replication to the
    nearest replica is pending, a session that observed the fresher store
    redirects to it; once replication lands, it returns to the nearest."""
    c = _cluster()
    c.deploy(get_function("rtr_counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    router = Router(c)
    # write at the FAR replica; the session token observes edge2's store
    res = c.invoke("rtr_counter", "edge2", jnp.zeros((1,)))
    session = router._session("s")
    router._observe(session, "rtr_counter", res)
    assert router.pick("rtr_counter", session) == "edge2"   # edge is stale
    assert router.stats.redirects_for_consistency == 1
    c.flush_replication()
    assert router.pick("rtr_counter", session) == "edge"    # caught up


# ---------------------------------------------------------------------------
# batched router path
# ---------------------------------------------------------------------------

def test_router_submit_pump_folds_sessions():
    c = _cluster()
    c.deploy(get_function("rtr_counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    c.engine.configure(window_ms=5.0)
    router = Router(c)
    tks = [router.submit("rtr_counter", jnp.zeros((1,)), t_send=float(i),
                         session_id="s1") for i in range(3)]
    assert router.pump(0.0) == {}
    out = router.pump(1000.0)
    assert set(out) == set(tks)
    assert sorted(float(np.asarray(out[t].output)[0]) for t in tks) \
        == [1.0, 2.0, 3.0]
    # session observed the batch's writes at the store node
    session = router.sessions["s1"]
    edge = c.nodes["edge"]
    assert session.requirement()[edge.node_id] == int(edge.clock) > 0
    assert session.can_read_from(np.asarray(c.store_of("rtrcnt", "edge").vv))
    assert router._inflight == {}


def test_two_routers_sharing_engine_keep_their_tickets():
    """Two routers front the same cluster engine: one router's drain must
    not swallow the other's results — foreign tickets are handed back for
    their owner's next pump/flush, and each session still updates."""
    c = _cluster()
    c.deploy(get_function("rtr_counter"), ["edge"],
             policy=ReplicationPolicy.REPLICATED)
    r1, r2 = Router(c), Router(c)
    ta = r1.submit("rtr_counter", jnp.zeros((1,)), session_id="a")
    tb = r2.submit("rtr_counter", jnp.zeros((1,)), t_send=1.0,
                   session_id="b")
    out1 = r1.flush()                 # drains the engine, returns only ta
    assert set(out1) == {ta}
    out2 = r2.pump(0.0)               # picks up the held-back result
    assert set(out2) == {tb}
    assert r1.sessions["a"].requirement().sum() > 0
    assert r2.sessions["b"].requirement().sum() > 0
    assert r1._inflight == {} and r2._inflight == {}


def test_inflight_pruned_after_discard():
    """A ticket discarded from the engine queue can never complete; the
    router must not track it forever."""
    c = _cluster()
    c.deploy(get_function("rtr_counter"), ["edge"],
             policy=ReplicationPolicy.REPLICATED)
    router = Router(c)
    t = router.submit("rtr_counter", jnp.zeros((1,)), session_id="s")
    assert c.engine.discard(t)
    assert router.flush() == {}
    assert router._inflight == {}


def test_router_flush_drains_engine():
    c = _cluster()
    c.deploy(get_function("rtr_counter"), ["edge"],
             policy=ReplicationPolicy.REPLICATED)
    router = Router(c)
    t1 = router.submit("rtr_counter", jnp.zeros((1,)), session_id="a")
    t2 = router.submit("rtr_counter", jnp.zeros((1,)), t_send=1.0,
                       session_id="b")
    out = router.flush()
    assert set(out) == {t1, t2}
    # both sessions were folded independently
    for sid in ("a", "b"):
        assert router.sessions[sid].requirement().sum() > 0
