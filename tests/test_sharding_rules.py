"""Sharding-rule invariants: every generated PartitionSpec must divide its
array evenly on the production meshes, for every assigned architecture —
the property the dry-run relies on (a violation fails at .compile())."""
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.tier0  # fast pre-commit subset
from jax.sharding import PartitionSpec as P

from repro.configs import (ARCH_IDS, ParallelConfig, SHAPES, get_arch)
from repro.launch.train import default_parallel, opt_specs_tree, state_shapes
from repro.models import model_zoo as zoo
from repro.parallel.sharding import (cache_partition_specs,
                                     param_partition_specs)

jax.config.update("jax_platform_name", "cpu")

MESH_SIZES = {"data": 16, "model": 16}


class FakeMesh:
    """Shape-only stand-in (the rules only read mesh.shape)."""
    shape = MESH_SIZES
    axis_names = tuple(MESH_SIZES)


def _check(tree_shapes, tree_specs, what):
    leaves_sh = jax.tree.leaves(tree_shapes)
    leaves_sp = jax.tree.leaves(tree_specs,
                                is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_sh) == len(leaves_sp), what
    for sh, sp in zip(leaves_sh, leaves_sp):
        shape = sh.shape if hasattr(sh, "shape") else sh
        for d, axis in enumerate(sp):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            total = 1
            for a in axes:
                total *= MESH_SIZES[a]
            assert shape[d] % total == 0, \
                f"{what}: dim {d} of {shape} not divisible by {axis}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_specs_divide(arch_id):
    arch = get_arch(arch_id)
    pshape = jax.eval_shape(
        lambda: zoo.init_params(arch, jax.random.PRNGKey(0),
                                dtype=jnp.bfloat16))
    for fsdp in (False, True):
        par = ParallelConfig(fsdp=fsdp)
        specs = param_partition_specs(pshape, arch, FakeMesh, par)
        _check(pshape, specs, f"{arch_id} params fsdp={fsdp}")


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_opt_state_specs_divide(arch_id):
    arch = get_arch(arch_id)
    par = default_parallel(arch, SHAPES[0])
    sshape = state_shapes(arch, par)
    specs = opt_specs_tree(sshape["opt"], arch, FakeMesh, par)
    _check(sshape["opt"], specs, f"{arch_id} opt")


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("batch,seq", [(128, 1024), (32, 2048)])
def test_cache_specs_divide(arch_id, batch, seq):
    arch = get_arch(arch_id)
    cshape = jax.eval_shape(lambda: zoo.init_cache(arch, batch, seq))
    for prefer_seq in (False, True):
        specs = cache_partition_specs(cshape, arch, FakeMesh, batch,
                                      prefer_seq=prefer_seq)
        _check(cshape, specs, f"{arch_id} cache prefer_seq={prefer_seq}")


def test_whisper_vocab_not_sharded():
    """51865 % 16 != 0: the embedding must fall back to replication rather
    than emit an invalid spec (the divisibility-guard contract)."""
    arch = get_arch("whisper-tiny")
    pshape = jax.eval_shape(
        lambda: zoo.init_params(arch, jax.random.PRNGKey(0)))
    specs = param_partition_specs(pshape, arch, FakeMesh, ParallelConfig())
    assert specs["embed"][0] is None
