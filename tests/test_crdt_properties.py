"""Hypothesis property tests: every Enoki merge is a CRDT join
(commutative, associative, idempotent) and anti-entropy converges
regardless of round order — the invariant that makes the paper's
asynchronous replication safe."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.crdt import (GCounter, LWWRegister, PNCounter, gcounter_merge,
                             gcounter_value, lww_merge, pncounter_add,
                             pncounter_merge, pncounter_new, pncounter_value,
                             vv_merge)
from repro.core.keygroup import TensorKeygroup
from repro.core.replication import anti_entropy_round, converge
from repro.core.store import kv_set, merge_stores, store_contents, store_new
from repro.core.versioning import MAX_NODES, fnv1a

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)

arrays = st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                  min_size=4, max_size=4).map(
    lambda xs: jnp.asarray(xs, jnp.float32))
versions = st.lists(st.integers(0, 1000), min_size=4, max_size=4).map(
    lambda xs: jnp.asarray(xs, jnp.int32))


def _reg(draw_val, draw_ver):
    return LWWRegister(value=draw_val, version=draw_ver)


@given(arrays, versions, arrays, versions)
@settings(**SETTINGS)
def test_lww_commutative(v1, t1, v2, t2):
    a, b = _reg(v1, t1), _reg(v2, t2)
    ab = lww_merge(a, b)
    ba = lww_merge(b, a)
    np.testing.assert_array_equal(np.asarray(ab.version),
                                  np.asarray(ba.version))
    # where versions tie the values may differ (concurrent identical clocks);
    # restrict equality check to non-tied slots
    tie = np.asarray(t1) == np.asarray(t2)
    np.testing.assert_array_equal(np.asarray(ab.value)[~tie],
                                  np.asarray(ba.value)[~tie])


@given(arrays, versions, arrays, versions, arrays, versions)
@settings(**SETTINGS)
def test_lww_associative(v1, t1, v2, t2, v3, t3):
    a, b, c = _reg(v1, t1), _reg(v2, t2), _reg(v3, t3)
    left = lww_merge(lww_merge(a, b), c)
    right = lww_merge(a, lww_merge(b, c))
    np.testing.assert_array_equal(np.asarray(left.version),
                                  np.asarray(right.version))


@given(arrays, versions)
@settings(**SETTINGS)
def test_lww_idempotent(v, t):
    a = _reg(v, t)
    aa = lww_merge(a, a)
    np.testing.assert_array_equal(np.asarray(aa.value), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(aa.version), np.asarray(t))


counters = st.lists(st.integers(0, 1000), min_size=4, max_size=4).map(
    lambda xs: GCounter(jnp.asarray(xs, jnp.int32)))


@given(counters, counters, counters)
@settings(**SETTINGS)
def test_gcounter_semilattice(a, b, c):
    ab = gcounter_merge(a, b)
    ba = gcounter_merge(b, a)
    np.testing.assert_array_equal(np.asarray(ab.counts), np.asarray(ba.counts))
    l = gcounter_merge(gcounter_merge(a, b), c)
    r = gcounter_merge(a, gcounter_merge(b, c))
    np.testing.assert_array_equal(np.asarray(l.counts), np.asarray(r.counts))
    aa = gcounter_merge(a, a)
    np.testing.assert_array_equal(np.asarray(aa.counts), np.asarray(a.counts))


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(-50, 50)),
                min_size=0, max_size=12))
@settings(**SETTINGS)
def test_pncounter_value_converges(ops):
    """Apply ops at different replicas, merge in two different orders:
    values agree and equal the sequential sum."""
    replicas = [pncounter_new(4) for _ in range(4)]
    for node, amount in ops:
        replicas[node] = pncounter_add(replicas[node], node, amount)
    import functools
    m1 = functools.reduce(pncounter_merge, replicas)
    m2 = functools.reduce(pncounter_merge, reversed(replicas))
    assert int(pncounter_value(m1)) == int(pncounter_value(m2)) \
        == sum(a for _, a in ops)


@given(st.lists(st.tuples(st.integers(0, 2), st.sampled_from("abcd"),
                          st.floats(-10, 10, allow_nan=False, width=32)),
                min_size=1, max_size=10),
       st.permutations([0, 1, 2]))
@settings(max_examples=15, deadline=None)
def test_store_anti_entropy_converges_any_order(writes, order):
    """The paper's §4.3 guarantee: replica contents converge after
    anti-entropy regardless of merge order."""
    stores = [store_new(8, 2, MAX_NODES) for _ in range(3)]
    clocks = [jnp.zeros((), jnp.int32) for _ in range(3)]
    for node, key, val in writes:
        row = jnp.zeros((2,), jnp.float32).at[0].set(val)
        stores[node], clocks[node], _ = kv_set(
            stores[node], fnv1a(key), row, 1, clocks[node], node)
    # full anti-entropy in the drawn permutation order
    permuted = [stores[i] for i in order]
    merged = converge(permuted, merge_stores, topology="full")
    contents = [store_contents(s) for s in merged]
    assert contents[0] == contents[1] == contents[2]
    # and in canonical order -> same contents
    merged2 = converge(stores, merge_stores, topology="full")
    assert store_contents(merged2[0]) == contents[0]


@given(st.integers(2, 5))
@settings(max_examples=8, deadline=None)
def test_ring_gossip_converges(n):
    kgs = []
    for i in range(n):
        kgs.append(TensorKeygroup(
            {"w": jnp.full((3,), float(i))}, jnp.asarray(i, jnp.int32),
            "lww"))
    out = converge(kgs, lambda a, b: a.merged_with(b), topology="ring")
    tops = [float(k.tree["w"][0]) for k in out]
    assert tops == [float(n - 1)] * n, "ring gossip must reach the newest"
