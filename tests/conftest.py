"""Shared test plumbing.

Three jobs:

1. Register the ``slow`` marker so ``pytest.mark.slow`` doesn't warn.
2. Guard the ``hypothesis`` dependency.  The property tests in
   ``test_crdt_properties.py`` import hypothesis at module scope; without
   this guard a missing install kills the *whole* ``pytest -x`` run at
   collection.  When hypothesis is absent we install a tiny deterministic
   shim (seeded draws, no shrinking) so the CRDT invariant tests still
   execute as plain example-based tests.
3. Arm lockdep (``repro.analysis.lockdep``) across the concurrency
   suites: every cluster/server built inside those tests gets ordered
   locks that assert the declared ``LOCK_ORDER`` at acquire time, and
   each test ends by verifying the accumulated cross-thread acquisition
   graph is violation- and cycle-free.
"""
from __future__ import annotations

import importlib.util
import random
import sys
import types
import zlib

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration test (subprocess meshes)")
    config.addinivalue_line(
        "markers", "tier0: fast pre-commit subset (<60 s total, no heavy "
        "jit) — run with `pytest -m tier0` or scripts/verify.sh --fast")


# ---------------------------------------------------------------------------
# lockdep: runtime lock-order validation across the concurrency suites
# ---------------------------------------------------------------------------

_LOCKDEP_MODULES = {
    "test_concurrent_pipeline",
    "test_dataflow_scheduler",
    "test_faas_server",
    "test_failure_recovery",
}


@pytest.fixture(autouse=True)
def _lockdep_guard(request):
    """Enable the runtime lock-order validator for the concurrency
    suites.  ``enable()`` runs BEFORE the test body so objects the test
    constructs get instrumented locks; teardown fails the test on any
    recorded order violation (even one swallowed by an executor) or on a
    cycle in the cross-thread acquisition graph."""
    mod = getattr(request, "module", None)
    name = getattr(mod, "__name__", "").rpartition(".")[2]
    if name not in _LOCKDEP_MODULES:
        yield
        return
    from repro.analysis import lockdep
    lockdep.enable()
    problems = None
    try:
        yield
        problems = lockdep.verify()
    finally:
        lockdep.disable()
    assert not problems, "lockdep:\n  " + "\n  ".join(problems)


# ---------------------------------------------------------------------------
# hypothesis fallback shim
# ---------------------------------------------------------------------------

class _Strategy:
    """A draw function wrapper mirroring the tiny slice of the hypothesis
    strategy API the CRDT tests use (including ``.map``)."""

    def __init__(self, draw):
        self._draw = draw

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))


def _integers(min_value=0, max_value=100):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value=0.0, max_value=1.0, allow_nan=False, allow_infinity=False,
            width=64):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements._draw(rng) for _ in range(n)]
    return _Strategy(draw)


def _tuples(*strategies):
    return _Strategy(lambda rng: tuple(s._draw(rng) for s in strategies))


def _sampled_from(seq):
    items = list(seq)
    return _Strategy(lambda rng: items[rng.randrange(len(items))])


def _permutations(seq):
    items = list(seq)
    return _Strategy(lambda rng: rng.sample(items, len(items)))


def _settings(max_examples=10, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def _given(*strategies):
    def deco(fn):
        n = min(getattr(fn, "_shim_max_examples", 10), 10)

        def runner():
            # deterministic per-test seed: same draws on every run
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                fn(*[s._draw(rng) for s in strategies])

        # NOT functools.wraps: pytest would introspect __wrapped__'s
        # signature and treat the strategy parameters as fixtures
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco


def _install_hypothesis_shim():
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.floats = _floats
    st.lists = _lists
    st.tuples = _tuples
    st.sampled_from = _sampled_from
    st.permutations = _permutations
    hyp.given = _given
    hyp.settings = _settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    hyp.__is_repro_shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


if importlib.util.find_spec("hypothesis") is None:
    _install_hypothesis_shim()
