"""Per-kernel shape/dtype sweeps: pallas interpret=True vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")


def _allclose(a, b, rtol, atol, what=""):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    np.testing.assert_allclose(a, b, rtol=rtol, atol=atol, err_msg=what)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 4, 4, 32), (2, 256, 4, 2, 64), (1, 512, 8, 2, 32),
    (2, 128, 2, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, D, dtype, causal):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, H, D), dtype)
    k = jax.random.normal(k2, (B, S, KV, D), dtype)
    v = jax.random.normal(k3, (B, S, KV, D), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    _allclose(out, ref, rtol=tol, atol=tol, what="flash vs ref")


def test_flash_attention_sliding_window():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, D = 1, 256, 2, 32
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, H, D))
    v = jax.random.normal(k3, (B, S, H, D))
    out = flash_attention(q, k, v, causal=True, window=64, bq=64, bk=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=64)
    _allclose(out, ref, rtol=2e-5, atol=2e-5, what="sliding window")


# ---------------------------------------------------------------------------
# ssd_chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,P,N,chunk", [
    (1, 2, 128, 32, 16, 32), (2, 4, 256, 64, 64, 64), (1, 1, 64, 16, 8, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_sweep(B, H, S, P, N, chunk, dtype):
    from repro.kernels.ssd_chunk.kernel import ssd_chunk_bhcp
    from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (B, H, S, P), dtype)
    a_dt = -jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))) * 0.5
    b = jax.random.normal(ks[2], (B, 1, S, N), dtype) * 0.3
    c = jax.random.normal(ks[3], (B, 1, S, N), dtype) * 0.3
    out = ssd_chunk_bhcp(x, a_dt.astype(dtype), b, c, chunk=chunk,
                         interpret=True)
    ref = ssd_chunk_ref(x.astype(jnp.float32), a_dt,
                        b.astype(jnp.float32), c.astype(jnp.float32),
                        chunk=chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    _allclose(out, ref, rtol=tol, atol=tol, what="ssd chunk vs ref")


def test_ssd_chunk_matches_stepwise():
    """Chunked kernel == step-by-step recurrence (ground truth)."""
    from repro.kernels.ssd_chunk.kernel import ssd_chunk_bhcp
    from repro.models.ssm import ssd_step
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    B, H, S, P, N = 1, 2, 64, 16, 8
    x = jax.random.normal(ks[0], (B, H, S, P))
    a_dt = -jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))) * 0.5
    b = jax.random.normal(ks[2], (B, 1, S, N)) * 0.3
    c = jax.random.normal(ks[3], (B, 1, S, N)) * 0.3
    out = ssd_chunk_bhcp(x, a_dt, b, c, chunk=16, interpret=True)
    state = jnp.zeros((B, H, P, N))
    ys = []
    ones = jnp.ones((B, H))
    for t in range(S):
        y, state = ssd_step(x[:, :, t], a_dt[:, :, t], b[:, 0, t], c[:, 0, t],
                            ones, state)
        ys.append(y)
    ref = jnp.stack(ys, axis=2)
    _allclose(out, ref, rtol=1e-4, atol=1e-4, what="chunk vs stepwise")


# ---------------------------------------------------------------------------
# mlstm_chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,D,chunk", [
    (1, 2, 128, 32, 32), (2, 2, 64, 64, 16), (1, 4, 256, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunk_sweep(B, H, S, D, chunk, dtype):
    from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_bhsd
    from repro.kernels.mlstm_chunk.ref import mlstm_chunk_ref
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, H, S, D), dtype)
    v = jax.random.normal(ks[2], (B, H, S, D), dtype)
    log_i = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, H, S)) - 2.0)
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 2.0)
    out = mlstm_chunk_bhsd(q, k, v, log_i, log_f, chunk=chunk,
                           interpret=True)
    ref = mlstm_chunk_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), log_i, log_f, chunk=chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    _allclose(out, ref, rtol=tol, atol=tol, what="mlstm chunk vs ref")


def test_mlstm_chunk_matches_stepwise():
    from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_bhsd
    from repro.models.xlstm import mlstm_cell_step
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, H, S, D = 1, 2, 32, 16
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    log_i = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, H, S)) - 1.0)
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 1.0)
    out = mlstm_chunk_bhsd(q, k, v, log_i, log_f, chunk=8, interpret=True)
    carry = (jnp.zeros((B, H, D, D)), jnp.zeros((B, H, D)),
             jnp.zeros((B, H)))
    ys = []
    for t in range(S):
        y, carry = mlstm_cell_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                   log_i[:, :, t], log_f[:, :, t], carry)
        ys.append(y)
    ref = jnp.stack(ys, axis=2)
    _allclose(out, ref, rtol=1e-4, atol=1e-4, what="mlstm chunk vs stepwise")


# ---------------------------------------------------------------------------
# enoki_merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,V,tile", [(256, 128, 64), (512, 256, 256),
                                      (64, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_enoki_merge_sweep(R, V, tile, dtype):
    from repro.kernels.enoki_merge.kernel import enoki_merge_rows
    from repro.kernels.enoki_merge.ref import enoki_merge_ref
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    if dtype == jnp.int32:
        a = jax.random.randint(ks[0], (R, V), 0, 100, dtype)
        b = jax.random.randint(ks[1], (R, V), 0, 100, dtype)
    else:
        a = jax.random.normal(ks[0], (R, V), dtype)
        b = jax.random.normal(ks[1], (R, V), dtype)
    aver = jax.random.randint(ks[2], (R,), 0, 50, jnp.int32)
    bver = jax.random.randint(ks[3], (R,), 0, 50, jnp.int32)
    mv, mver = enoki_merge_rows(a, aver, b, bver, rows_tile=tile,
                                interpret=True)
    rv, rver = enoki_merge_ref(a, aver, b, bver)
    _allclose(mv, rv, 0, 0, "merge values")
    _allclose(mver, rver, 0, 0, "merge versions")


def test_enoki_merge_commutative_idempotent():
    """CRDT laws on the kernel itself (versions totally ordered => LWW is a
    proper CRDT)."""
    from repro.kernels.enoki_merge.kernel import enoki_merge_rows
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    R, V = 128, 64
    a = jax.random.normal(ks[0], (R, V))
    b = jax.random.normal(ks[1], (R, V))
    # distinct versions => merge is commutative even on values
    aver = jax.random.permutation(ks[2], jnp.arange(R, dtype=jnp.int32))
    bver = jax.random.permutation(ks[3], jnp.arange(R, dtype=jnp.int32)) + R
    ab = enoki_merge_rows(a, aver, b, bver, rows_tile=64, interpret=True)
    ba = enoki_merge_rows(b, bver, a, aver, rows_tile=64, interpret=True)
    _allclose(ab[0], ba[0], 0, 0, "commutative values")
    _allclose(ab[1], ba[1], 0, 0, "commutative versions")
    aa = enoki_merge_rows(ab[0], ab[1], ab[0], ab[1], rows_tile=64,
                          interpret=True)
    _allclose(aa[0], ab[0], 0, 0, "idempotent")


@pytest.mark.parametrize("n,row_width", [(10, 4), (8, 4), (3, 4), (7, 7)])
def test_merge_flat_keygroup_ragged_tail(n, row_width):
    """Row-granularity contract: ceil(N/row_width) version entries, the
    last owning the ragged tail — its version must be MERGED into the
    returned versions (max of the compared pair), never dropped, and the
    tail payload follows the strictly-greater version like full rows do."""
    from repro.kernels.enoki_merge.ops import merge_flat_keygroup
    rows = n // row_width
    nver = rows + (1 if rows * row_width < n else 0)
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    a = jax.random.normal(ks[0], (n,))
    b = jax.random.normal(ks[1], (n,))
    aver = (jnp.arange(nver, dtype=jnp.int32) * 3 + 1) % 7      # mixed wins
    bver = (jnp.arange(nver, dtype=jnp.int32) * 5 + 2) % 7
    out, mver = merge_flat_keygroup(a, b_flat=b, a_ver=aver, b_ver=bver,
                                    row_width=row_width, interpret=True)
    assert out.shape == (n,) and mver.shape == (nver,)
    _allclose(mver, jnp.maximum(aver, bver), 0, 0, "flat versions")
    # per-row reference: row i (incl. the ragged tail row) follows b iff
    # b's version is strictly greater
    ref = np.asarray(a).copy()
    bn = np.asarray(b)
    for i in range(nver):
        lo, hi = i * row_width, min((i + 1) * row_width, n)
        if int(bver[i]) > int(aver[i]):
            ref[lo:hi] = bn[lo:hi]
    _allclose(out, jnp.asarray(ref), 0, 0, "flat payload")
    if rows * row_width < n:
        # the old tail-dropping call shape (rows version entries) must be
        # rejected loudly, not silently mis-merged
        with pytest.raises(AssertionError):
            merge_flat_keygroup(a, aver[:rows], b, bver[:rows],
                                row_width=row_width, interpret=True)
