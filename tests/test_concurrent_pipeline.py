"""Concurrent dispatch pipeline (executor-per-store-node pump).

The contract under test: parallelism must be semantically INVISIBLE —
``workers=4`` produces the identical ticket→result map, converged stores
and clocks as ``workers=1`` on the same submission stream (same-store-node
groups share a single pool worker, so every fold keeps its order); stats
counters stay exact under racing submitter threads; and the serving loop's
deadline horizon strictly progresses under the executor pump (the guard
against the PR-3 pump-loop hang pattern).  Plus the asyncio front-end:
many logical clients on one event loop, no thread per client.
"""
import asyncio
import math
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.tier0  # fast pre-commit subset

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster, Router, enoki_function, get_function
from repro.core.engine import BatchedInvocationEngine, EngineStats
from repro.core.store import store_contents, stores_equal

jax.config.update("jax_platform_name", "cpu")


@enoki_function(name="cp_mix", keygroups=["cpkg"], codec_width=8)
def cp_mix(kv, x):
    cur, found = kv.get("acc")
    kv.set("acc", cur + x)
    return cur[:2] + x[:2]


@enoki_function(name="cp_peek", keygroups=["cpkg"], codec_width=8)
def cp_peek(kv, x):
    cur, found = kv.get("acc")
    return cur[:2]


@enoki_function(name="cp_central", keygroups=["cpcloudkg"], codec_width=8)
def cp_central(kv, x):
    cur, _ = kv.get("n")
    kv.set("n", cur + 1.0)
    return cur[:1]


@enoki_function(name="cp_src", keygroups=[], calls=["cp_sink"], codec_width=8)
def cp_src(kv, x):
    return x[:2]


@enoki_function(name="cp_sink", keygroups=["cpsinkkg"], codec_width=8)
def cp_sink(kv, x):
    cur, _ = kv.get("n")
    kv.set("n", cur + 1.0)
    return x[:1]


def _x(v=1.0):
    return np.full(8, v, np.float32)


def _cluster():
    """The fixed 3-node topology of the determinism acceptance check."""
    c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                measure_compute=False)
    c.deploy(get_function("cp_mix"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    c.deploy(get_function("cp_peek"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    # a CLOUD_CENTRAL placement so a third store node is in play
    c.deploy(get_function("cp_central"), ["edge"],
             policy=ReplicationPolicy.CLOUD_CENTRAL)
    # a stateless caller + stateful callee: downstream waves in the cycle
    c.deploy(get_function("cp_sink"), ["edge"])
    c.deploy(get_function("cp_src"), ["edge"])
    return c


def _submit_stream(c, n=24):
    """A fixed mixed stream: three store nodes, two clients, downstream
    calls, staggered send times — several windows per flush cycle."""
    tks = []
    for i in range(n):
        t = i * 0.7
        node = ("edge", "edge2")[i % 2]
        client = ("client", "client2")[(i // 2) % 2]
        fn = ("cp_mix", "cp_peek", "cp_central", "cp_src")[i % 4]
        at = "edge" if fn in ("cp_central", "cp_src") else node
        tks.append(c.engine.submit(fn, at, _x(float(i)), t_send=t,
                                   client=client))
    return tks


def _result_key(r):
    return (np.asarray(r.output).tobytes(), r.t_sent, r.t_received,
            r.t_applied, r.response_ms, r.node, tuple(r.chain),
            tuple(r.kv_ops))


def _run_pipeline(workers):
    c = _cluster()
    c.engine = BatchedInvocationEngine(c, window_ms=5.0, workers=workers)
    c.engine.min_parallel_requests = 1      # force the pool on this stream
    tks = _submit_stream(c)
    out = {}
    # two partial pumps + a drain: multiple cycles through the shared pool
    out.update(c.engine.pump(8.0))
    out.update(c.engine.pump(16.0))
    out.update(c.engine.pump(math.inf))
    assert set(out) == set(tks)
    c.flush_replication()
    c.engine.close()
    return c, {t: _result_key(r) for t, r in out.items()}


def test_parallel_pump_matches_serial_results():
    """The acceptance determinism check: on the fixed 3-node topology the
    workers=4 pump yields a ticket→result map EQUAL to workers=1, and the
    clusters converge to identical stores and clocks."""
    c1, m1 = _run_pipeline(workers=1)
    c4, m4 = _run_pipeline(workers=4)
    assert m1 == m4
    for kg, nodes in (("cpkg", ("edge", "edge2")),
                      ("cpcloudkg", ("cloud",)),
                      ("cpsinkkg", ("edge",))):
        for nd in nodes:
            assert stores_equal(c1.nodes[nd].stores[kg],
                                c4.nodes[nd].stores[kg]), (kg, nd)
    for nd in ("edge", "edge2", "cloud"):
        np.testing.assert_array_equal(np.asarray(c1.nodes[nd].clock),
                                      np.asarray(c4.nodes[nd].clock))
    # the parallel run coalesced replication exactly like the serial one
    assert (c1.engine.stats.replication_coalesced
            == c4.engine.stats.replication_coalesced)
    assert c1.engine.stats.dispatches == c4.engine.stats.dispatches


@enoki_function(name="cp_nc_add", keygroups=["cpnckg"], codec_width=8)
def cp_nc_add(kv, x):
    cur, _ = kv.get("n")
    kv.set("n", cur + 1.0)
    return x[:1]


@enoki_function(name="cp_nc_mul", keygroups=["cpnckg"], codec_width=8)
def cp_nc_mul(kv, x):
    cur, _ = kv.get("n")
    kv.set("n", cur * 2.0 + 1.0)
    return x[:1]


@enoki_function(name="cp_call_add", keygroups=[], calls=["cp_nc_add"],
                codec_width=8)
def cp_call_add(kv, x):
    return x[:1]


@enoki_function(name="cp_call_mul", keygroups=[], calls=["cp_nc_mul"],
                codec_width=8)
def cp_call_mul(kv, x):
    return x[:1]


def test_wave_batches_on_shared_store_fold_in_serial_order():
    """Regression: two DISTINCT wave batches (different callees, fired
    from different caller nodes) that land on the SAME store node must
    fold in the serial pump's wave order under the parallel pump.  The
    sinks' writes do not commute (n+1 vs n*2+1), so any reordering
    diverges the store — the original parallel pipeline grouped frames by
    store node and got exactly this wrong."""
    stores, maps = [], []
    for workers in (1, 4):
        c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                    measure_compute=False)
        # both sinks write ONE CLOUD_CENTRAL keygroup (store node: cloud);
        # callers are stateless, one per edge node, so the wave carries
        # two distinct (callee, target, caller-node) batches to cloud
        c.deploy(get_function("cp_nc_add"), ["edge2"],
                 policy=ReplicationPolicy.CLOUD_CENTRAL)
        c.deploy(get_function("cp_nc_mul"), ["edge"],
                 policy=ReplicationPolicy.CLOUD_CENTRAL)
        c.deploy(get_function("cp_call_add"), ["edge2"])
        c.deploy(get_function("cp_call_mul"), ["edge"])
        c.deploy(get_function("cp_mix"), ["edge", "edge2"])
        c.engine = BatchedInvocationEngine(c, window_ms=5.0,
                                           workers=workers)
        c.engine.min_parallel_requests = 1
        tks = [c.engine.submit("cp_mix", "edge", _x(), t_send=0.0),
               c.engine.submit("cp_call_add", "edge2", _x(), t_send=0.1),
               c.engine.submit("cp_call_mul", "edge", _x(), t_send=0.2),
               c.engine.submit("cp_mix", "edge2", _x(), t_send=0.3)]
        out = c.engine.pump(math.inf)
        assert set(out) == set(tks)
        c.engine.close()
        stores.append(store_contents(c.nodes["cloud"].stores["cpnckg"]))
        maps.append({t: _result_key(r) for t, r in out.items()})
    assert stores[0] == stores[1]           # add-then-mul, both runs
    assert maps[0] == maps[1]


def test_parallel_pump_flush_on_full_matches_serial():
    """Flush-on-full (auto-flush on the submitting thread) under the
    executor pump still matches the serial engine."""
    maps = []
    for workers in (1, 4):
        c = _cluster()
        c.engine = BatchedInvocationEngine(c, window_ms=100.0, max_batch=4,
                                           workers=workers)
        tks = [c.engine.submit("cp_mix", ("edge", "edge2")[i % 2],
                               _x(float(i)), t_send=float(i))
               for i in range(10)]
        out = c.engine.pump(math.inf)
        assert set(out) == set(tks)
        assert c.engine.stats.auto_flushes == 2     # two full 4-windows
        c.engine.close()
        maps.append({t: _result_key(r) for t, r in out.items()})
    assert maps[0] == maps[1]


def test_next_deadline_strictly_progresses_under_executor_pump():
    """Pump-by-deadline with the parallel pump must terminate: every
    next_deadline() is strictly later than the one just pumped (guards
    the known pump-loop hang pattern), including hedge fire instants."""
    c = _cluster()
    c.engine = BatchedInvocationEngine(c, window_ms=10.0, workers=4)
    c.engine.min_parallel_requests = 1      # force the pool path
    c.set_compute_ms("edge", "cp_peek", 40.0)       # straggler: hedge fires
    router = Router(c, hedge_after_ms=4.0)
    for i in range(6):
        router.submit("cp_peek", _x(), t_send=i * 7.0)
    out, last, steps = {}, -math.inf, 0
    while (nd := router.next_deadline()) is not None:
        assert nd > last, f"horizon stalled at {nd}"
        last = nd
        out.update(router.pump(nd))
        steps += 1
        assert steps < 64, "pump loop failed to terminate"
    out.update(router.pump(math.inf))
    assert len(out) == 6
    c.engine.close()


def test_stats_inc_is_exact_under_contention():
    """The one mutation path of every stats counter is atomic: hammering
    inc() from many threads loses nothing."""
    stats = EngineStats()
    n_threads, per_thread = 8, 500

    def bump():
        for _ in range(per_thread):
            stats.inc("submitted")
            stats.inc("requests_flushed", 2)

    threads = [threading.Thread(target=bump) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert stats.submitted == n_threads * per_thread
    assert stats.requests_flushed == 2 * n_threads * per_thread


def _serve_cluster():
    c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                measure_compute=False)
    c.deploy(get_function("cp_mix"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    c.deploy(get_function("cp_peek"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    x = _x()
    for b in (1, 8, 64):                    # warm jit buckets off the clock
        c.invoke_batch("cp_mix", "edge", [x] * b)
        c.invoke_batch("cp_peek", "edge", [x] * b)
    c.flush_replication()
    return c


def _count(c, node):
    contents = store_contents(c.nodes[node].stores["cpkg"])
    return list(contents.values())[0][2][0] if contents else 0.0


def test_server_stress_racing_submitters():
    """N submitter threads race the serving loop and each other: every
    future resolves, no ticket is lost or served twice, the counter
    advances exactly once per write, and the stats ledger balances."""
    from repro.launch.faas_server import FaasServer
    c = _serve_cluster()
    seeded = _count(c, "edge")
    n_threads, per_thread = 6, 12
    total = n_threads * per_thread
    results, errors = [], []
    lock = threading.Lock()
    flushed_before = c.engine.stats.requests_flushed    # warm-up traffic
    with FaasServer(c, window_ms=5.0, time_scale=200.0, workers=4) as srv:
        def client(cid):
            try:
                futs = [srv.submit("cp_mix", _x(), session_id=f"s{cid}")
                        for _ in range(per_thread)]
                rs = [f.result(timeout=60.0) for f in futs]
            except BaseException as e:
                with lock:
                    errors.append(e)
                return
            with lock:
                results.extend((f.ticket, r) for f, r in zip(futs, rs))

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_threads)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert time.perf_counter() - t0 < 60.0
    assert errors == []
    # no lost or duplicated tickets
    assert len(results) == total
    assert len({tk for tk, _ in results}) == total
    # every write landed exactly once (the counter is a perfect ledger)
    c.flush_replication()
    assert _count(c, "edge") == _count(c, "edge2") == seeded + total
    # stats sum correctly under contention
    assert srv.stats.submitted == total
    assert srv.stats.served == total
    assert srv.stats.lost == 0
    assert srv.router.stats.requests == total
    assert c.engine.stats.requests_flushed - flushed_before == total
    # EXACT conservation ledger (per-frame-terminal accounting): every
    # submitted request flushed exactly once, and with no churn the
    # reroute/drop counters must not drift — a request that is never
    # moved is never counted, no matter how many cycles/waves it crossed
    eng = c.engine.stats
    assert eng.submitted == eng.requests_flushed + eng.dropped_dead
    assert eng.reroutes == 0
    assert eng.dropped_dead == 0
    # per-replica latency EWMAs got fed by the completions
    assert srv.router.stats.ewma_ms          # non-empty
    assert all(v > 0 for v in srv.router.stats.ewma_ms.values())


def test_asyncio_front_end_many_logical_clients():
    """One event loop hosts many logical closed-loop clients through
    async_submit — no thread per client — and the result ledger matches
    the thread-based drivers'."""
    from repro.launch.faas_server import (FaasServer, serve_closed_loop_async)
    c = _serve_cluster()
    seeded = _count(c, "edge")
    n = 24

    async def drive(srv):
        # a lone await first: async_submit resolves like a plain future
        r0 = await srv.async_submit("cp_peek", _x())
        assert float(np.asarray(r0.output)[0]) == seeded
        return await serve_closed_loop_async(
            srv, "cp_mix", lambda i: _x(), n_requests=n, concurrency=8,
            timeout_s=60.0, session_prefix="ac")

    with FaasServer(c, window_ms=5.0, time_scale=200.0, workers=2) as srv:
        results = asyncio.run(drive(srv))
    assert len(results) == n
    assert srv.stats.lost == 0
    c.flush_replication()
    assert _count(c, "edge") == seeded + n
    # sessions folded every batched write (reads-your-writes held)
    assert srv.router.sessions["ac0"] is not None


def test_cancelled_future_does_not_kill_the_serving_loop():
    """A client cancelling its future (asyncio task cancellation reaches
    the ServedRequest through wrap_future) must not crash the serving
    thread when its result arrives — later requests still serve."""
    from repro.launch.faas_server import FaasServer
    c = _serve_cluster()
    with FaasServer(c, window_ms=50.0, time_scale=50.0, workers=2) as srv:
        doomed = srv.submit("cp_peek", _x())
        assert doomed.cancel()              # still queued: cancel wins
        fut = srv.submit("cp_peek", _x())
        res = fut.result(timeout=30.0)      # loop survived the delivery
        assert res is not None
        # an asyncio timeout cancelling mid-flight is the same path
        async def impatient():
            try:
                await asyncio.wait_for(
                    srv.async_submit("cp_peek", _x()), timeout=1e-4)
            except asyncio.TimeoutError:
                pass
        asyncio.run(impatient())
        assert srv.submit("cp_peek", _x()).result(timeout=30.0) is not None
    assert srv.stats.lost == 0              # cancelled != lost


def test_use_workers_validation_and_close_idempotent():
    c = _cluster()
    with pytest.raises(ValueError, match="workers"):
        c.engine.use_workers(0)
    c.engine.use_workers(2)
    t = c.engine.submit("cp_peek", "edge", _x())
    assert set(c.engine.flush()) == {t}
    c.engine.close()
    c.engine.close()                        # idempotent
    # pool rebuilds lazily after close
    t2 = c.engine.submit("cp_peek", "edge", _x())
    assert set(c.engine.flush()) == {t2}
    c.engine.close()
