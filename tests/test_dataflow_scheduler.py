"""Per-frame dataflow scheduler: a straggling store node must delay only
the frames that fold into it (fast nodes' windows stream out mid-cycle via
``engine.on_ready``), dispatch order must respect per-store-node seal
(fold) order under any workers setting, and dead-node reroutes are counted
at most once per request no matter how many times a request moves."""
import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.tier0  # fast pre-commit subset

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster, enoki_function, get_function

jax.config.update("jax_platform_name", "cpu")

_NODES = ["edge", "edge2", "edge3"]


@enoki_function(name="dfs_leaf", keygroups=[], codec_width=4)
def dfs_leaf(kv, x):
    """Stateless leaf — its store key is the serving node itself, so the
    three nodes' windows ride three independent lanes."""
    return x[:2]


@enoki_function(name="dfs_parent", keygroups=[], calls=["dfs_sink"],
                codec_width=4)
def dfs_parent(kv, x):
    return x[:2]


@enoki_function(name="dfs_sink", keygroups=["dfskg"], codec_width=4)
def dfs_sink(kv, x):
    cur, _ = kv.get("n")
    kv.set("n", cur + 1.0)
    return x[:1]


def _x(v=1.0):
    return np.full(4, v, np.float32)


def _leaf_cluster():
    c = Cluster({n: "edge" for n in _NODES}, measure_compute=False)
    c.deploy(get_function("dfs_leaf"), _NODES,
             policy=ReplicationPolicy.REPLICATED)
    # warm every node's singleton-bucket compile OUTSIDE the timed region
    for n in _NODES:
        c.invoke("dfs_leaf", n, _x())
    return c


def _slow_wrap(c, node, fn, sleep_s):
    """Wall-clock straggler: ``set_compute_ms`` is virtual-only, so slow a
    lane for real by wrapping the node's batched handler in a sleep."""
    nd = c.nodes[node]
    orig = nd.batched_handlers[fn]
    done = [None]

    def slow(*a, **kw):
        time.sleep(sleep_s)
        out = orig(*a, **kw)
        done[0] = time.perf_counter()
        return out

    nd.batched_handlers[fn] = slow
    return done


# ---------------------------------------------------------------------------
# straggler store node: fast lanes stream, slow lane delays only itself
# ---------------------------------------------------------------------------

def test_fast_nodes_stream_past_straggler():
    """One store node 10x+ slower than the rest: the fast nodes' windows
    must DELIVER (on_ready) before the slow node's handler has even
    finished — under the old wave barrier every result waited for the
    whole cycle."""
    c = _leaf_cluster()
    eng = c.engine
    slow_done = _slow_wrap(c, "edge3", "dfs_leaf", sleep_s=0.25)
    deliveries = []     # (wall stamp, tickets) per on_ready call
    eng.on_ready = lambda res: deliveries.append(
        (time.perf_counter(), set(res)))
    eng.configure(window_ms=5.0).use_workers(4)
    eng.min_parallel_requests = 1
    tks = {n: eng.submit("dfs_leaf", n, _x()) for n in _NODES}
    out = eng.pump(1e9)
    assert out == {}                        # everything streamed out
    assert slow_done[0] is not None
    delivered = {}
    for stamp, tickets in deliveries:
        for t in tickets:
            delivered[t] = stamp
    assert set(delivered) == set(tks.values())
    for n in ("edge", "edge2"):
        assert delivered[tks[n]] < slow_done[0], \
            f"{n}'s window waited for the straggler (wave barrier is back?)"


def test_wave_barrier_restores_cycle_end_delivery():
    """The A/B compat knob: with ``wave_barrier=True`` nothing streams
    mid-cycle — every result comes back at pump return, after the slow
    lane too."""
    c = _leaf_cluster()
    eng = c.engine
    _slow_wrap(c, "edge3", "dfs_leaf", sleep_s=0.05)
    fired = []
    eng.on_ready = lambda res: fired.append(set(res))
    eng.wave_barrier = True
    eng.configure(window_ms=5.0).use_workers(4)
    eng.min_parallel_requests = 1
    tks = {n: eng.submit("dfs_leaf", n, _x()) for n in _NODES}
    out = eng.pump(1e9)
    assert fired == []
    assert set(out) == set(tks.values())


# ---------------------------------------------------------------------------
# property: dispatch order respects per-store-node seal (fold) order
# ---------------------------------------------------------------------------

def _traced_cluster(workers):
    c = Cluster({n: "edge" for n in _NODES}, measure_compute=False)
    c.deploy(get_function("dfs_sink"), _NODES,
             policy=ReplicationPolicy.REPLICATED)
    c.deploy(get_function("dfs_parent"), _NODES,
             policy=ReplicationPolicy.REPLICATED)
    c.engine.configure(window_ms=5.0)
    if workers:
        c.engine.use_workers(workers)
        c.engine.min_parallel_requests = 1
    c.engine.trace_folds = True
    return c


_TRACED = {}


def _get_traced(workers):
    if workers not in _TRACED:
        _TRACED[workers] = _traced_cluster(workers)
    return _TRACED[workers]


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(_NODES), st.integers(1, 3)),
                min_size=1, max_size=5))
def test_fold_order_respects_per_store_seal_order(plan):
    """For every store node, tasks must EXECUTE in seal-sequence order —
    the fold-clock invariant the per-request LWW semantics hang on — and
    the parallel scheduler's ticket→result map must stay bit-identical to
    the serial one (determinism contract)."""
    outs = {}
    for workers in (None, 4):
        c = _get_traced(workers)
        eng = c.engine
        eng.fold_trace.clear()
        tickets = []
        for i, (node, k) in enumerate(plan):
            for j in range(k):
                tickets.append(eng.submit("dfs_parent", node,
                                          _x(float(i + j)),
                                          t_send=float(i)))
        res = eng.pump(1e9)
        assert set(res) == set(tickets)
        # the invariant: per store key, execution order == seal order
        last = {}
        for key, seq in eng.fold_trace:
            assert last.get(key, -1) < seq, \
                f"lane {key!r} executed seq {seq} after {last[key]}"
            last[key] = seq
        outs[workers] = [np.asarray(res[t].output) for t in tickets]
    for a, b in zip(outs[None], outs[4]):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# reroute accounting is per-request-terminal
# ---------------------------------------------------------------------------

def test_reroute_counted_once_per_request():
    """A request whose rerouted target ALSO dies moves again but is
    counted once — pre-fix, each eviction sweep re-counted the whole
    window and the reroute ledger drifted."""
    c = _leaf_cluster()
    eng = c.engine
    eng.configure(window_ms=50.0)
    base = eng.stats.reroutes
    tks = [eng.submit("dfs_leaf", "edge", _x(float(i)), t_send=0.0)
           for i in range(3)]
    c.naming.mark_dead("edge")
    eng.pump(0.0)                           # sweep only: nothing is due yet
    assert eng.stats.reroutes - base == 3   # moved edge -> edge2
    c.naming.mark_dead("edge2")
    out = eng.pump(1e9)                     # second sweep + dispatch
    assert set(out) == set(tks)
    assert all(out[t].node == "edge3" for t in tks)
    assert eng.stats.reroutes - base == 3   # the second move is NOT re-counted
    assert eng.stats.dropped_dead == 0
