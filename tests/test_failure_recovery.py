"""Fault-injection harness: deterministic crash/partition/restore scenarios
on a 3-node topology, plus a hypothesis-driven churn property test against
``ElasticMembership``.

The contracts under test:

* at-most-once — killing a node mid-serve never HANGS or silently loses a
  ticket: every queued request either completes (rerouted to a surviving
  replica) or disappears from the engine's pending view so the server can
  fail it fast;
* recovery — after a restore (peer catch-up or checkpoint fallback) the
  revived replica's store is byte-identical (``stores_equal``) to the
  surviving copy;
* membership invariants — under random join/leave/crash schedules every
  keygroup keeps >= 1 live replica and session reads-your-writes holds
  across re-pinning.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster, Router, enoki_function, get_function
from repro.core.store import stores_equal
from repro.runtime import ElasticMembership, FailureInjector

jax.config.update("jax_platform_name", "cpu")


@enoki_function(name="frctr", keygroups=["frcnt"], codec_width=4)
def frctr(kv, x):
    cur, found = kv.get("count")
    new = jnp.where(found, cur[0] + 1.0, 1.0)
    kv.set("count", jnp.stack([new, 0.0, 0.0, 0.0]))
    return jnp.stack([new])


def make_cluster(**kw):
    kw.setdefault("measure_compute", False)
    return Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"}, **kw)


def deploy_replicated(c, nodes=("edge", "edge2")):
    c.deploy(get_function("frctr"), list(nodes),
             policy=ReplicationPolicy.REPLICATED)


# ---------------------------------------------------------------------------
# scenario 1: kill during a flush cycle
# ---------------------------------------------------------------------------

def test_kill_during_flush_cycle_reroutes_queued_windows():
    """Requests queued against a node that dies BEFORE their flush must be
    rerouted to the surviving replica — same tickets, correct fold order —
    not raise and not hang."""
    c = make_cluster()
    deploy_replicated(c)
    m = ElasticMembership(c)
    inj = FailureInjector(c, membership=m)

    tickets = [c.engine.submit("frctr", "edge", jnp.zeros((1,)),
                               t_send=float(i)) for i in range(4)]
    inj.kill_node("edge")
    out = c.engine.flush()

    assert set(tickets) <= set(out), "every queued ticket must complete"
    assert all(out[t].node == "edge2" for t in tickets), \
        "completions must come from the surviving replica"
    assert [float(np.asarray(out[t].output)[0]) for t in tickets] == \
        [1.0, 2.0, 3.0, 4.0], "rerouted fold must keep submission order"
    assert c.engine.stats.reroutes == 4
    assert c.engine.pending() == [], "nothing may stay queued on a dead node"


def test_kill_all_replicas_fails_fast_not_hangs():
    """With NO surviving deployment the queued tickets are dropped from the
    engine (at-most-once fail-fast): absent from results AND from pending,
    so a serving loop can fail their futures instead of hanging."""
    c = make_cluster()
    deploy_replicated(c)
    m = ElasticMembership(c)
    inj = FailureInjector(c, membership=m)
    router = Router(c)

    t1 = router.submit("frctr", jnp.zeros((1,)))
    inj.kill_node("edge")
    inj.kill_node("edge2")
    out = router.flush()

    assert t1 not in out
    assert c.engine.pending() == []
    assert c.engine.stats.dropped_dead == 1
    assert not router.tracks(t1), \
        "router must prune the dropped ticket so the server fails it fast"


def test_kill_between_submit_and_dispatch_in_flight_frame():
    """A crash landing between window collection and the pool job's
    dispatch converts the frame to a rerouted one (the in-dispatch
    failover in ``_exec_chunk``), exercised here via dispatch()."""
    c = make_cluster()
    deploy_replicated(c)
    m = ElasticMembership(c)

    # dispatch() runs the cycle directly; kill first so the frame's target
    # is dead at _exec_chunk time
    m.crash("edge")
    rs = c.engine.dispatch("frctr", "edge", [jnp.zeros((1,))] * 2,
                           t_sends=[0.0, 1.0])
    assert [r.node for r in rs] == ["edge2", "edge2"]
    assert [float(np.asarray(r.output)[0]) for r in rs] == [1.0, 2.0]


# ---------------------------------------------------------------------------
# scenario 2: kill with pending replication
# ---------------------------------------------------------------------------

def test_kill_with_pending_replication_then_restore_converges():
    """Replication events on the wire TO a crashing node die with it; a
    restore catches the node up from the surviving peer's log view and the
    stores end byte-identical."""
    c = make_cluster()
    deploy_replicated(c)
    m = ElasticMembership(c)
    inj = FailureInjector(c, membership=m)

    r = c.invoke("frctr", "edge", jnp.zeros((1,)))
    assert c.pending_replication("edge2"), "write must schedule a delivery"

    inj.kill_node("edge2")
    assert c.pending_replication("edge2") == [], \
        "a crash drops what was still on the wire to the node"
    assert m.stats.dropped_deliveries >= 1

    # the survivor keeps serving (state intact)
    r2 = c.invoke("frctr", "edge", jnp.zeros((1,)), t_send=r.t_received)
    assert float(np.asarray(r2.output)[0]) == 2.0

    inj.restore_node("edge2", t=1e12)
    assert c.naming.is_alive("edge2")
    assert stores_equal(c.store_of("frcnt", "edge"),
                        c.store_of("frcnt", "edge2")), \
        "restored replica must be byte-identical to the survivor"


# ---------------------------------------------------------------------------
# scenario 3: partition then heal
# ---------------------------------------------------------------------------

def test_partition_then_heal_converges():
    """A severed link stalls replication (infinite arrival) without
    violating at-most-once — both sides keep serving their own state — and
    after healing, the next write's snapshot carries the backlog across."""
    c = make_cluster()
    deploy_replicated(c)
    inj = FailureInjector(c)

    inj.partition("edge", "edge2")
    r1 = c.invoke("frctr", "edge", jnp.zeros((1,)))
    # flush with a LARGE FINITE horizon: events scheduled across the
    # severed link carry arrival=inf and must NOT deliver
    c.flush_replication(1e12)
    assert not stores_equal(c.store_of("frcnt", "edge"),
                            c.store_of("frcnt", "edge2")), \
        "partitioned peer must not have observed the write"

    # both partitions still serve (their own replica, at-most-once intact)
    r_far = c.invoke("frctr", "edge2", jnp.zeros((1,)), t_send=0.0)
    assert float(np.asarray(r_far.output)[0]) == 1.0, \
        "partitioned replica serves from its own (stale) state"

    inj.heal("edge", "edge2")
    # snapshot replication: the next write on either side ships the whole
    # arena, folding the backlog in via LWW merge
    c.invoke("frctr", "edge", jnp.zeros((1,)), t_send=r1.t_received)
    c.invoke("frctr", "edge2", jnp.zeros((1,)), t_send=r1.t_received)
    c.flush_replication(1e12)
    assert stores_equal(c.store_of("frcnt", "edge"),
                        c.store_of("frcnt", "edge2")), \
        "healed replicas must converge byte-for-byte"


# ---------------------------------------------------------------------------
# scenario 4: crash + restore from checkpoint
# ---------------------------------------------------------------------------

def test_crash_restore_from_checkpoint(tmp_path):
    """When the LAST live copy of a keygroup dies, the crash path revives
    it on a survivor from the node's latest checkpoint — byte-identical to
    checkpoint-time state; writes after the checkpoint are the documented
    loss window.  PEER_FETCH with the dying node as owner: the single
    placed copy lives exactly there, and recovery must also re-home the
    owner so surviving deployments resolve placement to the new store."""
    c = make_cluster()
    c.deploy(get_function("frctr"), ["edge", "edge2"],
             policy=ReplicationPolicy.PEER_FETCH, owner="edge")
    m = ElasticMembership(c, checkpoint_dir=str(tmp_path))
    inj = FailureInjector(c, membership=m)

    c.invoke("frctr", "edge", jnp.zeros((1,)))
    c.invoke("frctr", "edge", jnp.zeros((1,)), t_send=100.0)
    m.checkpoint("edge", step=1)
    expected = c.store_of("frcnt", "edge")       # stores are immutable:
    c.invoke("frctr", "edge", jnp.zeros((1,)), t_send=200.0)  # not in ckpt

    rehomed = inj.membership.crash("edge")
    assert rehomed.get("frcnt"), "sole replica must be re-homed somewhere"
    target = rehomed["frcnt"]
    assert m.stats.checkpoint_restores == 1
    assert stores_equal(expected, c.store_of("frcnt", target)), \
        "revived store must match the checkpoint byte-for-byte"
    assert c.policies["frcnt"].owner == target, \
        "the owner must be re-homed to the revived copy"

    # serving continues from checkpointed state via the surviving
    # deployment (kv ops resolve to the re-homed owner store)
    router = Router(c)
    r = router.invoke("frctr", jnp.zeros((1,)), t_send=300.0)
    assert r.node == "edge2"
    assert float(np.asarray(r.output)[0]) == 3.0, \
        "counter resumes from the checkpointed value (2 -> 3)"


def test_crash_without_checkpoint_restores_fresh():
    """No checkpoint configured: the sole replica's data is lost, but the
    keygroup itself survives (fresh arena at the new home) so serving
    continues — loss is visible in stats, never silent."""
    c = make_cluster()
    c.deploy(get_function("frctr"), ["edge", "edge2"],
             policy=ReplicationPolicy.PEER_FETCH, owner="edge")
    m = ElasticMembership(c)

    c.invoke("frctr", "edge", jnp.zeros((1,)))
    rehomed = m.crash("edge")
    assert "frcnt" in rehomed
    assert m.stats.fresh_restores == 1
    r = Router(c).invoke("frctr", jnp.zeros((1,)), t_send=100.0)
    assert r.node == "edge2"
    assert float(np.asarray(r.output)[0]) == 1.0, "state restarted fresh"


# ---------------------------------------------------------------------------
# churn property test (hypothesis; shimmed deterministically when absent)
# ---------------------------------------------------------------------------

_CHURN_NODES = ("edge", "edge2", "cloud")
_churn_env = {}


def _churn_cluster():
    """One cluster reused across examples (deploy compiles 3 handlers —
    per-example rebuilds would blow the tier0 budget).  Each example
    starts by restoring every dead node, so schedules are independent."""
    if not _churn_env:
        c = make_cluster()
        c.deploy(get_function("frctr"), list(_CHURN_NODES),
                 policy=ReplicationPolicy.REPLICATED)
        m = ElasticMembership(c, min_replicas=2)
        _churn_env.update(c=c, m=m, r=Router(c), t=[0.0], last=[0.0])
    env = _churn_env
    for n in _CHURN_NODES:
        if env["m"].state.get(n) == "dead":
            env["m"].restore(n, t=1e15)
    return env


@pytest.mark.tier0
@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["crash", "restore", "invoke"]),
                          st.sampled_from(_CHURN_NODES)),
                min_size=1, max_size=12))
def test_churn_keeps_replicas_and_reads_your_writes(schedule):
    env = _churn_cluster()
    c, m, router = env["c"], env["m"], env["r"]

    def step_time(ms=500.0):
        env["t"][0] += ms
        return env["t"][0]

    for op, node in schedule:
        if op == "crash":
            # never take down the last live deployment: the serving
            # invariant below needs one survivor (real deployments gate
            # scale-in the same way)
            alive = [n for n in _CHURN_NODES
                     if m.state.get(n) == "alive"]
            if len(alive) > 1 and m.state.get(node) == "alive":
                m.crash(node)
        elif op == "restore":
            if m.state.get(node) == "dead":
                m.restore(node, t=1e15)
        else:
            r = router.invoke("frctr", jnp.zeros((1,)),
                              t_send=step_time(), session_id="churn")
            v = float(np.asarray(r.output)[0])
            assert v > env["last"][0], \
                "reads-your-writes: the counter can never regress " \
                "across re-pinning"
            env["last"][0] = v
            # quiesce replication before the next churn op so a crash
            # cannot eat an un-replicated write (the harness's contract;
            # un-flushed loss is scenario 2's territory)
            c.flush_replication(1e15)

        # invariant: every keygroup keeps >= 1 live replica
        for kg in c.policies:
            live = [n for n in c.naming.replicas_of(kg)
                    if c.naming.is_alive(n)]
            assert live, f"keygroup {kg!r} lost every live replica"
