"""Pod-axis integration tests on an 8-device test mesh (2 pods × 2 data ×
2 model).  Runs in a subprocess so XLA_FLAGS applies without polluting the
other tests' single-device world."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import (EnokiConfig, ReplicationPolicy, SHAPES_BY_NAME,
                               TrainConfig, get_arch, reduced, reduced_shape)
    from repro.launch import train as train_mod
    from repro.launch.mesh import make_test_mesh
    from repro.data import synthetic_batch
    from repro.optim import diloco_init

    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    arch = reduced(get_arch("internlm2-1.8b"))
    shape = reduced_shape(SHAPES_BY_NAME["train_4k"])
    enoki = EnokiConfig(policy=ReplicationPolicy.REPLICATED,
                        replication_period=2)
    from repro.configs import ParallelConfig
    par = ParallelConfig(fsdp=False, remat="none", optimizer="adamw")

    step, sshape, (sspecs, bspecs) = train_mod.make_train_step(
        arch, shape, mesh, par, enoki, TrainConfig(lr=1e-3), donate=False)

    # materialise pod-stacked state: 2 pods, identical init
    from repro.models import model_zoo as zoo
    single = train_mod.init_state(arch, jax.random.PRNGKey(0), par)
    state = jax.tree.map(lambda l: jnp.stack([l, l]), single)
    from repro.parallel.sharding import named
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state,
                         named(mesh, sspecs))

    def stacked_batch(step_i):
        b0 = synthetic_batch(arch, shape, 0, step_i, shard=0, num_shards=2,
                             batch_override=4)
        b1 = synthetic_batch(arch, shape, 0, step_i, shard=1, num_shards=2,
                             batch_override=4)
        return jax.tree.map(lambda a, b: jnp.stack([a, b]), b0, b1)

    # 1. hot path: pods diverge (different data, no cross-pod sync)
    for i in range(2):
        state, metrics = step(state, stacked_batch(i))
    p0 = jax.tree.leaves(state["params"])[0][0]
    p1 = jax.tree.leaves(state["params"])[0][1]
    div = float(jnp.abs(p0 - p1).max())
    assert div > 0, "pods must diverge between anti-entropy rounds"
    print("DIVERGENCE_OK", div)

    # 2. anti-entropy: replicate_step converges the pods (staleness -> 0)
    rstep, outer_shape, _ = train_mod.make_replicate_step(
        arch, mesh, par, enoki, sshape)
    outer = diloco_init(single["params"])
    state, outer = rstep(state, outer)
    p0 = jax.tree.leaves(state["params"])[0][0]
    p1 = jax.tree.leaves(state["params"])[0][1]
    conv = float(jnp.abs(p0 - p1).max())
    assert conv == 0.0, f"replicas must converge after anti-entropy: {conv}"
    print("CONVERGENCE_OK", conv)

    # 3. loss trends down across rounds (outer optimizer optimises; a few
    # noisy steps, so compare window means)
    losses = []
    for i in range(2, 20):
        state, metrics = step(state, stacked_batch(i))
        losses.append(float(metrics["loss"][0]))
        if i % 2:
            state, outer = rstep(state, outer)
    first, last = sum(losses[:5]) / 5, sum(losses[-5:]) / 5
    assert last < first, f"loss must trend down: {first} -> {last} ({losses})"
    print("LOSS_OK", first, "->", last)

    # 4. serving: session replication + failover on the pod axis
    from repro.launch import serve as serve_mod
    import dataclasses
    dshape = dataclasses.replace(reduced_shape(SHAPES_BY_NAME["decode_32k"]),
                                 seq_len=32, global_batch=4)
    dstep, shapes, specs = serve_mod.make_decode_step(
        arch, dshape, mesh, donate=False)
    params_b16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                              single["params"])
    sparams = jax.tree.map(lambda l: jnp.stack([l, l]), params_b16)
    cache = zoo.init_cache(arch, 2, 32)
    cache = jax.tree.map(lambda l: jnp.stack([l, l]), cache)
    token = jnp.ones((2, 2, 1), jnp.int32)
    for _ in range(3):
        token, cache = dstep(sparams, cache, token)
    rsess, rshape, _ = serve_mod.make_replicate_sessions_step(
        arch, dshape, mesh)
    backup = rsess(cache)
    # pod1's backup slot holds pod0's sessions
    np.testing.assert_array_equal(np.asarray(backup["k"][1]),
                                  np.asarray(cache["k"][0]))
    mstep, _, _ = serve_mod.make_migrate_sessions_step(arch, dshape, mesh)
    dead = jnp.asarray([True, False])
    restored = mstep(cache, backup, dead)
    # pod0 flagged dead -> its slot now carries the backup contents
    np.testing.assert_array_equal(np.asarray(restored["k"][0]),
                                  np.asarray(backup["k"][0]))
    print("SERVE_FAILOVER_OK")
""")


@pytest.mark.slow
def test_pod_replication_end_to_end(tmp_path):
    script = tmp_path / "pod_test.py"
    script.write_text(SCRIPT)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    for marker in ("DIVERGENCE_OK", "CONVERGENCE_OK", "LOSS_OK",
                   "SERVE_FAILOVER_OK"):
        assert marker in res.stdout, res.stdout
