"""Tests for the concurrency-contract checkers.

Static half (``repro.analysis.lockcheck``): every rule must flag its
seeded-violation fixture, the suppression syntax must silence it, and —
the acceptance bar — the REAL tree under ``src/repro`` must lint clean.

Runtime half (``repro.analysis.lockdep``): ordered wrappers enforce the
declared order at acquire time, the on_ready delta edges are legal, the
condition-wait pattern works, distinct same-name instances are rejected,
and a cross-thread A->B / B->A inversion is caught as a cycle in the
acquisition graph even when each thread is locally consistent.

Doc sync: the hierarchy block in ``docs/batched_engine.md`` is generated
from ``lock_order`` and must not drift.
"""
from __future__ import annotations

import pathlib
import threading
import textwrap

import pytest

from repro.analysis import lock_order, lockdep
from repro.analysis.lockcheck import check_paths, check_source
from repro.analysis.lockdep import LockOrderViolation

pytestmark = pytest.mark.tier0  # fast pre-commit subset

REPO = pathlib.Path(__file__).resolve().parents[1]


def _rules(src: str):
    return [f.rule for f in check_source(textwrap.dedent(src))]


# ---------------------------------------------------------------------------
# the spec itself
# ---------------------------------------------------------------------------

def test_lock_order_is_a_dag():
    lock_order.assert_dag()     # raises on cycle / unknown / leaf out-edge


def test_on_ready_delta_is_declared_not_reversed():
    # the delta edges exist ...
    assert lock_order.allowed("engine.cycle_lock", "router.lock")
    assert lock_order.allowed("engine.cycle_lock", "server.cond")
    # ... and the reverse direction (which would complete a deadlock
    # cycle) does not
    assert not lock_order.allowed("router.lock", "engine.cycle_lock")
    assert not lock_order.allowed("server.cond", "engine.cycle_lock")


def test_leaf_semantics():
    assert lock_order.allowed("engine.cycle_lock", "stats.lock")
    assert not lock_order.allowed("stats.lock", "engine.qlock")
    assert not lock_order.allowed("engine.cycle_state_lock", "stats.lock")


def test_transitive_closure():
    # pump_lock reaches qlock only through router/cycle edges
    assert lock_order.allowed("server.pump_lock", "engine.qlock")
    assert not lock_order.allowed("engine.qlock", "server.pump_lock")


# ---------------------------------------------------------------------------
# static lint: seeded violations
# ---------------------------------------------------------------------------

def test_flags_inverted_acquisition():
    assert _rules("""
        class BatchedInvocationEngine:
            def bad(self):
                with self._qlock:
                    with self._cycle_lock:
                        pass
    """) == ["order"]


def test_flags_inversion_through_call_graph():
    assert _rules("""
        class BatchedInvocationEngine:
            def helper(self):
                with self._cycle_lock:
                    pass
            def bad(self):
                with self._qlock:
                    self.helper()
    """) == ["order"]


def test_flags_dispatch_under_qlock():
    assert _rules("""
        class BatchedInvocationEngine:
            def bad(self, xs):
                with self._qlock:
                    return self._exec_group(xs)
    """) == ["dispatch-under-qlock"]
    assert _rules("""
        import jax
        class BatchedInvocationEngine:
            def bad(self, xs):
                with self._qlock:
                    return jax.vmap(lambda x: x)(xs)
    """) == ["dispatch-under-qlock"]


def test_flags_raw_stats_increment():
    assert _rules("""
        class Router:
            def bad(self):
                self.stats.requests += 1
    """) == ["stats-raw-increment"]


def test_flags_blocking_under_cycle_lock():
    assert _rules("""
        import time
        class BatchedInvocationEngine:
            def bad(self):
                with self._cycle_lock:
                    time.sleep(0.1)
    """) == ["blocking-under-lock"]


def test_flags_future_result_under_router_lock():
    assert _rules("""
        class Router:
            def bad(self, fut):
                with self._lock:
                    return fut.result(timeout=1.0)
    """) == ["blocking-under-lock"]


def test_condition_self_wait_is_exempt():
    assert _rules("""
        class FaasServer:
            def ok(self):
                with self._cond:
                    self._cond.wait(0.1)
    """) == []


def test_flags_guarded_field_without_lock():
    assert _rules("""
        class FaasServer:
            def bad(self):
                self._submit_gen += 1
            def ok(self):
                with self._cond:
                    self._submit_gen += 1
    """) == ["guarded-field"]


def test_flags_unlocked_shared_counter():
    assert _rules("""
        class Cluster:
            def bad(self):
                self.hits += 1
    """) == ["shared-counter"]


def test_flags_acquire_under_leaf_via_inc():
    # the shape of the bug this PR fixed: AtomicStats.inc (which takes
    # the stats lock) reached from under the per-cycle leaf lock
    assert _rules("""
        class AtomicStats:
            def inc(self, name, n=1):
                with self._lock:
                    setattr(self, name, getattr(self, name) + n)
        class BatchedInvocationEngine:
            def _exec_chunk(self, cycle, rkey):
                with cycle.lock:
                    if rkey in cycle.repl:
                        self.stats.inc("x")
    """) == ["order"]


# ---------------------------------------------------------------------------
# static lint: suppressions
# ---------------------------------------------------------------------------

def test_line_suppression_silences_rule():
    assert _rules("""
        import time
        class BatchedInvocationEngine:
            def ok(self):
                with self._cycle_lock:
                    time.sleep(0.1)   # lockcheck: ok[blocking-under-lock]
    """) == []


def test_suppression_is_rule_specific():
    assert _rules("""
        import time
        class BatchedInvocationEngine:
            def bad(self):
                with self._cycle_lock:
                    time.sleep(0.1)   # lockcheck: ok[order]
    """) == ["blocking-under-lock"]


def test_single_threaded_class_annotation():
    assert _rules("""
        class Cluster:   # lockcheck: single-threaded
            def ok(self):
                self.hits += 1
    """) == []


# ---------------------------------------------------------------------------
# the acceptance bar: the real tree is clean
# ---------------------------------------------------------------------------

def test_src_tree_is_clean():
    findings = check_paths([str(REPO / "src" / "repro")])
    assert findings == [], "\n".join(str(f) for f in findings)


# ---------------------------------------------------------------------------
# docs sync
# ---------------------------------------------------------------------------

def test_docs_hierarchy_in_sync():
    doc = REPO / "docs" / "batched_engine.md"
    assert lock_order.check_docs(doc), (
        "docs/batched_engine.md hierarchy block drifted from "
        "lock_order.py — run `python -m repro.analysis.lock_order --write`")


# ---------------------------------------------------------------------------
# runtime validator
# ---------------------------------------------------------------------------

@pytest.fixture
def lockdep_session():
    lockdep.enable()
    try:
        yield
    finally:
        lockdep.disable()


def test_lockdep_disabled_returns_plain_primitives():
    assert not lockdep.enabled()
    lk = lockdep.make_lock("engine.qlock")
    assert not isinstance(lk, lockdep.OrderedLock)
    with lk:
        pass


def test_lockdep_rejects_inversion(lockdep_session):
    q = lockdep.make_rlock("engine.qlock")
    cyc = lockdep.make_rlock("engine.cycle_lock")
    with cyc:       # declared direction: fine
        with q:
            pass
    with pytest.raises(LockOrderViolation):
        with q:
            with cyc:
                pass
    assert lockdep.verify()     # also recorded for teardown checks


def test_lockdep_allows_on_ready_delta(lockdep_session):
    cyc = lockdep.make_rlock("engine.cycle_lock")
    router = lockdep.make_rlock("router.lock")
    cond = lockdep.make_condition("server.cond")
    with cyc:
        with router:
            pass
        with cond:
            pass
    assert lockdep.verify() == []


def test_lockdep_rejects_acquire_under_leaf(lockdep_session):
    stats = lockdep.make_lock("stats.lock")
    q = lockdep.make_rlock("engine.qlock")
    with pytest.raises(LockOrderViolation):
        with stats:
            with q:
                pass


def test_lockdep_rejects_peer_instance_nesting(lockdep_session):
    n1 = lockdep.make_rlock("cluster.node_lock")
    n2 = lockdep.make_rlock("cluster.node_lock")
    with n1:        # reentrancy on the SAME instance is fine
        with n1:
            pass
    with pytest.raises(LockOrderViolation):
        with n1:
            with n2:
                pass


def test_lockdep_condition_wait_releases_held_entry(lockdep_session):
    cond = lockdep.make_condition("server.cond")
    with cond:
        assert cond.wait(0.01) is False     # timeout, no violation
        with lockdep.make_rlock("router.lock"):
            pass
    assert lockdep.verify() == []


def test_lockdep_cross_thread_cycle_detected():
    # two record-only locks, each thread locally consistent, jointly a
    # deadlock: the acquisition graph must report the cycle
    lockdep.enable(raise_on_violation=False)
    try:
        a = lockdep.make_lock("test.alpha")
        b = lockdep.make_lock("test.beta")

        def t1():
            with a:
                with b:
                    pass

        def t2():
            with b:
                with a:
                    pass

        for fn in (t1, t2):
            th = threading.Thread(target=fn)
            th.start()
            th.join()
        problems = lockdep.verify()
    finally:
        lockdep.disable()
    assert any("cycle" in p for p in problems), problems


def test_lockdep_instruments_a_real_engine(lockdep_session):
    # an engine built while enabled gets ordered locks and a tiny
    # submit/flush pass stays violation-free
    import numpy as np
    from repro.core import Cluster, enoki_function, get_function

    @enoki_function(name="lkd_probe_acc", keygroups=["lkdkg"],
                    codec_width=4)
    def lkd_probe_acc(kv, x):
        cur, found = kv.get("t")
        kv.set("t", cur + x)
        return cur[:1] + x[:1]

    c = Cluster({"edge": "edge"}, measure_compute=False)
    assert isinstance(c.engine._qlock, lockdep.OrderedRLock)
    c.deploy(get_function("lkd_probe_acc"), ["edge"])
    c.engine.configure(window_ms=5.0)
    tk = c.engine.submit("lkd_probe_acc", "edge",
                         np.ones(4, np.float32))
    res = c.engine.flush()
    assert tk in res
    assert lockdep.verify() == []
