"""Partition tolerance: the lossy-network fault plane, the ack/retry
replication transport, suspicion-based membership with fencing epochs, and
the seeded chaos harness (ISSUE 10).

Contracts under test:

* transport — a snapshot scheduled while a link is partitioned is NOT
  stranded: the outbox re-offers it with capped exponential backoff and it
  delivers after ``heal()`` with its arrival re-timed from the healed link
  (the red case this PR landed first: the old fire-and-forget heap insert
  stamped ``arrival_t = inf`` at schedule time and never delivered);
* determinism — the fault plane's drop/dup/jitter schedule is a pure
  function of (seed, link, send counter): same seed, same schedule;
* idempotence — duplicate deliveries are deduped at the drain, and even
  WITHOUT the dedup the versioned-LWW merge makes re-application a no-op
  (property-tested);
* suspicion — a minority reachability view parks a node SUSPECT (no
  rebalance, router stops picking it, replicas intact) while a quorum of
  live peers confirming silence crashes it within one poll; fencing epochs
  reject a restored node's stale deliveries;
* chaos — under a seeded schedule of drops/dups/partitions/crashes a
  served workload loses nothing silently and converges byte-identically
  (version vectors included) to a fault-free twin after heal + drain.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster, Router, enoki_function, get_function
from repro.core.cluster import (REPL_RETRY_BASE_MS, REPL_RETRY_CAP_MS,
                                Cluster as _Cluster)
from repro.core.network import FaultPlane, paper_topology
from repro.core.store import arena_clone, merge_stores_jit, stores_equal
from repro.runtime import (ElasticMembership, FailureInjector, HealthMonitor,
                           chaos_schedule, run_chaos)

jax.config.update("jax_platform_name", "cpu")


@enoki_function(name="ptctr", keygroups=["ptkg"], codec_width=4)
def ptctr(kv, x):
    cur, found = kv.get("count")
    new = jnp.where(found, cur[0] + x[0], x[0])
    kv.set("count", jnp.stack([new, 0.0, 0.0, 0.0]))
    return jnp.stack([new])


def make_cluster(**kw):
    kw.setdefault("measure_compute", False)
    return Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"}, **kw)


def deploy_replicated(c, nodes=("edge", "edge2")):
    c.deploy(get_function("ptctr"), list(nodes),
             policy=ReplicationPolicy.REPLICATED)


# ---------------------------------------------------------------------------
# the red case: partition-era snapshots must deliver after heal
# ---------------------------------------------------------------------------

def test_snapshot_scheduled_during_partition_delivers_after_heal():
    """Regression (landed red first): a write REPLICATED while the link is
    severed must reach the peer once the link heals — without any further
    writes.  The old transport stamped ``arrival_t = t + one_way`` at
    schedule time, so a partition-era snapshot carried ``inf`` and survived
    ``heal()`` undelivered forever."""
    c = make_cluster()
    deploy_replicated(c)
    inj = FailureInjector(c)

    inj.partition("edge", "edge2")
    c.invoke("ptctr", "edge", jnp.ones((1,)))
    c.flush_replication(1e12)
    assert not stores_equal(c.store_of("ptkg", "edge"),
                            c.store_of("ptkg", "edge2")), \
        "partitioned peer must not observe the write"

    inj.heal("edge", "edge2")
    # NO new write: the healed link must carry the backlog by itself
    c.flush_replication(1e12)
    assert stores_equal(c.store_of("ptkg", "edge"),
                        c.store_of("ptkg", "edge2")), \
        "partition-era snapshot stranded after heal"
    assert c.stats.repl_retries >= 1, "the outbox must have re-offered"


# ---------------------------------------------------------------------------
# tier0: fault plane determinism
# ---------------------------------------------------------------------------

@pytest.mark.tier0
def test_fault_plane_same_seed_same_schedule():
    """Every drop/dup/jitter decision is a pure function of (seed, link,
    send counter): two planes with the same seed produce the identical
    transmission schedule, a different seed produces a different one."""
    def schedule(seed, n=64):
        p = FaultPlane(paper_topology(), seed=seed)
        p.set_fault("edge1", "cloud", drop_p=0.3, dup_p=0.3, jitter_ms=2.0)
        return [p.transmit("edge1", "cloud") for _ in range(n)]

    assert schedule(7) == schedule(7), "same seed must replay exactly"
    assert schedule(7) != schedule(8), "seeds must decorrelate schedules"


@pytest.mark.tier0
def test_fault_plane_partition_blocks_and_heals():
    p = FaultPlane(paper_topology(), seed=0)
    name = p.partition({"edge1"}, {"cloud", "edge2"})
    assert p.partitioned("edge1", "cloud")
    assert p.partitioned("cloud", "edge1"), "partitions are symmetric"
    assert not p.partitioned("cloud", "edge2"), "same group stays connected"
    assert not p.transmit("edge1", "cloud").ok
    p.heal(name)
    assert not p.partitioned("edge1", "cloud")
    assert p.transmit("edge1", "cloud").ok


# ---------------------------------------------------------------------------
# tier0: outbox state machine
# ---------------------------------------------------------------------------

@pytest.mark.tier0
def test_outbox_backoff_is_capped():
    assert _Cluster._backoff_ms(0) == REPL_RETRY_BASE_MS
    assert _Cluster._backoff_ms(1) == 2 * REPL_RETRY_BASE_MS
    assert _Cluster._backoff_ms(3) == 8 * REPL_RETRY_BASE_MS
    for attempts in range(6, 64):
        assert _Cluster._backoff_ms(attempts) == REPL_RETRY_CAP_MS, \
            "backoff must cap, not grow without bound"


def test_outbox_retries_then_ack_clears_entry():
    """A lossy link (drop_p=1) keeps the entry PENDING with growing
    backoff; once the fault clears, the retransmit delivers, the drain
    acks, and the outbox entry is gone."""
    c = make_cluster()
    deploy_replicated(c)
    inj = FailureInjector(c, membership=ElasticMembership(c))
    inj.set_link_fault("edge", "edge2", drop_p=1.0)

    c.invoke("ptctr", "edge", jnp.ones((1,)))
    c.flush_replication(1e6)
    with c._outbox_lock:
        entries = list(c._outboxes.get(("edge", "edge2"), []))
    assert len(entries) == 1 and not entries[0].sent, \
        "a fully lossy link must leave the entry pending"
    assert entries[0].attempts >= 1
    assert c.stats.repl_dropped >= 1 and c.stats.repl_retries >= 1

    inj.clear_link_fault("edge", "edge2")
    c.drain_transport(1e6)
    with c._outbox_lock:
        assert not c._outboxes.get(("edge", "edge2")), \
            "the delivery ack must clear the outbox entry"
    assert stores_equal(c.store_of("ptkg", "edge"),
                        c.store_of("ptkg", "edge2"))


def test_duplicate_delivery_is_deduped():
    """dup_p=1 delivers two copies of every snapshot; the drain's applied
    ledger suppresses the second and the stores still converge."""
    c = make_cluster()
    deploy_replicated(c)
    inj = FailureInjector(c, membership=ElasticMembership(c))
    inj.set_link_fault("edge", "edge2", dup_p=1.0)

    c.invoke("ptctr", "edge", jnp.ones((1,)))
    c.flush_replication(1e12)
    assert c.stats.repl_duped >= 1, "the duplicate copy must be counted"
    assert stores_equal(c.store_of("ptkg", "edge"),
                        c.store_of("ptkg", "edge2"))


@pytest.mark.tier0
@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=1, max_size=6))
def test_lww_merge_is_idempotent_under_duplicates(xs):
    """Even WITHOUT the dedup ledger, re-merging the same versioned-LWW
    snapshot is a byte-level no-op (version vectors included) — the
    property that makes at-least-once retransmission safe."""
    c = Cluster({"edge": "edge", "edge2": "edge"}, measure_compute=False)
    c.deploy(get_function("ptctr"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    for i, x in enumerate(xs):
        c.invoke("ptctr", "edge", jnp.asarray([x], jnp.float32),
                 t_send=float(i))
    c.flush_replication(1e12)
    src = c.store_of("ptkg", "edge")
    once = merge_stores_jit(arena_clone(c.store_of("ptkg", "edge2")), src)
    twice = merge_stores_jit(arena_clone(once), src)
    assert stores_equal(once, twice), \
        "re-applying a snapshot must be a byte-identical no-op"


# ---------------------------------------------------------------------------
# suspicion-based membership
# ---------------------------------------------------------------------------

def _beating_env(**kw):
    """Cluster + monitor + membership with heartbeats from every node at
    t=0 (virtual-seconds clock for the health plane)."""
    c = make_cluster(**kw)
    deploy_replicated(c)
    hm = HealthMonitor(naming=c.naming, timeout_s=10.0, plane=c.faults)
    m = ElasticMembership(c, monitor=hm)
    inj = FailureInjector(c, membership=m)
    for n in c.nodes:
        hm.beat(n, step=0, t=0.0)
    return c, hm, m, inj


def test_minority_partition_parks_suspect_not_crash():
    """edge<->edge2 severed, cloud still reaches both: each side of the
    cut is silent to ONE observer — below quorum (2 of 2 here) — so both
    park SUSPECT: no rebalance, replicas intact, router stops picking
    them; the heal un-suspects with nothing torn down."""
    c, hm, m, inj = _beating_env()
    inj.partition("edge", "edge2")
    for t in (5.0, 11.0):               # beats keep flowing post-cut
        for n in c.nodes:
            hm.beat(n, step=1, t=t)

    # at now=15 only the views frozen by the cut (age 15s > 10s timeout)
    # are stale; everything that still flows is 4s old
    crashed = m.poll(now=15.0)
    assert crashed == []
    assert m.state["edge2"] == "suspect" and m.state["edge"] == "suspect"
    assert m.stats.suspects >= 2
    assert m.stats.rebalanced == 0, "a suspect must NOT trigger rebalance"
    assert c.naming.replicas_of("ptkg") >= {"edge", "edge2"}, \
        "suspect replicas must stay in the replica set"
    assert not c.naming.is_routable("edge2")
    assert Router(c).candidates("ptctr") == [], \
        "router must not pick suspect nodes (both deployments suspect)"

    inj.heal("edge", "edge2")
    for n in c.nodes:
        hm.beat(n, step=2, t=23.0)
    assert m.poll(now=24.0) == []
    assert m.state["edge"] == "alive" and m.state["edge2"] == "alive"
    assert m.stats.false_suspects >= 2
    assert c.naming.is_routable("edge2")


def test_quorum_silence_crashes_within_one_poll():
    """Full isolation of edge2: BOTH other observers find it silent —
    quorum — so one poll takes it through the same crash path as an
    injected kill (rebalance fires, replication stops targeting it)."""
    c, hm, m, inj = _beating_env()
    inj.partition_groups({"edge2"}, {"edge", "cloud"})
    for t in (5.0, 11.0):
        for n in c.nodes:
            hm.beat(n, step=1, t=t)

    crashed = m.poll(now=15.0)
    assert crashed == ["edge2"]
    assert m.state["edge2"] == "dead"
    assert m.stats.crashes == 1
    assert not c.naming.is_alive("edge2")


def test_stale_epoch_delivery_rejected_after_restore():
    """The victim writes during the partition (snapshot parked in ITS
    outbox), is crashed by quorum, and its keygroup's fencing epoch bumps
    with the rebalance.  After heal + restore the parked pre-crash
    snapshot finally transmits — and must be REJECTED as stale instead of
    resurrecting pre-crash state past the rebalance; the node converges
    via the restore's catch-up instead."""
    c = make_cluster()
    deploy_replicated(c)
    m = ElasticMembership(c)
    inj = FailureInjector(c, membership=m)

    c.invoke("ptctr", "edge", jnp.ones((1,)))           # shared history
    c.flush_replication(1e12)

    inj.partition("edge", "edge2")
    c.invoke("ptctr", "edge2", jnp.ones((1,)), t_send=10.0)
    c.flush_replication(1e12)           # parked: edge2 -> edge, epoch 0
    with c._outbox_lock:
        assert c._outboxes.get(("edge2", "edge")), \
            "the partition-era write must be parked in edge2's outbox"

    inj.kill_node("edge2")              # bumps ptkg's fence to 1; edge2's
    assert c.fence_epoch("ptkg") >= 1   # own outgoing entries survive
    inj.heal("edge", "edge2")
    inj.restore_node("edge2", t=1e12)

    c.drain_transport(1e12)             # the stale entry transmits now
    assert m.stats.epoch_rejections >= 1, \
        "pre-crash snapshot must be fenced off, not merged"
    assert c.stats.epoch_rejections >= 1
    assert stores_equal(c.store_of("ptkg", "edge"),
                        c.store_of("ptkg", "edge2")), \
        "the restored node converges via catch-up, not the stale delivery"
    r = c.invoke("ptctr", "edge", jnp.ones((1,)), t_send=1e12)
    assert float(np.asarray(r.output)[0]) == 2.0, \
        "the fenced write stays lost (documented loss window), not replayed"


def test_resurrection_contract():
    """dead_nodes is PURE and a heartbeat from a declared-dead node must
    NOT revive naming — only ElasticMembership.restore may; and a restored
    node is not instantly re-crashed by its pre-crash silence."""
    c, hm, m, inj = _beating_env()
    m.crash("edge2")
    assert not c.naming.is_alive("edge2")

    for n in ("edge", "cloud"):         # survivors keep beating
        hm.beat(n, step=5, t=100.0)
    hm.beat("edge2", step=5, t=100.0)   # a zombie beat after the verdict
    assert hm.dead_nodes(now=100.0) == []       # it IS beating...
    assert not c.naming.is_alive("edge2"), \
        "a stray beat must not revive a dead node's naming entry"
    assert m.state["edge2"] == "dead"

    m.restore("edge2", t=1e12)
    assert c.naming.is_alive("edge2")
    # pre-crash views were wiped: the next poll judges it on post-restore
    # beats only, so it stays alive instead of being re-condemned
    assert m.poll(now=100.0) == []
    assert m.state["edge2"] == "alive"


def test_reads_your_writes_under_drop_faults():
    """A session pinned by the router never observes its counter regress,
    even when every replication link drops and duplicates aggressively —
    retries make the log converge between writes."""
    c = make_cluster()
    deploy_replicated(c)
    inj = FailureInjector(c, membership=ElasticMembership(c))
    for a, b in (("edge", "edge2"), ("edge", "cloud"), ("edge2", "cloud")):
        inj.set_link_fault(a, b, drop_p=0.2, dup_p=0.2, jitter_ms=2.0)
    router = Router(c)

    last, t = 0.0, 0.0
    for i in range(8):
        t += 500.0
        r = router.invoke("ptctr", jnp.ones((1,)), t_send=t,
                          session_id="pt-session")
        v = float(np.asarray(r.output)[0])
        assert v > last, "reads-your-writes: counter must never regress"
        last = v
        c.drain_transport(t)
    assert last == 8.0


# ---------------------------------------------------------------------------
# the seeded chaos harness
# ---------------------------------------------------------------------------

_CHAOS_NODES = ("edge", "edge2", "cloud")


@enoki_function(name="ptprobe", keygroups=["ptprobekg"], codec_width=4)
def ptprobe(kv, x):
    cur, _ = kv.get("beacon")
    return cur[:1] + x[:1]


def _chaos_run(seed, rounds, apply_faults):
    """One full chaos run (faulty or fault-free twin) over the same plan.
    Returns (cluster, membership, plan, probe log)."""
    c = Cluster({n: ("cloud" if n == "cloud" else "edge")
                 for n in _CHAOS_NODES}, measure_compute=False,
                fault_seed=seed)
    c.deploy(get_function("ptctr"), list(_CHAOS_NODES),
             policy=ReplicationPolicy.REPLICATED)
    c.deploy(get_function("ptprobe"), ["edge2"],
             policy=ReplicationPolicy.REPLICATED)
    m = ElasticMembership(c)
    inj = FailureInjector(c, membership=m)
    plan = chaos_schedule(seed, rounds, _CHAOS_NODES, victim="edge2")

    def write(node, r, t):
        # sequential writers with an inter-write drain: every write folds
        # on top of ALL prior writes, so each adds exactly +1 and the
        # final counter equals the total write count — which is what lets
        # the faulty run be compared byte-for-byte against the twin
        # (counters are LWW registers, not CRDTs: concurrent writes from
        # stale bases would race and lose increments run-dependently)
        c.invoke("ptctr", node, jnp.ones((1,)), t_send=t + 1.0)
        c.drain_transport(t + 1.0)

    served, lost = [], []

    def probe(r, t):
        ticket = c.engine.submit("ptprobe", "edge2", jnp.ones((1,)),
                                 t_send=t + 2.0)
        out = c.engine.flush()
        (served if ticket in out else lost).append(r)

    run_chaos(c, m, inj, plan, write, probe=probe,
              apply_faults=apply_faults)
    return c, m, plan, served, lost


def test_chaos_no_silent_loss_and_byte_identical_convergence():
    """The headline invariant: under a seeded schedule of drops (p<=0.2),
    duplication, one multi-round partition and one crash+restore, a
    served workload (a) loses nothing silently — every engine submission
    is either flushed or surfaced as dropped, (b) converges so every live
    replica is byte-identical, and (c) the converged stores are
    byte-identical (version vectors included) to a fault-free twin run of
    the same plan."""
    rounds = 12
    c, m, plan, served, lost = _chaos_run(seed=7, rounds=rounds,
                                          apply_faults=True)
    ct, mt, _, served_t, lost_t = _chaos_run(seed=7, rounds=rounds,
                                             apply_faults=False)

    # (a) conservation: nothing vanishes from the engine's accounting,
    # and every lost probe is surfaced (dropped_dead), never silent
    st_ = c.engine.stats
    assert st_.submitted == st_.requests_flushed + st_.dropped_dead, \
        "engine accounting must balance: submitted == flushed + dropped"
    assert len(lost) == st_.dropped_dead, \
        "every unserved probe must be a surfaced drop"
    assert len(served) + len(lost) == rounds
    assert lost, "the crash window must actually drop some probes"

    # the faults were real: retries/drops/dups all exercised
    assert c.stats.repl_retries > 0
    assert c.stats.repl_dropped > 0 or c.stats.repl_duped > 0

    # (b) post-heal convergence across the faulty run's replicas, and no
    # write lost: the counter equals the exact number of issued writes
    for node in _CHAOS_NODES[1:]:
        assert stores_equal(c.store_of("ptkg", _CHAOS_NODES[0]),
                            c.store_of("ptkg", node)), \
            f"faulty-run replicas diverge at {node}"
    writes = sum(len(plan.writers_for(r)) for r in range(rounds))
    final = float(np.asarray(c.store_of("ptkg", "edge").values)[0][0])
    assert final == writes, \
        f"every write must survive the faults: {final} != {writes}"

    # (c) byte-identical to the fault-free twin, version vectors included
    assert lost == lost_t and served == served_t, \
        "the twin must drop exactly the same probes (crash parity)"
    for node in _CHAOS_NODES:
        assert stores_equal(c.store_of("ptkg", node),
                            ct.store_of("ptkg", node)), \
            f"faulty vs fault-free stores differ at {node}"


@pytest.mark.tier0
def test_chaos_schedule_is_deterministic():
    a = chaos_schedule(3, 12, _CHAOS_NODES, victim="edge2")
    b = chaos_schedule(3, 12, _CHAOS_NODES, victim="edge2")
    assert a == b, "same seed must produce the identical plan"
    assert a != chaos_schedule(4, 12, _CHAOS_NODES, victim="edge2")
    # exactly one partition, one heal, one crash, one restore
    kinds = [e.action for e in a.events]
    for k in ("partition", "heal", "crash", "restore"):
        assert kinds.count(k) == 1
    assert a.quiet_rounds, "the victim must sit out the fault windows"
