"""Per-architecture smoke tests: reduced config, one forward/train step and
one decode step on CPU; asserts output shapes and no NaNs.  (The FULL
configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_arch, reduced, reduced_shape
from repro.models import model_zoo as zoo

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _setup(arch_id, key):
    arch = reduced(get_arch(arch_id))
    shape = reduced_shape(SHAPES_BY_NAME["train_4k"])
    params = zoo.init_params(arch, key)
    batch = zoo.example_batch(arch, shape, key)
    return arch, shape, params, batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_loss(arch_id, key):
    arch, shape, params, batch = _setup(arch_id, key)
    logits, aux, _ = zoo.forward_seq(arch, params, batch["tokens"],
                                     extra=batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, arch.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), "NaN/inf in logits"
    loss, parts = zoo.lm_loss(arch, params, batch)
    assert jnp.isfinite(loss), f"loss not finite: {loss}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_grads(arch_id, key):
    arch, shape, params, batch = _setup(arch_id, key)

    def loss_fn(p):
        return zoo.lm_loss(arch, p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0, f"bad grad norm {gnorm}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step(arch_id, key):
    arch = reduced(get_arch(arch_id))
    params = zoo.init_params(arch, key)
    B, max_len = 2, 64
    cache = zoo.init_cache(arch, B, max_len)
    token = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t: zoo.decode_step(arch, p, c, t))
    logits, cache = step(params, cache, token)
    assert logits.shape == (B, 1, arch.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    assert int(cache["length"]) == 1
    # a second step advances the cache
    logits2, cache = step(params, cache, token)
    assert int(cache["length"]) == 2
    assert jnp.isfinite(logits2.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch_id", sorted(ARCH_IDS))
def test_prefill_matches_decode(arch_id, key):
    """Prefill a short prompt, then decode-step token-by-token from scratch:
    the final-position logits must agree (cache correctness)."""
    arch = reduced(get_arch(arch_id))
    if arch.family == "moe":
        pytest.skip("capacity drops differ between seq and step routing")
    params = zoo.init_params(arch, key)
    B, S = 1, 8
    tokens = jax.random.randint(key, (B, S), 0, arch.vocab_size, jnp.int32)
    extra = {}
    if arch.frontend_stub == "clip_patches":
        pytest.skip("vlm prefix alters positions at tiny S")
    if arch.frontend_stub == "audio_frames":
        extra["frame_embeds"] = jax.random.normal(
            key, (B, arch.num_patches, arch.d_model)) * 0.02
    logits_seq, _, _ = zoo.forward_seq(arch, params, tokens, extra=extra,
                                       compute_dtype=jnp.float32)
    cache = zoo.init_cache(arch, B, S, dtype=jnp.float32)
    if arch.family == "audio":
        # cross K/V come from the encoder: build them via prefill cache
        _, _, pc = zoo.forward_seq(arch, params, tokens, extra=extra,
                                   return_cache=True,
                                   compute_dtype=jnp.float32)
        cache["cross_k"] = pc["cross_k"].astype(jnp.float32)
        cache["cross_v"] = pc["cross_v"].astype(jnp.float32)
    logits_step = None
    for t in range(S):
        logits_step, cache = zoo.decode_step(arch, params, cache,
                                             tokens[:, t:t + 1],
                                             compute_dtype=jnp.float32)
    final_seq = logits_seq[:, -1].astype(jnp.float32)
    final_step = logits_step[:, 0].astype(jnp.float32)
    err = jnp.max(jnp.abs(final_seq - final_step))
    scale = jnp.max(jnp.abs(final_seq)) + 1e-6
    assert err / scale < 5e-2, f"prefill/decode mismatch: rel err {err/scale}"


@pytest.mark.parametrize("arch_id", ["internlm2-1.8b", "xlstm-350m",
                                     "zamba2-7b", "whisper-tiny"])
def test_prefill_then_decode_continuation(arch_id, key):
    """Prefill S tokens, decode one more: logits must match the full
    (S+1)-token sequence forward — validates the emitted prefill caches."""
    arch = reduced(get_arch(arch_id))
    params = zoo.init_params(arch, key)
    B, S = 1, 16
    tokens = jax.random.randint(key, (B, S + 1), 0, arch.vocab_size,
                                jnp.int32)
    extra = {}
    if arch.frontend_stub == "audio_frames":
        extra["frame_embeds"] = jax.random.normal(
            key, (B, arch.num_patches, arch.d_model)) * 0.02
    logits_full, _, _ = zoo.forward_seq(arch, params, tokens, extra=extra,
                                        compute_dtype=jnp.float32)
    _, _, cache = zoo.forward_seq(arch, params, tokens[:, :S], extra=extra,
                                  return_cache=True,
                                  compute_dtype=jnp.float32)
    cache = dict(cache)
    cache["length"] = jnp.asarray(S, jnp.int32)
    # decode caches must be padded to hold S+1 for attention archs: rebuild
    full = zoo.init_cache(arch, B, S + 1, dtype=jnp.float32)
    for k_, v_ in cache.items():
        if k_ in full and hasattr(v_, "shape") and \
                full[k_].shape != getattr(v_, "shape", None):
            pad = [(0, a - b) for a, b in zip(full[k_].shape, v_.shape)]
            cache[k_] = jnp.pad(v_.astype(full[k_].dtype), pad)
        elif k_ in full:
            cache[k_] = v_
    for k_ in full:
        if k_ not in cache:
            cache[k_] = full[k_]
    logits_step, _ = zoo.decode_step(arch, params, cache, tokens[:, S:S + 1],
                                     compute_dtype=jnp.float32)
    a = logits_full[:, -1].astype(jnp.float32)
    b = logits_step[:, 0].astype(jnp.float32)
    err = jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-6)
    assert err < 5e-2, f"prefill->decode continuation mismatch: {err}"
