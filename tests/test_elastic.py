"""Elastic re-mesh: state survives a pod loss (subprocess, 8 devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import MeshConfig
    from repro.runtime.elastic import degraded_mesh_config, make_mesh, remesh

    full_cfg = MeshConfig(shape=(2, 2, 2), axes=("pod", "data", "model"))
    mesh = make_mesh(full_cfg)
    state = {
        "w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "stacked": jnp.arange(2 * 4 * 4, dtype=jnp.float32).reshape(2, 4, 4),
    }
    specs = {"w": P(None, "model"), "stacked": P("pod", "data", None)}
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state, specs,
        is_leaf=lambda x: isinstance(x, P))

    # pod 1 dies -> collapse the pod axis
    degraded = degraded_mesh_config(full_cfg, alive_pods=1)
    assert degraded.shape == (2, 2) and degraded.axes == ("data", "model")
    new_mesh = make_mesh(degraded)
    moved = remesh(placed, specs, new_mesh)
    for k in state:
        np.testing.assert_array_equal(np.asarray(moved[k]),
                                      np.asarray(state[k]))
    # pod-stacked keygroup: slot 0 (the survivor's replica) is intact
    np.testing.assert_array_equal(np.asarray(moved["stacked"][0]),
                                  np.asarray(state["stacked"][0]))
    print("REMESH_OK")
""")


@pytest.mark.slow
def test_elastic_remesh(tmp_path):
    script = tmp_path / "elastic.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "REMESH_OK" in res.stdout
