"""Wall-clock serving loop + windowed hedging: hedge state machine
determinism (fired only for read-only handlers past the hedge deadline,
earlier completion wins, losers that never dispatched are discarded),
next_deadline() monotonicity at both the engine and router levels, and a
bounded-sleep FaasServer smoke test with a deterministic result set."""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster, Router, enoki_function, get_function
from repro.core.store import store_contents

jax.config.update("jax_platform_name", "cpu")


@enoki_function(name="fs_bump", keygroups=["fskg"], codec_width=4)
def fs_bump(kv, x):
    cur, found = kv.get("c")
    new = jnp.where(found, cur[0] + 1.0, 1.0)
    kv.set("c", jnp.stack([new, 0.0, 0.0, 0.0]))
    return jnp.stack([new])


@enoki_function(name="fs_peek", keygroups=["fskg"], codec_width=4)
def fs_peek(kv, x):
    cur, found = kv.get("c")
    return cur[:1]


def _cluster():
    return Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                   measure_compute=False)


def _deploy_both(c, policy=ReplicationPolicy.REPLICATED):
    c.deploy(get_function("fs_bump"), ["edge", "edge2"], policy=policy)
    c.deploy(get_function("fs_peek"), ["edge", "edge2"], policy=policy)
    c.invoke("fs_bump", "edge", jnp.zeros((1,)))     # seed state
    c.flush_replication()


def _x():
    return np.zeros(4, np.float32)


def _count(c, node):
    contents = store_contents(c.nodes[node].stores["fskg"])
    return list(contents.values())[0][2][0] if contents else 0.0


def _pump_all(router, n):
    """Drive pump deadline-by-deadline, exactly like the serving loop."""
    out = {}
    while len(out) < n:
        nd = router.next_deadline()
        if nd is None:
            out.update(router.pump(math.inf))
            break
        out.update(router.pump(nd))
    return out


# ---------------------------------------------------------------------------
# windowed hedging
# ---------------------------------------------------------------------------

def test_windowed_hedge_wins_on_straggler_and_takes_earlier_completion():
    """Nearest replica straggles: the hedge fired at t_send+hedge_after_ms
    to the second replica completes earlier and is the result reported
    under the primary ticket."""
    c = _cluster()
    _deploy_both(c)
    c.set_compute_ms("edge", "fs_peek", 50.0)       # straggler
    c.engine.configure(window_ms=20.0)
    router = Router(c, hedge_after_ms=5.0)
    t = router.submit("fs_peek", _x(), t_send=0.0)
    out = _pump_all(router, 1)
    assert set(out) == {t}
    assert router.stats.hedges_fired == 1
    assert router.stats.hedge_wins == 1
    assert out[t].node == "edge2"                   # hedge's replica won
    # the winner is re-stamped against the PRIMARY's send instant: the
    # client observes latency from its original submission
    assert out[t].t_sent == 0.0
    assert out[t].response_ms == pytest.approx(out[t].t_received)
    # BOTH members fed the latency EWMA with their OWN latency: the slow
    # primary recorded its true (slow) completion even though it lost,
    # and the winner's sample is not inflated by the pre-hedge wait
    assert router.stats.ewma_ms["edge"] > router.stats.ewma_ms["edge2"]
    assert router.stats.ewma_ms["edge2"] < out[t].response_ms
    # unhedged run for comparison: strictly slower completion
    c2 = _cluster()
    _deploy_both(c2)
    c2.set_compute_ms("edge", "fs_peek", 50.0)
    c2.engine.configure(window_ms=20.0)
    plain = Router(c2)
    t2 = plain.submit("fs_peek", _x(), t_send=0.0)
    ref = _pump_all(plain, 1)
    assert out[t].t_received < ref[t2].t_received
    assert router._inflight == {} and router._hedges == {}


def test_windowed_hedge_loser_discarded_before_dispatch():
    """Without a straggler the primary wins at its window close, before the
    hedge's window closes — the hedge is discarded undipatched (at-most-
    once: exactly one batch dispatch serves the request)."""
    c = _cluster()
    _deploy_both(c)
    c.engine.configure(window_ms=20.0)
    router = Router(c, hedge_after_ms=5.0)
    base_dispatch = c.engine.stats.dispatches
    t = router.submit("fs_peek", _x(), t_send=0.0)
    out = _pump_all(router, 1)
    assert set(out) == {t}
    assert out[t].node == "edge"                    # primary won
    assert router.stats.hedges_fired == 1
    assert router.stats.hedge_wins == 0
    assert c.engine.stats.dispatches == base_dispatch + 1   # loser never ran
    assert c.engine.pending() == []                 # ...and is not queued
    assert router._inflight == {} and router._hedges == {}


def test_hedge_only_fires_for_read_only_handlers():
    """A mutating handler must never hedge (double-apply): suppressed and
    counted, and the counter advances exactly once."""
    c = _cluster()
    _deploy_both(c)
    c.engine.configure(window_ms=20.0)
    router = Router(c, hedge_after_ms=5.0)
    t = router.submit("fs_bump", _x(), t_send=0.0)
    out = _pump_all(router, 1)
    assert set(out) == {t}
    assert router.stats.hedges_fired == 0
    assert router.stats.hedges_suppressed == 1
    c.flush_replication()
    assert _count(c, "edge") == _count(c, "edge2") == 2.0   # seed + one bump


def test_hedge_not_fired_when_window_beats_the_deadline():
    """A window closing BEFORE the hedge deadline never hedges — the batch
    completes within the hedge budget."""
    c = _cluster()
    _deploy_both(c)
    c.engine.configure(window_ms=4.0)
    router = Router(c, hedge_after_ms=30.0)
    t = router.submit("fs_peek", _x(), t_send=0.0)
    out = _pump_all(router, 1)
    assert set(out) == {t}
    assert router.stats.hedges_fired == 0
    assert router.stats.hedges_suppressed == 0


def test_windowed_hedge_deterministic_across_pump_cadence():
    """One coarse pump(inf) and deadline-by-deadline pumping produce the
    same completion (same winner, same t_received) and the same hedge
    stats — the hedge fires at a virtual instant, not at a pump call."""
    outs, stats = [], []
    for coarse in (False, True):
        c = _cluster()
        _deploy_both(c)
        c.set_compute_ms("edge", "fs_peek", 50.0)
        c.engine.configure(window_ms=20.0)
        router = Router(c, hedge_after_ms=5.0)
        t = router.submit("fs_peek", _x(), t_send=0.0)
        out = (router.pump(math.inf) if coarse else _pump_all(router, 1))
        outs.append(out[t])
        stats.append((router.stats.hedges_fired, router.stats.hedge_wins))
    assert stats[0] == stats[1] == (1, 1)
    assert outs[0].t_received == outs[1].t_received
    assert outs[0].node == outs[1].node == "edge2"


def test_hedge_waits_for_partner_under_flush_on_full():
    """With max_batch set, a queued partner's window can fill and dispatch
    BEFORE its deadline, so the early-settle shortcut (present result beats
    the partner's window close) is unsound — the pair must wait for the
    partner's actual completion instead of discarding it."""
    c = _cluster()
    _deploy_both(c)
    c.engine.configure(window_ms=20.0, max_batch=8)
    router = Router(c, hedge_after_ms=5.0)
    t = router.submit("fs_peek", _x(), t_send=0.0)
    assert router.pump(5.0) == {}               # hedge fires here
    assert router.stats.hedges_fired == 1
    out = router.pump(21.0)                     # primary window drains...
    assert out == {}                            # ...but the pair WAITS
    assert len(c.engine.pending()) == 1         # hedge still queued
    out = _pump_all(router, 1)                  # hedge completes -> settle
    assert set(out) == {t}
    assert out[t].node == "edge"                # primary still won
    assert router.stats.hedge_wins == 0
    assert router._inflight == {} and router._hedges == {}


def test_hedge_respects_session_consistency():
    """A hedge must never win with a STALE read: when the only alternate
    replica cannot satisfy the session (replication pending), the hedge is
    skipped and the request completes at the session's replica."""
    c = _cluster()
    _deploy_both(c)
    c.engine.configure(window_ms=20.0)
    router = Router(c, hedge_after_ms=5.0)
    # write at the FAR replica; session observes edge2's store, edge lags
    res = c.invoke("fs_bump", "edge2", jnp.zeros((1,)))
    session = router._session("s")
    router._observe(session, "fs_bump", res)
    t = router.submit("fs_peek", _x(), t_send=0.0, session_id="s")
    assert router.pick("fs_peek", session) == "edge2"   # sanity: edge stale
    out = _pump_all(router, 1)
    assert set(out) == {t}
    assert router.stats.hedges_fired == 0       # no satisfying alternate
    assert out[t].node == "edge2"
    # the session read actually saw its own write
    assert float(np.asarray(out[t].output)[0]) == 2.0   # seed + far write


def test_hedge_target_prefers_lowest_ewma_replica():
    """The hedge-target policy: with latency samples, the duplicate goes
    to the lowest-EWMA session-satisfying replica even when another is
    nearer; with no samples it falls back to the nearest other replica."""
    for ewma, expect in (({}, "edge2"),                 # no samples: nearest
                         ({"edge2": 80.0, "cloud": 2.0}, "cloud"),
                         ({"edge2": 3.0, "cloud": 90.0}, "edge2")):
        c = _cluster()
        c.deploy(get_function("fs_bump"), ["edge", "edge2", "cloud"])
        c.deploy(get_function("fs_peek"), ["edge", "edge2", "cloud"])
        c.invoke("fs_bump", "edge", jnp.zeros((1,)))
        c.flush_replication()
        c.engine.configure(window_ms=20.0)
        router = Router(c, hedge_after_ms=5.0)
        router.stats.ewma_ms.update(ewma)
        t = router.submit("fs_peek", _x(), t_send=0.0)
        assert router.pump(5.0) == {}           # hedge fires at t=5
        assert router.stats.hedges_fired == 1
        queued = {p["ticket"]: p["node"] for p in c.engine.pending()}
        hedge_nodes = [nd for tk, nd in queued.items() if tk != t]
        assert hedge_nodes == [expect], (ewma, hedge_nodes)
        out = _pump_all(router, 1)
        assert set(out) == {t}


def test_completions_feed_per_replica_latency_ewma():
    """Every completion (sequential and batched path) folds into its
    replica's EWMA with Router.EWMA_ALPHA smoothing."""
    c = _cluster()
    _deploy_both(c)
    router = Router(c)
    r1 = router.invoke("fs_peek", _x(), t_send=0.0)
    assert router.stats.ewma_ms[r1.node] == pytest.approx(r1.response_ms)
    r2 = router.invoke("fs_peek", _x(), t_send=10.0)
    a = Router.EWMA_ALPHA
    assert router.stats.ewma_ms[r2.node] == pytest.approx(
        a * r2.response_ms + (1 - a) * r1.response_ms)
    # batched path feeds the same signal
    c.engine.configure(window_ms=5.0)
    t = router.submit("fs_peek", _x(), t_send=20.0)
    out = _pump_all(router, 1)
    assert router.stats.ewma_ms[out[t].node] == pytest.approx(
        a * out[t].response_ms
        + (1 - a) * (a * r2.response_ms + (1 - a) * r1.response_ms))


# ---------------------------------------------------------------------------
# next_deadline
# ---------------------------------------------------------------------------

def test_engine_next_deadline_monotone_across_pumps():
    c = _cluster()
    _deploy_both(c)
    c.engine.configure(window_ms=10.0)
    assert c.engine.next_deadline() is None
    c.engine.submit("fs_peek", "edge", _x(), t_send=0.0)
    d1 = c.engine.next_deadline()
    assert d1 is not None
    c.engine.submit("fs_peek", "edge", _x(), t_send=2.0)    # joins the window
    assert c.engine.next_deadline() == d1
    c.engine.submit("fs_peek", "edge", _x(), t_send=50.0)   # later window
    assert c.engine.next_deadline() == d1                   # earliest wins
    c.engine.pump(d1)
    d2 = c.engine.next_deadline()
    assert d2 is not None and d2 > d1                       # monotone
    c.engine.pump(d2)
    assert c.engine.next_deadline() is None
    assert c.engine.pending() == []


def test_router_next_deadline_covers_hedge_fire_times():
    """The router's horizon is the EARLIER of the engine's next window
    close and a queued read-only ticket's hedge instant, and it advances
    monotonically as the serving loop pumps."""
    c = _cluster()
    _deploy_both(c)
    c.engine.configure(window_ms=20.0)
    router = Router(c, hedge_after_ms=5.0)
    router.submit("fs_peek", _x(), t_send=0.0)
    window_close = c.engine.next_deadline()
    d1 = router.next_deadline()
    assert d1 == pytest.approx(5.0)                 # hedge fires first
    assert d1 < window_close
    router.pump(d1)                                 # hedge fired here
    d2 = router.next_deadline()
    assert d2 == window_close                       # next: primary's close
    router.pump(d2)
    d3 = router.next_deadline()
    assert d3 is None or d3 > d2                    # hedge window or done
    _pump_all(router, 1)
    assert router.next_deadline() is None


def test_unclocked_pump_without_argument_still_drains_everything():
    """Back-compat: pump() with no clock plugged means pump(inf)."""
    c = _cluster()
    _deploy_both(c)
    c.engine.configure(window_ms=5.0)
    t = c.engine.submit("fs_peek", "edge", _x(), t_send=0.0)
    assert set(c.engine.pump()) == {t}


# ---------------------------------------------------------------------------
# the wall-clock server
# ---------------------------------------------------------------------------

def test_faas_server_smoke_bounded_and_deterministic():
    """Real threads, real sleeps, bounded wall time: every future resolves,
    the counter advances exactly once per request (deterministic result
    set), and sessions hold reads-your-writes through the server."""
    from repro.launch.faas_server import FaasServer
    c = _cluster()
    _deploy_both(c)
    # warm the jit buckets outside the served window
    for b in (1, 8, 64):
        c.invoke_batch("fs_bump", "edge", [_x()] * b)
    seeded = _count(c, "edge")
    n = 12
    t0 = time.perf_counter()
    with FaasServer(c, window_ms=5.0, time_scale=200.0) as srv:
        futs = [srv.submit("fs_bump", _x(), session_id="s") for _ in range(n)]
        outs = [f.result(timeout=30.0) for f in futs]
    elapsed = time.perf_counter() - t0
    assert elapsed < 30.0                           # bounded, no hang
    assert all(f.done() for f in futs)
    assert srv.stats.served == n and srv.stats.lost == 0
    # deterministic result set: each request saw a distinct counter value
    vals = sorted(float(np.asarray(r.output)[0]) for r in outs)
    assert vals == [seeded + 1.0 + i for i in range(n)]
    c.flush_replication()
    assert _count(c, "edge") == seeded + n
    # the session folded every batched write (reads-your-writes holds)
    session = srv.router.sessions["s"]
    assert session.can_read_from(np.asarray(c.store_of("fskg", "edge").vv))
    # virtual latency: solo latency + at most the window
    assert all(r.response_ms <= 1.0 + 5.0 + 1.0 for r in outs)


def test_faas_server_submit_requires_start():
    from repro.launch.faas_server import FaasServer
    c = _cluster()
    _deploy_both(c)
    srv = FaasServer(c, window_ms=5.0)
    with pytest.raises(RuntimeError, match="not started"):
        srv.submit("fs_peek", _x())
    # None is the engine's no-windowing sentinel: nothing would come due
    with pytest.raises(ValueError, match="window_ms"):
        FaasServer(c, window_ms=None)


def test_faas_server_stop_drains_queued_windows():
    """stop() must not strand futures whose windows never came due."""
    from repro.launch.faas_server import FaasServer
    c = _cluster()
    _deploy_both(c)
    srv = FaasServer(c, window_ms=10_000.0, time_scale=1.0).start()
    fut = srv.submit("fs_peek", _x())
    srv.stop(drain=True)
    assert fut.done()
    assert float(np.asarray(fut.result(timeout=1.0).output)[0]) >= 1.0
    # the server unplugged its wall clock from the cluster's shared engine
    assert c.engine.clock is None


def test_faas_server_lost_ticket_fails_future():
    """A discarded ticket can never resolve: its future fails instead of
    hanging the client (at-most-once surface)."""
    from repro.launch.faas_server import FaasServer, RequestLost
    c = _cluster()
    _deploy_both(c)
    srv = FaasServer(c, window_ms=10_000.0, time_scale=1.0).start()
    fut = srv.submit("fs_peek", _x())
    with srv._cond:
        assert c.engine.discard(fut.ticket)
        srv._cond.notify_all()
    srv.stop(drain=True)
    with pytest.raises(RequestLost):
        fut.result(timeout=1.0)
    assert srv.stats.lost == 1


def test_faas_server_node_death_mid_serving_reroutes_or_fails_fast():
    """Kill a replica while the server is live: in-flight and queued
    requests either complete at the survivor (rerouted) or surface as
    RequestLost — the accounting balances exactly and nothing hangs."""
    from repro.launch.faas_server import FaasServer, RequestLost
    from repro.runtime import ElasticMembership, FailureInjector
    c = _cluster()
    _deploy_both(c)
    m = ElasticMembership(c)
    inj = FailureInjector(c, membership=m)
    for b in (1, 8, 64):
        c.invoke_batch("fs_bump", "edge", [_x()] * b)
    n = 16
    t0 = time.perf_counter()
    with FaasServer(c, window_ms=5.0, time_scale=200.0,
                    membership=m) as srv:
        futs = [srv.submit("fs_bump", _x()) for _ in range(n)]
        inj.kill_node("edge2")          # mid-serving: windows may target it
        served = lost = 0
        for f in futs:
            try:
                f.result(timeout=30.0)
                served += 1
            except RequestLost:
                lost += 1
    assert time.perf_counter() - t0 < 30.0          # bounded, no hang
    assert all(f.done() for f in futs)
    assert served + lost == n                       # at-most-once balances
    assert srv.stats.served == served and srv.stats.lost == lost
    # both replicas were deployed, so the survivor absorbs the work
    assert served == n and lost == 0
    c.flush_replication(1e12)
    assert m.state["edge2"] == "dead"


def test_faas_server_submit_stop_race_under_injected_death():
    """Regression for the submit-vs-stop race crossed with node death:
    client threads hammer submit (auto-flush via max_batch=1) while the
    main thread kills a node and then stops the server.  Every future a
    client obtained must SETTLE — resolved, RequestLost, or the explicit
    stopping-server failure — and the orphan buffer must be empty (no
    result stranded without its future)."""
    import threading
    from repro.launch.faas_server import FaasServer, RequestLost
    from repro.runtime import ElasticMembership, FailureInjector
    c = _cluster()
    _deploy_both(c)
    m = ElasticMembership(c)
    inj = FailureInjector(c, membership=m)
    for b in (1, 8):
        c.invoke_batch("fs_bump", "edge", [_x()] * b)
    srv = FaasServer(c, window_ms=5.0, time_scale=200.0, max_batch=1,
                     membership=m).start()
    futs, submit_refused = [], []
    flock = threading.Lock()
    stop_submitting = threading.Event()

    def client():
        while not stop_submitting.is_set():
            try:
                f = srv.submit("fs_bump", _x())
            except RuntimeError:        # raced past stop(): fail-fast path
                submit_refused.append(1)
                return
            except Exception:
                # a cycle the kill broke can raise out of the auto-flush
                # inside submit; the server reconciles before re-raising
                continue
            with flock:
                futs.append(f)

    threads = [threading.Thread(target=client) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    inj.kill_node("edge2")
    time.sleep(0.05)
    stop_submitting.set()
    srv.stop(drain=True)
    for t in threads:
        t.join(timeout=10.0)
    assert not any(t.is_alive() for t in threads)
    served = lost = 0
    for f in futs:
        assert f.done()                 # drain settles every future
        try:
            f.result(timeout=0.0)
            served += 1
        except (RequestLost, RuntimeError):
            lost += 1
    assert served + lost == len(futs)
    # server-side accounting agrees with the client-side settlement;
    # RuntimeError-settled futures were counted lost by the server too
    assert srv.stats.submitted == len(futs)
    assert srv.stats.served == served
    assert not srv._orphans              # no result stranded futureless
    assert not srv._futures              # no future left unresolved
