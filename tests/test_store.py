"""Unit tests for the node-local KV arena (core/store.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.store import (Store, kv_delete, kv_get, kv_scan, kv_set,
                              merge_stores, store_contents, store_new)
from repro.core.versioning import MAX_NODES, fnv1a, pack_version, unpack_clock

jax.config.update("jax_platform_name", "cpu")


def _row(store, val):
    row = jnp.zeros((store.value_width,), store.values.dtype)
    return row.at[:len(val)].set(jnp.asarray(val, store.values.dtype))


def test_set_get_roundtrip():
    s = store_new(8, 4, MAX_NODES)
    clock = jnp.zeros((), jnp.int32)
    h = fnv1a("x")
    s, clock, ok = kv_set(s, h, _row(s, [1.0, 2.0]), 2, clock, 0)
    assert bool(ok)
    val, length, ver, found = kv_get(s, h)
    assert bool(found) and int(length) == 2
    np.testing.assert_allclose(np.asarray(val[:2]), [1.0, 2.0])
    assert int(unpack_clock(ver)) == int(clock)


def test_get_missing():
    s = store_new(8, 4, MAX_NODES)
    _, _, _, found = kv_get(s, fnv1a("nope"))
    assert not bool(found)


def test_update_in_place_no_new_slot():
    s = store_new(4, 4, MAX_NODES)
    clock = jnp.zeros((), jnp.int32)
    h = fnv1a("k")
    s, clock, _ = kv_set(s, h, _row(s, [1.0]), 1, clock, 0)
    s, clock, _ = kv_set(s, h, _row(s, [2.0]), 1, clock, 0)
    assert int((s.keys != 0).sum()) == 1
    val, _, _, _ = kv_get(s, h)
    assert float(val[0]) == 2.0


def test_arena_overflow_drops_write():
    s = store_new(2, 4, MAX_NODES)
    clock = jnp.zeros((), jnp.int32)
    for i in range(2):
        s, clock, ok = kv_set(s, fnv1a(f"k{i}"), _row(s, [float(i)]), 1,
                              clock, 0)
        assert bool(ok)
    s2, clock2, ok = kv_set(s, fnv1a("k2"), _row(s, [9.0]), 1, clock, 0)
    assert not bool(ok)
    assert int(clock2) == int(clock)          # clock unchanged on drop
    assert store_contents(s2) == store_contents(s)


def test_delete_tombstone_replicates():
    s = store_new(4, 4, MAX_NODES)
    clock = jnp.zeros((), jnp.int32)
    h = fnv1a("k")
    s, clock, _ = kv_set(s, h, _row(s, [1.0]), 1, clock, 0)
    s, clock, ok = kv_delete(s, h, clock, 0)
    assert bool(ok)
    _, _, _, found = kv_get(s, h)
    assert not bool(found)                    # reads as absent
    # but the tombstone wins an LWW merge against the stale peer copy
    peer = store_new(4, 4, MAX_NODES)
    pc = jnp.zeros((), jnp.int32)
    peer, pc, _ = kv_set(peer, h, _row(peer, [1.0]), 1, pc, 1)
    merged = merge_stores(peer, s)
    _, _, _, found = kv_get(merged, h)
    assert not bool(found), "tombstone must dominate the older write"


def test_scan_multi_get():
    s = store_new(8, 4, MAX_NODES)
    clock = jnp.zeros((), jnp.int32)
    for i in range(3):
        s, clock, _ = kv_set(s, fnv1a(f"k{i}"), _row(s, [float(i)]), 1,
                             clock, 0)
    vals, lengths, found = kv_scan(s, [fnv1a("k0"), fnv1a("k2"),
                                       fnv1a("missing")])
    assert list(np.asarray(found)) == [True, True, False]
    np.testing.assert_allclose(np.asarray(vals[:2, 0]), [0.0, 2.0])


def test_merge_takes_newer_and_inserts_new():
    a = store_new(8, 4, MAX_NODES)
    b = store_new(8, 4, MAX_NODES)
    ca = jnp.zeros((), jnp.int32)
    cb = jnp.zeros((), jnp.int32)
    h_shared = fnv1a("shared")
    a, ca, _ = kv_set(a, h_shared, _row(a, [1.0]), 1, ca, 0)
    b, cb, _ = kv_set(b, h_shared, _row(b, [2.0]), 1, cb, 1)
    b, cb, _ = kv_set(b, h_shared, _row(b, [3.0]), 1, cb, 1)  # newer clock
    b, cb, _ = kv_set(b, fnv1a("bonly"), _row(b, [7.0]), 1, cb, 1)
    m = merge_stores(a, b)
    val, _, _, _ = kv_get(m, h_shared)
    assert float(val[0]) == 3.0
    val, _, _, found = kv_get(m, fnv1a("bonly"))
    assert bool(found) and float(val[0]) == 7.0
    np.testing.assert_array_equal(np.asarray(m.vv),
                                  np.maximum(np.asarray(a.vv),
                                             np.asarray(b.vv)))


def test_lamport_clock_dominates_after_merge():
    """A node that merges remote state must issue strictly newer versions."""
    a = store_new(8, 4, MAX_NODES)
    b = store_new(8, 4, MAX_NODES)
    ca = jnp.zeros((), jnp.int32)
    cb = jnp.zeros((), jnp.int32)
    h = fnv1a("k")
    for _ in range(5):
        b, cb, _ = kv_set(b, h, _row(b, [9.0]), 1, cb, 1)
    a = merge_stores(a, b)
    a, ca, _ = kv_set(a, h, _row(a, [1.0]), 1, ca, 0)
    val, _, ver, _ = kv_get(a, h)
    assert float(val[0]) == 1.0
    assert int(unpack_clock(ver)) > int(cb), "local write must win LWW"
