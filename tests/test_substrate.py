"""Substrate tests: checkpoint round-trip/reshard, optimizers, schedules,
data pipeline determinism, compression, consistency sessions."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.tier0  # fast pre-commit subset

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2, 2), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_serializer_roundtrip():
    from repro.checkpoint import deserialize_tree, serialize_tree
    t = _tree()
    blob = serialize_tree(t)
    out = deserialize_tree(blob, jax.tree.map(np.asarray, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_serializer_integrity_check():
    from repro.checkpoint import deserialize_tree, serialize_tree
    from repro.checkpoint.serializer import compress_bytes, decompress_bytes
    blob = serialize_tree(_tree())
    raw = bytearray(decompress_bytes(blob))
    raw[len(raw) // 2] ^= 0xFF
    corrupted = compress_bytes(bytes(raw))
    with pytest.raises(Exception):
        deserialize_tree(corrupted, _tree())


def test_serializer_corrupt_magic_raises_ioerror():
    """A blob whose leading (magic) bytes are corrupted must surface the
    checkpoint-corruption IOError, not a raw ``zlib.error`` from the
    fallback decompressor."""
    from repro.checkpoint import serialize_tree
    from repro.checkpoint.serializer import decompress_bytes
    blob = bytearray(serialize_tree(_tree()))
    blob[0] ^= 0xFF
    blob[1] ^= 0xFF
    with pytest.raises(IOError, match="corrupted|zstd"):
        decompress_bytes(bytes(blob))


def test_serializer_truncated_frame_raises_ioerror():
    from repro.checkpoint import serialize_tree
    from repro.checkpoint.serializer import decompress_bytes
    blob = serialize_tree(_tree())
    with pytest.raises(IOError, match="corrupted|zstd"):
        decompress_bytes(blob[: len(blob) // 2])


def test_serializer_zstd_magic_without_zstd_raises_ioerror():
    """A frame carrying the zstd magic must fail as an IOError either way:
    'zstandard not installed' when the module is absent, frame-corruption
    when it is present (the payload here is junk)."""
    from repro.checkpoint.serializer import _ZSTD_MAGIC, decompress_bytes
    with pytest.raises(IOError):
        decompress_bytes(_ZSTD_MAGIC + b"\x00\x01junk")


def test_manager_save_restore_retention(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for step in [1, 2, 3, 4]:
        mgr.save(step, jax.tree.map(lambda x: x + step, t), blocking=False)
    mgr.wait()
    assert mgr.steps() == [3, 4], "retention must keep the last 2"
    out = mgr.restore(t)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(t["a"]) + 4)


def test_manager_restore_with_resharding(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jnp.ones((4, 4))}
    mgr.save(7, t, blocking=True)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = mgr.restore(t, shardings=sh)
    assert out["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quadratic_progress(update_fn, init_fn, steps=60, lr=0.1):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    state = init_fn(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = loss(params)
    for _ in range(steps):
        grads = jax.grad(loss)(params)
        params, state, _ = update_fn(grads, state, params, lr)
    return float(l0), float(loss(params))


def test_adamw_decreases_loss():
    from repro.optim import adamw_init, adamw_update
    l0, l1 = _quadratic_progress(
        lambda g, s, p, lr: adamw_update(g, s, p, lr, weight_decay=0.0),
        adamw_init)
    assert l1 < 0.05 * l0


def test_adafactor_decreases_loss():
    from repro.optim import adafactor_init, adafactor_update
    l0, l1 = _quadratic_progress(
        lambda g, s, p, lr: adafactor_update(g, s, p, lr),
        adafactor_init)
    assert l1 < 0.2 * l0


def test_adafactor_memory_is_factored():
    from repro.optim import adafactor_init
    p = {"w": jnp.zeros((128, 64))}
    st = adafactor_init(p)
    n_state = sum(x.size for x in jax.tree.leaves(st["v"]))
    assert n_state == 128 + 64, "second moment must be O(R+C), not O(R*C)"


def test_grad_clip():
    from repro.optim.adamw import clip_by_global_norm
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-4)


def test_warmup_cosine_shape():
    from repro.optim import warmup_cosine
    lrs = [float(warmup_cosine(jnp.asarray(s), 1e-3, 10, 100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert lrs[50] < lrs[10]
    assert lrs[99] >= 1e-4 - 1e-9  # final_frac floor


def test_diloco_outer_pulls_towards_consensus():
    from repro.optim import diloco_init, diloco_local_delta, diloco_outer_update
    outer0 = {"w": jnp.zeros((4,))}
    state = diloco_init(outer0)
    # two pods moved in the same direction: outer must follow
    local_a = {"w": jnp.full((4,), 1.0)}
    local_b = {"w": jnp.full((4,), 3.0)}
    deltas = jax.tree.map(
        lambda *ds: sum(ds) / len(ds),
        diloco_local_delta(state["outer_params"], local_a),
        diloco_local_delta(state["outer_params"], local_b))
    new_outer, state = diloco_outer_update(state, deltas, outer_lr=0.5,
                                           outer_momentum=0.0)
    # mean delta = -2 -> outer moves +1 with lr 0.5
    np.testing.assert_allclose(np.asarray(new_outer["w"]), 1.0)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_compression_bounded_error():
    from repro.optim import int8_compress, int8_decompress
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 5
    p = int8_compress(x)
    err = jnp.abs(int8_decompress(p) - x).max()
    assert float(err) <= float(p.scale) * 0.5 + 1e-6
    assert p.q.dtype == jnp.int8


def test_topk_compression_with_error_feedback():
    from repro.optim import topk_compress, topk_decompress
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))
    payload, residual = topk_compress(x, 8)
    np.testing.assert_allclose(
        np.asarray(topk_decompress(payload) + residual), np.asarray(x),
        rtol=1e-6)
    assert payload.values.shape == (8,)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    from repro.configs import SHAPES_BY_NAME, get_arch, reduced, reduced_shape
    from repro.data import DataPipeline, synthetic_batch
    arch = reduced(get_arch("internlm2-1.8b"))
    shape = reduced_shape(SHAPES_BY_NAME["train_4k"])
    b1 = synthetic_batch(arch, shape, seed=0, step=5, shard=0, num_shards=2)
    b2 = synthetic_batch(arch, shape, seed=0, step=5, shard=0, num_shards=2)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic_batch(arch, shape, seed=0, step=5, shard=1, num_shards=2)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"])), "shards must differ"
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


def test_data_cursor_restart():
    from repro.configs import SHAPES_BY_NAME, get_arch, reduced, reduced_shape
    from repro.data import DataPipeline
    arch = reduced(get_arch("internlm2-1.8b"))
    shape = reduced_shape(SHAPES_BY_NAME["train_4k"])
    p1 = DataPipeline(arch, shape)
    batches = [p1.next() for _ in range(3)]
    # restart from the replicated cursor: must resume at step 3
    p2 = DataPipeline(arch, shape)
    p2.restore(p1.cursor)
    b3 = p2.next()
    p1b = DataPipeline(arch, shape)
    for _ in range(3):
        expected = p1b.next()
    expected = p1b.next()
    np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                  np.asarray(expected["tokens"]))


# ---------------------------------------------------------------------------
# consistency sessions
# ---------------------------------------------------------------------------

def test_session_read_your_writes():
    from repro.core import Session
    s = Session(num_nodes=4)
    s.observe_write(2, 7)
    stale = np.zeros(4, np.int32)
    fresh = np.zeros(4, np.int32)
    fresh[2] = 7
    assert not s.can_read_from(stale)
    assert s.can_read_from(fresh)


def test_session_monotonic_reads():
    from repro.core import Session
    s = Session(num_nodes=2)
    s.observe_read(np.asarray([5, 0], np.int32))
    assert not s.can_read_from(np.asarray([4, 0], np.int32))
    assert s.can_read_from(np.asarray([5, 0], np.int32))


# ---------------------------------------------------------------------------
# runtime policies
# ---------------------------------------------------------------------------

def test_straggler_policy():
    from repro.runtime import StragglerPolicy
    pol = StragglerPolicy(max_staleness_rounds=2, quorum_frac=0.5)
    pods = ["p0", "p1", "p2", "p3"]
    for p in pods[:3]:
        pol.report(p, 5)
    assert pol.can_proceed(5, pods)
    assert pol.laggards(5, pods) == ["p3"]
    assert pol.too_stale("p3", 5)
    assert not pol.too_stale("p0", 5)


def test_health_monitor():
    from repro.runtime import HealthMonitor
    hm = HealthMonitor(timeout_s=10.0, lag_steps=5)
    hm.beat("a", step=100, t=0.0)
    hm.beat("b", step=90, t=0.0)
    assert hm.stragglers() == ["b"]
    assert hm.dead_nodes(now=11.0) == ["a", "b"]


def test_degraded_mesh_config():
    from repro.configs.base import MULTI_POD_MESH
    from repro.runtime import degraded_mesh_config
    d = degraded_mesh_config(MULTI_POD_MESH, alive_pods=1)
    assert d.shape == (16, 16) and "pod" not in d.axes
