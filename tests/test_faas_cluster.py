"""FaaS layer + cluster simulator: Listing 1 semantics, placement latency
accounting (fig 3), replication events and staleness (fig 6), failover."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ReplicationPolicy
from repro.core import (Cluster, KeygroupSpec, Router, Session, WriteLog,
                        enoki_function, get_function)
from repro.core.faas import FunctionSpec
from repro.runtime.failure import FailureInjector

jax.config.update("jax_platform_name", "cpu")


@enoki_function(name="counter", keygroups=["cnt"], codec_width=4)
def counter_fn(kv, x):
    cur, found = kv.get("count")
    new = jnp.where(found, cur[0] + 1.0, 1.0)
    kv.set("count", jnp.stack([new, 0.0, 0.0, 0.0]))
    return jnp.stack([new])


@enoki_function(name="movavg", keygroups=["avg"], codec_width=16)
def moving_average(kv, x):
    """The paper's §4.1 function: store value, read last 10, update pointer
    (4 kv ops per invocation)."""
    ptr, found = kv.get("ptr")
    idx = jnp.where(found, ptr[0], 0.0)
    kv.set(f"v", jnp.concatenate([jnp.atleast_1d(x)[:1],
                                  jnp.zeros((15,))]))
    window, _ = kv.scan([f"v"])
    kv.set("ptr", jnp.stack([idx + 1.0]))
    return jnp.stack([window[:, 0].mean()])


def make_cluster(**kw):
    return Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"}, **kw)


def test_listing1_semantics():
    c = make_cluster(measure_compute=False)
    c.deploy(get_function("counter"), ["edge"])
    r1 = c.invoke("counter", "edge", jnp.zeros((1,)))
    r2 = c.invoke("counter", "edge", jnp.zeros((1,)), t_send=r1.t_received)
    assert float(np.asarray(r1.output)[0]) == 1.0
    assert float(np.asarray(r2.output)[0]) == 2.0, "state persists across calls"


def test_warm_start_no_recompile():
    c = make_cluster(measure_compute=False)
    c.deploy(get_function("counter"), ["edge"])
    h1 = c.nodes["edge"].handlers["counter"]
    c.invoke("counter", "edge", jnp.zeros((1,)))
    assert c.nodes["edge"].handlers["counter"] is h1


def test_fig3_cloud_store_adds_latency():
    """Store in cloud: every kv op pays the 50ms RTT; with 4 ops the paper
    measures +200ms (§4.1)."""
    edge = make_cluster(measure_compute=False)
    edge.deploy(get_function("movavg"), ["edge"],
                policy=ReplicationPolicy.REPLICATED)
    cloud = make_cluster(measure_compute=False)
    cloud.deploy(get_function("movavg"), ["edge"],
                 policy=ReplicationPolicy.CLOUD_CENTRAL, owner="cloud")
    r_edge = edge.invoke("movavg", "edge", jnp.ones((1,)))
    r_cloud = cloud.invoke("movavg", "edge", jnp.ones((1,)))
    delta = r_cloud.response_ms - r_edge.response_ms
    assert len(r_cloud.kv_ops) == 4
    assert 195.0 <= delta <= 215.0, f"expected ≈+200ms, got {delta}"


def test_fig6_replication_staleness():
    """Write on edge, read on edge2: REPLICATED serves locally with bounded
    staleness; reads after the one-way delay see the new value."""
    c = make_cluster(measure_compute=False)
    c.deploy(get_function("counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    w = c.invoke("counter", "edge", jnp.zeros((1,)))
    # read on edge2 arriving BEFORE the 10ms one-way replication delay
    # (client->edge2 one-way is 10.5ms, so send while the write replicates)
    r_early = c.invoke("counter", "edge2", jnp.zeros((1,)),
                       t_send=w.t_applied - 9.0)
    # counter_fn increments what it sees: stale -> writes 1 again
    assert float(np.asarray(r_early.output)[0]) == 1.0
    # read after the delay: sees edge's write (its own 1 + edge's 1 merged ->
    # higher version wins; edge2's write was later so value reflects merge)
    r_late = c.invoke("counter", "edge2", jnp.zeros((1,)),
                      t_send=w.t_applied + 50.0)
    assert float(np.asarray(r_late.output)[0]) == 2.0


def test_peer_fetch_pays_rtt_on_read():
    c = make_cluster(measure_compute=False)
    c.deploy(get_function("counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.PEER_FETCH, owner="edge")
    r_local = c.invoke("counter", "edge", jnp.zeros((1,)))
    r_remote = c.invoke("counter", "edge2", jnp.zeros((1,)),
                        t_send=r_local.t_received)
    assert r_remote.response_ms > r_local.response_ms + 30.0, \
        "remote node must pay the 20ms RTT per kv op"


def test_router_failover_and_session():
    c = make_cluster(measure_compute=False)
    c.deploy(get_function("counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    router = Router(c, client="client")
    r1 = router.invoke("counter", jnp.zeros((1,)), session_id="s1")
    assert r1.node == "edge"     # nearest
    FailureInjector(c).kill_node("edge")
    r2 = router.invoke("counter", jnp.zeros((1,)), session_id="s1",
                       t_send=r1.t_received)
    assert r2.node == "edge2", "router must fail over to the live replica"


def test_keygroup_restore_from_peer():
    c = make_cluster(measure_compute=False)
    c.deploy(get_function("counter"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    c.invoke("counter", "edge", jnp.zeros((1,)))
    c.flush_replication()
    inj = FailureInjector(c)
    inj.lose_keygroup("edge2", "cnt")
    assert inj.restore_keygroup_from_peer("edge2", "cnt")
    r = c.invoke("counter", "edge2", jnp.zeros((1,)), t_send=100.0)
    assert float(np.asarray(r.output)[0]) == 2.0, \
        "restored replica must contain the pre-failure state"


def test_staleness_writelog():
    log = WriteLog()
    log.add(10.0, 1)
    log.add(20.0, 2)
    assert log.staleness_of_read(25.0, 2) == 0.0
    assert log.staleness_of_read(25.0, 1) == 5.0   # overwritten at t=20
    assert log.latest_at(15.0) == 1


def test_staleness_writelog_out_of_order_adds():
    """Replicated writes ARRIVE out of apply-time order by design: the log
    must insertion-sort its records so bisect-backed queries see the same
    answers as an in-order feed (regression for the unsorted-scan
    version, which assumed in-order add)."""
    log = WriteLog()
    for t, p in [(20.0, 2), (5.0, 1), (35.0, 4), (28.0, 3)]:
        log.add(t, p)
    assert log.records == [(5.0, 1), (20.0, 2), (28.0, 3), (35.0, 4)]
    assert log.latest_at(1.0) is None
    assert log.latest_at(30.0) == 3
    assert log.latest_at(100.0) == 4
    # payload 1 was first overwritten at t=20
    assert log.staleness_of_read(30.0, 1) == 10.0
    # payload 2 was first overwritten at t=28
    assert log.staleness_of_read(40.0, 2) == 12.0
    assert log.staleness_of_read(30.0, 3) == 0.0   # newest applied by t=30
    # a read BEFORE any overwrite applied is fresh
    assert log.staleness_of_read(19.0, 1) == 0.0


def test_staleness_writelog_non_comonotonic_feed_stays_exact():
    """If a feed ever violates the single-client contract (payload ids not
    co-monotonic with apply times), staleness must fall back to the exact
    scan rather than bisecting a payload-unsorted list."""
    log = WriteLog()
    for t, p in [(10.0, 5), (20.0, 3), (30.0, 6)]:
        log.add(t, p)
    # the earliest newer-payload record applied by t=35 is (10.0, 5):
    # a bisect on the time-sorted list keyed by payload would miss it
    assert log.staleness_of_read(35.0, 3) == 25.0
    assert log.staleness_of_read(35.0, 5) == 5.0   # overwritten by 6 at 30
    assert log.staleness_of_read(35.0, 6) == 0.0
    assert log.latest_at(25.0) == 3                # latest applied by t=25
