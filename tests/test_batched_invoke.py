"""Batched invocation engine: equivalence with sequential invoke (the
tentpole invariant), per-request timing, bucket padding, the read-only vmap
path, and the submit/flush coalescing API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster, enoki_function, get_function
from repro.core.store import kv_set, kv_set_fold, store_contents, store_new
from repro.core.versioning import MAX_NODES, fnv1a

jax.config.update("jax_platform_name", "cpu")


@enoki_function(name="batched_mix", keygroups=["bmixkg"], codec_width=8)
def batched_mix(kv, x):
    """Mixed get/set/scan — exercises the scan-fold store path."""
    cur, found = kv.get("acc")
    kv.set("acc", cur + x)
    tot, _ = kv.scan(["acc"])
    return jnp.stack([cur[0] + x[0], tot[0, 0]])


@enoki_function(name="batched_peek", keygroups=["bmixkg"], codec_width=8)
def batched_peek(kv, x):
    """Read-only — exercises the vmap path."""
    cur, found = kv.get("acc")
    return cur[:2] + x[:2]


def _cluster(policy, owner=None):
    c = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                measure_compute=False)
    c.deploy(get_function("batched_mix"), ["edge", "edge2"], policy=policy,
             owner=owner)
    return c


def _assert_same_state(c1, c2, kg="bmixkg"):
    for name in c1.nodes:
        s1 = c1.nodes[name].stores.get(kg)
        s2 = c2.nodes[name].stores.get(kg)
        assert (s1 is None) == (s2 is None), name
        if s1 is not None:
            for leaf1, leaf2 in zip(s1, s2):
                np.testing.assert_array_equal(np.asarray(leaf1),
                                              np.asarray(leaf2),
                                              err_msg=f"arena at {name}")
        np.testing.assert_array_equal(np.asarray(c1.nodes[name].clock),
                                      np.asarray(c2.nodes[name].clock),
                                      err_msg=f"clock at {name}")


@pytest.mark.parametrize("policy,owner", [
    (ReplicationPolicy.REPLICATED, None),
    (ReplicationPolicy.PEER_FETCH, "edge"),
    (ReplicationPolicy.CLOUD_CENTRAL, "cloud"),
])
def test_batch_equals_sequential_all_placements(policy, owner):
    """64 mixed get/set invocations: byte-identical final arena, vector
    clock, outputs, and per-request timings vs 64 sequential invokes."""
    xs = [np.arange(8, dtype=np.float32) + i for i in range(64)]
    ts = [i * 0.25 for i in range(64)]
    c_seq, c_bat = _cluster(policy, owner), _cluster(policy, owner)

    seq = [c_seq.invoke("batched_mix", "edge", x, t_send=t)
           for x, t in zip(xs, ts)]
    bat = c_bat.invoke_batch("batched_mix", "edge", xs, t_sends=ts)

    assert len(bat) == 64
    for a, b in zip(seq, bat):
        np.testing.assert_array_equal(np.asarray(a.output),
                                      np.asarray(b.output))
        assert a.response_ms == b.response_ms
        assert a.t_received == b.t_received
        assert a.t_applied == b.t_applied
        assert a.kv_ops == b.kv_ops
        assert a.chain == b.chain
    # replication coalescing must converge peers to the same contents
    c_seq.flush_replication()
    c_bat.flush_replication()
    _assert_same_state(c_seq, c_bat)


def test_per_request_network_timing():
    """Each request in a batch keeps its own send/arrival/response
    timeline."""
    c = _cluster(ReplicationPolicy.REPLICATED)
    ts = [0.0, 7.5, 40.0, 41.25]
    xs = [np.ones(8, np.float32)] * 4
    rs = c.invoke_batch("batched_mix", "edge", xs, t_sends=ts)
    for t, r in zip(ts, rs):
        assert r.t_sent == t
        # same link + same static op trace -> same response latency, but
        # anchored at each request's own send time
        assert r.t_received == pytest.approx(t + rs[0].response_ms)
    assert rs[0].response_ms > 0.0


def test_bucket_padding_is_masked_out():
    """A batch of 5 pads to the 8-bucket; padded slots must not write."""
    xs = [np.full(8, float(i), np.float32) for i in range(5)]
    c_seq = _cluster(ReplicationPolicy.REPLICATED)
    c_bat = _cluster(ReplicationPolicy.REPLICATED)
    seq = [c_seq.invoke("batched_mix", "edge", x, t_send=float(i))
           for i, x in enumerate(xs)]
    bat = c_bat.invoke_batch("batched_mix", "edge", xs,
                             t_sends=[float(i) for i in range(5)])
    assert len(bat) == 5
    for a, b in zip(seq, bat):
        np.testing.assert_array_equal(np.asarray(a.output),
                                      np.asarray(b.output))
    c_seq.flush_replication()
    c_bat.flush_replication()
    _assert_same_state(c_seq, c_bat)


def test_read_only_batch_uses_vmap_and_leaves_state_alone():
    c = _cluster(ReplicationPolicy.REPLICATED)
    c.deploy(get_function("batched_peek"), ["edge"])
    assert c.nodes["edge"].batched_handlers["batched_peek"].read_only
    assert not c.nodes["edge"].batched_handlers["batched_mix"].read_only
    c.invoke("batched_mix", "edge", np.ones(8, np.float32))
    before = store_contents(c.nodes["edge"].stores["bmixkg"])
    clock_before = int(c.nodes["edge"].clock)
    rs = c.invoke_batch("batched_peek", "edge",
                        [np.full(8, float(i), np.float32) for i in range(16)],
                        t_sends=[float(i) for i in range(16)])
    # every request saw the same snapshot
    seq = [c.invoke("batched_peek", "edge", np.full(8, float(i), np.float32),
                    t_send=float(i)) for i in range(16)]
    for a, b in zip(seq, rs):
        np.testing.assert_array_equal(np.asarray(a.output),
                                      np.asarray(b.output))
    assert store_contents(c.nodes["edge"].stores["bmixkg"]) == before
    assert int(c.nodes["edge"].clock) == clock_before


def test_oversize_batch_chunks_at_largest_bucket():
    n = 300   # > largest default bucket (256): folded chunk-by-chunk
    xs = [np.full(8, 1.0, np.float32)] * n
    c_seq = _cluster(ReplicationPolicy.REPLICATED)
    c_bat = _cluster(ReplicationPolicy.REPLICATED)
    for i in range(n):
        c_seq.invoke("batched_mix", "edge", xs[i], t_send=float(i))
    bat = c_bat.invoke_batch("batched_mix", "edge", xs,
                             t_sends=[float(i) for i in range(n)])
    assert len(bat) == n
    c_seq.flush_replication()
    c_bat.flush_replication()
    _assert_same_state(c_seq, c_bat)


def test_submit_flush_coalesces_by_function_and_node():
    c = _cluster(ReplicationPolicy.REPLICATED)
    c.deploy(get_function("batched_peek"), ["edge"])
    tickets = []
    for i in range(6):
        fn = "batched_mix" if i % 2 == 0 else "batched_peek"
        tickets.append((c.engine.submit(fn, "edge",
                                        np.full(8, float(i), np.float32),
                                        t_send=float(i)), fn))
    results = c.engine.flush()
    assert len(results) == 6
    for t, fn in tickets:
        assert results[t].chain == [fn]
        assert results[t].t_sent == float(tickets.index((t, fn)))
    assert c.engine.flush() == {}   # queue drained


@enoki_function(name="batched_async_src", keygroups=[],
                async_calls=["batched_async_sink"], codec_width=4)
def batched_async_src(kv, x):
    return x[:2]


@enoki_function(name="batched_async_sink", keygroups=["asinkkg"],
                codec_width=4)
def batched_async_sink(kv, x):
    cur, _ = kv.get("n")
    kv.set("n", cur + 1.0)
    return x[:1]


def test_async_only_downstream_fires_in_both_paths():
    """Functions with ONLY async_calls must trigger their callees (was
    silently skipped before PR 1) — and async latency must not leak into
    the caller's response."""
    c = Cluster({"edge": "edge", "cloud": "cloud"}, measure_compute=False)
    c.deploy(get_function("batched_async_sink"), ["edge"])
    c.deploy(get_function("batched_async_src"), ["edge"])
    x = np.ones(4, np.float32)
    r = c.invoke("batched_async_src", "edge", x)
    assert r.chain == ["batched_async_src", "batched_async_sink"]
    rb = c.invoke_batch("batched_async_src", "edge", [x] * 3,
                        t_sends=[10.0, 11.0, 12.0])
    for sub in rb:
        assert sub.chain == ["batched_async_src", "batched_async_sink"]
        assert sub.response_ms == pytest.approx(r.response_ms)
    contents = store_contents(c.nodes["edge"].stores["asinkkg"])
    assert list(contents.values())[0][2][0] == 4.0   # sink ran 1 + 3 times


@enoki_function(name="batched_pair", keygroups=["pairkg"], codec_width=4)
def batched_pair(kv, x):
    """Tuple-structured input — batching must preserve pytree structure."""
    a, b = x
    cur, _ = kv.get("s")
    kv.set("s", cur + a[:4])
    return a[:2] + b[:2]


def test_pytree_inputs_keep_structure():
    example = (np.zeros(4, np.float32), np.zeros(2, np.float32))
    c = Cluster({"edge": "edge", "cloud": "cloud"}, measure_compute=False)
    c.deploy(get_function("batched_pair"), ["edge"], example_input=example)
    xs = [(np.full(4, float(i), np.float32),
           np.full(2, 10.0 * i, np.float32)) for i in range(6)]
    c2 = Cluster({"edge": "edge", "cloud": "cloud"}, measure_compute=False)
    c2.deploy(get_function("batched_pair"), ["edge"], example_input=example)
    seq = [c.invoke("batched_pair", "edge", x, t_send=float(i))
           for i, x in enumerate(xs)]
    bat = c2.invoke_batch("batched_pair", "edge", xs,
                          t_sends=[float(i) for i in range(6)])
    for a, b in zip(seq, bat):
        np.testing.assert_array_equal(np.asarray(a.output),
                                      np.asarray(b.output))
    _assert_same_state(c, c2, kg="pairkg")


def test_flush_survives_bad_group():
    """An undeployed function in the queue must fail the flush up front,
    with NO side effects and no lost tickets."""
    c = _cluster(ReplicationPolicy.REPLICATED)
    ok = c.engine.submit("batched_mix", "edge", np.ones(8, np.float32))
    bad = c.engine.submit("not_deployed", "edge", np.ones(8, np.float32))
    before = store_contents(c.nodes["edge"].stores["bmixkg"])
    with pytest.raises(KeyError, match="not_deployed"):
        c.engine.flush()
    # nothing dispatched, queue intact
    assert store_contents(c.nodes["edge"].stores["bmixkg"]) == before
    assert len(c.engine.pending()) == 2
    # drop the bad request (public queue-surgery API) and the good one must
    # still be redeemable
    assert c.engine.discard(bad)
    assert not c.engine.discard(bad)      # already gone
    assert [p["ticket"] for p in c.engine.pending()] == [ok]
    results = c.engine.flush()
    assert ok in results and results[ok].chain == ["batched_mix"]


def test_flush_mid_dispatch_failure_keeps_dispatched_results():
    """If a later group's dispatch raises, results of groups that already
    ran (store effects applied) must surface on the NEXT flush."""
    c = _cluster(ReplicationPolicy.REPLICATED)
    c.deploy(get_function("batched_pair"), ["edge"],
             example_input=(np.zeros(4, np.float32),
                            np.zeros(2, np.float32)))
    ok = c.engine.submit("batched_mix", "edge", np.ones(8, np.float32))
    # a LATER group that passes deployment validation but blows up at
    # trace time: plain array where the handler unpacks a 2-tuple
    bad = c.engine.submit("batched_pair", "edge", np.ones(8, np.float32),
                          t_send=1.0)
    with pytest.raises(Exception):
        c.engine.flush()
    # the failing group was dropped at-most-once style (its effects may have
    # committed); nothing left queued to poke
    assert c.engine.pending() == []
    # the good group dispatched (store mutated); its ticket must redeem now
    results = c.engine.flush()
    assert ok in results and results[ok].chain == ["batched_mix"]


@enoki_function(name="batched_gate", keygroups=[], calls=["batched_async_sink"],
                codec_width=4)
def batched_gate(kv, x):
    """Sync downstream gated by the fig-8 convention (first element < 0
    suppresses the call)."""
    return x[:2]


def test_mixed_fire_sync_downstream_matches_sequential():
    """Partial-fire batches: sub-results must stitch back onto the RIGHT
    requests (index remapping), matching sequential routing exactly."""
    c = Cluster({"edge": "edge", "cloud": "cloud"}, measure_compute=False)
    c.deploy(get_function("batched_async_sink"), ["edge"])
    c.deploy(get_function("batched_gate"), ["edge"])
    xs = [np.full(4, v, np.float32) for v in (1.0, -1.0, 2.0, -3.0, 4.0)]
    ts = [float(i) for i in range(5)]
    bat = c.invoke_batch("batched_gate", "edge", xs, t_sends=ts)
    c2 = Cluster({"edge": "edge", "cloud": "cloud"}, measure_compute=False)
    c2.deploy(get_function("batched_async_sink"), ["edge"])
    c2.deploy(get_function("batched_gate"), ["edge"])
    seq = [c2.invoke("batched_gate", "edge", x, t_send=t)
           for x, t in zip(xs, ts)]
    for a, b in zip(seq, bat):
        assert a.chain == b.chain
        assert a.response_ms == b.response_ms
        np.testing.assert_array_equal(np.asarray(a.output),
                                      np.asarray(b.output))
    # the three positive requests fired, the two negative ones were filtered
    assert [r.chain for r in bat] == [
        ["batched_gate", "batched_async_sink"], ["batched_gate"],
        ["batched_gate", "batched_async_sink"], ["batched_gate"],
        ["batched_gate", "batched_async_sink"]]
    _assert_same_state(c, c2, kg="asinkkg")


def test_all_filtered_sync_downstream_still_returns_results():
    """A batch where NO request fires its sync callee must still finalize
    (regression: the wave loop once dropped such frames' results)."""
    c = Cluster({"edge": "edge", "cloud": "cloud"}, measure_compute=False)
    c.deploy(get_function("batched_async_sink"), ["edge"])
    c.deploy(get_function("batched_gate"), ["edge"])
    xs = [np.full(4, -1.0, np.float32)] * 3        # all filtered
    rs = c.invoke_batch("batched_gate", "edge", xs,
                        t_sends=[0.0, 1.0, 2.0])
    assert len(rs) == 3
    assert all(r.chain == ["batched_gate"] for r in rs)
    tk = c.engine.submit("batched_gate", "edge", xs[0])
    out = c.engine.flush()
    assert out[tk].chain == ["batched_gate"]


def test_downstream_cycle_raises_cleanly():
    @enoki_function(name="cycle_a", keygroups=[], calls=["cycle_b"],
                    codec_width=4)
    def cycle_a(kv, x):
        return x[:2]

    @enoki_function(name="cycle_b", keygroups=[], calls=["cycle_a"],
                    codec_width=4)
    def cycle_b(kv, x):
        return x[:2]

    c = Cluster({"edge": "edge", "cloud": "cloud"}, measure_compute=False)
    c.deploy(get_function("cycle_a"), ["edge"])
    c.deploy(get_function("cycle_b"), ["edge"])
    with pytest.raises(RecursionError, match="cycle"):
        c.invoke_batch("cycle_a", "edge", [np.ones(4, np.float32)])


def test_kv_set_fold_matches_sequential_sets():
    store = store_new(16, 4, MAX_NODES)
    clock = jnp.zeros((), jnp.int32)
    keys = [fnv1a(k) for k in ("a", "b", "a", "c")]
    rows = jnp.stack([jnp.full((4,), float(i + 1)) for i in range(4)])
    lens = [4, 4, 4, 4]

    s_seq, c_seq = store, clock
    for h, row, ln in zip(keys, rows, lens):
        s_seq, c_seq, _ = kv_set(s_seq, h, row, ln, c_seq, node_id=2)

    s_fold, c_fold, oks = kv_set_fold(store, keys, rows, lens, clock,
                                      node_id=2)
    assert bool(oks.all())
    np.testing.assert_array_equal(np.asarray(c_seq), np.asarray(c_fold))
    for a, b in zip(s_seq, s_fold):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # last-writer-wins within the batch: "a" holds the THIRD row
    contents = store_contents(s_fold)
    np.testing.assert_array_equal(
        np.asarray(contents[fnv1a("a")][2], np.float32),
        np.full((4,), 3.0, np.float32))
