"""Background flusher + flush-cycle semantics: arrival-time windows (a
request never waits past window_ms; full buckets flush early), pump
draining only due windows, cross-node flush parity vs per-node sequential
flushes, cross-caller downstream coalescing, and the replication
delivery-order regression (heap fix in Cluster._deliver_until)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.tier0  # fast pre-commit subset

from repro.configs.base import ReplicationPolicy
from repro.core import Cluster, enoki_function, get_function
from repro.core.store import store_contents

jax.config.update("jax_platform_name", "cpu")


@enoki_function(name="wf_mix", keygroups=["wfkg"], codec_width=8)
def wf_mix(kv, x):
    cur, found = kv.get("acc")
    kv.set("acc", cur + x)
    return cur[:2] + x[:2]


@enoki_function(name="wf_set", keygroups=["wfsetkg"], codec_width=4)
def wf_set(kv, x):
    kv.set("v", x)
    return x[:1]


def _cluster(nodes=("edge", "edge2", "cloud")):
    kinds = {"edge": "edge", "edge2": "edge", "cloud": "cloud"}
    return Cluster({n: kinds[n] for n in nodes}, measure_compute=False)


def _x(v=1.0):
    return np.full(8, v, np.float32)


# ---------------------------------------------------------------------------
# window semantics
# ---------------------------------------------------------------------------

def test_request_never_waits_past_window_ms():
    """A windowed request executes at its window's close: its latency is the
    solo latency plus AT MOST window_ms (exactly window_ms for the request
    that opened the window, less for later joiners)."""
    solo = _cluster()
    solo.deploy(get_function("wf_mix"), ["edge"])
    r0 = solo.invoke("wf_mix", "edge", _x(), t_send=0.0)

    c = _cluster()
    c.deploy(get_function("wf_mix"), ["edge"])
    c.engine.configure(window_ms=5.0)
    t1 = c.engine.submit("wf_mix", "edge", _x(), t_send=0.0)
    t2 = c.engine.submit("wf_mix", "edge", _x(), t_send=2.0)  # joins window
    assert c.engine.pump(0.0) == {}          # window not due yet
    out = c.engine.pump(1000.0)
    assert set(out) == {t1, t2}
    # opener waits the full window...
    assert out[t1].response_ms == pytest.approx(r0.response_ms + 5.0)
    # ...joiners strictly less — nobody waits past window_ms
    assert out[t2].response_ms < r0.response_ms + 5.0
    assert out[t2].response_ms > r0.response_ms
    # both executed at the window close (same apply instant)
    assert out[t1].t_applied == pytest.approx(out[t2].t_applied)


def test_full_bucket_flushes_early():
    """A window that fills to max_batch dispatches immediately — identical
    timing to an explicit batch, no deadline wait — and a later request
    opens a fresh window."""
    c = _cluster()
    c.deploy(get_function("wf_mix"), ["edge"])
    c.engine.configure(window_ms=1000.0, max_batch=4)
    ts = [float(i) for i in range(4)]
    tks = [c.engine.submit("wf_mix", "edge", _x(i), t_send=t)
           for i, t in enumerate(ts)]
    assert c.engine.stats.auto_flushes == 1
    assert c.engine.pending() == []          # flushed, nothing queued
    t5 = c.engine.submit("wf_mix", "edge", _x(9.0), t_send=4.0)
    assert [p["ticket"] for p in c.engine.pending()] == [t5]

    out = c.engine.pump(0.0)                 # nothing due; ready results only
    assert set(out) == set(tks)
    ref = _cluster()
    ref.deploy(get_function("wf_mix"), ["edge"])
    bat = ref.invoke_batch("wf_mix", "edge", [_x(i) for i in range(4)],
                           t_sends=ts)
    for tk, b in zip(tks, bat):
        assert out[tk].t_received == b.t_received
        assert out[tk].response_ms == b.response_ms
        np.testing.assert_array_equal(np.asarray(out[tk].output),
                                      np.asarray(b.output))


def test_auto_flush_validation_leaves_window_intact():
    """Flush-on-full validates BEFORE taking the window off the queue: a
    KeyError for an undeployed function must lose no tickets."""
    c = _cluster()
    c.deploy(get_function("wf_mix"), ["edge"])
    c.engine.configure(window_ms=100.0, max_batch=2)
    t1 = c.engine.submit("not_deployed", "edge", _x())
    with pytest.raises(KeyError, match="not_deployed"):
        c.engine.submit("not_deployed", "edge", _x())   # fills the window
    assert len(c.engine.pending()) == 2                 # nothing lost
    assert c.engine.discard(t1)


def test_out_of_order_arrival_opens_its_own_window():
    """A request arriving BEFORE a window's opener must not inherit the
    later deadline (it would wait past window_ms) — it opens its own,
    earlier-closing window."""
    solo = _cluster()
    solo.deploy(get_function("wf_mix"), ["edge"])
    r0 = solo.invoke("wf_mix", "edge", _x(), t_send=0.0)
    c = _cluster()
    c.deploy(get_function("wf_mix"), ["edge"])
    c.engine.configure(window_ms=5.0)
    late = c.engine.submit("wf_mix", "edge", _x(), t_send=10.0)
    early = c.engine.submit("wf_mix", "edge", _x(), t_send=0.0)
    assert len(c.engine.pending()) == 2                 # two windows
    out = c.engine.pump(1000.0)
    assert out[early].response_ms == pytest.approx(r0.response_ms + 5.0)
    assert out[late].response_ms == pytest.approx(r0.response_ms + 5.0)


def test_stateless_handlers_are_read_only_for_hedging():
    """An empty op trace (no kv ops at all) is trivially safe to re-invoke
    — at the PER-HANDLER level; whole-invocation safety is the cluster's
    call-graph walk (next test)."""
    from repro.core import handler_read_only
    assert handler_read_only([])
    assert handler_read_only([("get", 4), ("scan", 8)])
    assert not handler_read_only([("get", 4), ("set", 8)])


@enoki_function(name="wf_peek", keygroups=["wfkg"], codec_width=8)
def wf_peek(kv, x):
    cur, found = kv.get("acc")
    return cur[:2]


def test_read_only_gate_covers_downstream_calls():
    """Hedge safety is a CALL-GRAPH property: a stateless caller whose
    callee writes must NOT be read-only (a hedged retry re-runs the whole
    chain, double-applying the callee's writes)."""
    c = _cluster(("edge", "cloud"))
    c.deploy(get_function("wf_sink"), ["edge"])
    c.deploy(get_function("wf_src_a"), ["edge"])     # stateless -> wf_sink
    c.deploy(get_function("wf_mix"), ["edge"])
    c.deploy(get_function("wf_peek"), ["edge"])
    assert not c.is_read_only("wf_src_a")    # own trace empty, callee writes
    assert not c.is_read_only("wf_sink")
    assert not c.is_read_only("wf_mix")
    assert c.is_read_only("wf_peek")         # get-only, no callees


def test_pump_drains_only_due_windows():
    c = _cluster()
    c.deploy(get_function("wf_mix"), ["edge"])
    c.engine.configure(window_ms=5.0)
    early = c.engine.submit("wf_mix", "edge", _x(), t_send=0.0)
    late = c.engine.submit("wf_mix", "edge", _x(), t_send=100.0)  # new window
    assert len(c.engine.pending()) == 2
    out = c.engine.pump(50.0)
    assert set(out) == {early}
    assert [p["ticket"] for p in c.engine.pending()] == [late]
    out2 = c.engine.pump(math.inf)
    assert set(out2) == {late}
    assert c.engine.pending() == []
    assert c.engine.stats.deadline_flushes == 2


def test_flush_ignores_deadlines_and_charges_no_wait():
    """Explicit flush drains everything NOW with the pre-window timing model
    (requests execute at their own arrivals)."""
    solo = _cluster()
    solo.deploy(get_function("wf_mix"), ["edge"])
    r0 = solo.invoke("wf_mix", "edge", _x(), t_send=0.0)
    c = _cluster()
    c.deploy(get_function("wf_mix"), ["edge"])
    c.engine.configure(window_ms=50.0)
    t1 = c.engine.submit("wf_mix", "edge", _x(), t_send=0.0)
    out = c.engine.flush()
    assert out[t1].response_ms == pytest.approx(r0.response_ms)


# ---------------------------------------------------------------------------
# cross-node flush cycles
# ---------------------------------------------------------------------------

def test_cross_node_flush_parity_vs_sequential_per_node():
    """One flush cycle spanning two nodes must produce the same per-request
    outputs/timings and the same converged stores as dispatching each
    node's batch separately.  Send times are chosen so neither path can
    deliver a same-run replication snapshot mid-run (each node's batch
    applies >10 ms — the edge-edge one-way delay — after the other node's
    last arrival), which is exactly the regime where the cycle's
    parallel-timeline model and sequential dispatch must agree."""
    xs = [_x(float(i)) for i in range(8)]
    # edge requests send at 5.0..5.3 (arrive ~5.5), edge2 at 0.0..0.3
    # (arrive ~10.8): edge's snapshot reaches edge2 at ~15.5, edge2's
    # reaches edge at ~20.8 — both after every arrival of the run
    ts = [5.0 + i * 0.05 if i % 2 == 0 else i * 0.05 for i in range(8)]
    nodes = ["edge" if i % 2 == 0 else "edge2" for i in range(8)]

    c1 = _cluster()
    c1.deploy(get_function("wf_mix"), ["edge", "edge2"],
              policy=ReplicationPolicy.REPLICATED)
    tks = [c1.engine.submit("wf_mix", nd, x, t_send=t)
           for nd, x, t in zip(nodes, xs, ts)]
    out = c1.engine.flush()
    assert c1.engine.stats.cycles == 1

    c2 = _cluster()
    c2.deploy(get_function("wf_mix"), ["edge", "edge2"],
              policy=ReplicationPolicy.REPLICATED)
    ref = {}
    for nd in ("edge", "edge2"):
        idxs = [i for i in range(8) if nodes[i] == nd]
        rs = c2.invoke_batch("wf_mix", nd, [xs[i] for i in idxs],
                             t_sends=[ts[i] for i in idxs])
        for i, r in zip(idxs, rs):
            ref[i] = r

    for i, tk in enumerate(tks):
        a, b = out[tk], ref[i]
        np.testing.assert_array_equal(np.asarray(a.output),
                                      np.asarray(b.output))
        assert a.t_applied == b.t_applied
        assert a.t_received == b.t_received
        assert a.node == b.node
    c1.flush_replication()
    c2.flush_replication()
    for nd in ("edge", "edge2"):
        assert (store_contents(c1.nodes[nd].stores["wfkg"])
                == store_contents(c2.nodes[nd].stores["wfkg"]))
        np.testing.assert_array_equal(np.asarray(c1.nodes[nd].clock),
                                      np.asarray(c2.nodes[nd].clock))


@enoki_function(name="wf_src_a", keygroups=[], calls=["wf_sink"],
                codec_width=4)
def wf_src_a(kv, x):
    return x[:2]


@enoki_function(name="wf_src_b", keygroups=[], calls=["wf_sink"],
                codec_width=4)
def wf_src_b(kv, x):
    return x[:2]


@enoki_function(name="wf_sink", keygroups=["wfsinkkg"], codec_width=4)
def wf_sink(kv, x):
    cur, _ = kv.get("n")
    kv.set("n", cur + 1.0)
    return x[:1]


def test_cross_caller_downstream_coalescing():
    """Downstream calls from DIFFERENT caller groups of one flush cycle to
    the same callee merge into one batch: 3 wf_src_a + 2 wf_src_b requests
    reach wf_sink as a single 5-deep dispatch."""
    c = _cluster(("edge", "cloud"))
    c.deploy(get_function("wf_sink"), ["edge"])
    c.deploy(get_function("wf_src_a"), ["edge"])
    c.deploy(get_function("wf_src_b"), ["edge"])
    x = np.ones(4, np.float32)
    tks = []
    for i in range(3):
        tks.append(c.engine.submit("wf_src_a", "edge", x, t_send=float(i)))
    for i in range(2):
        tks.append(c.engine.submit("wf_src_b", "edge", x, t_send=3.0 + i))
    out = c.engine.flush()
    # 2 caller dispatches + ONE merged sink dispatch (not one per caller)
    assert c.engine.stats.dispatches == 3
    assert c.engine.stats.downstream_coalesced == 5
    assert all(out[t].chain[-1] == "wf_sink" for t in tks)
    contents = store_contents(c.nodes["edge"].stores["wfsinkkg"])
    assert list(contents.values())[0][2][0] == 5.0   # sink ran exactly 5x

    # per-request latency matches the sequential router path
    ref = _cluster(("edge", "cloud"))
    ref.deploy(get_function("wf_sink"), ["edge"])
    ref.deploy(get_function("wf_src_a"), ["edge"])
    r0 = ref.invoke("wf_src_a", "edge", x, t_send=0.0)
    assert out[tks[0]].response_ms == pytest.approx(r0.response_ms)


def test_cycle_coalesces_replication_snapshots():
    """Writes of one cycle to the same keygroup+node schedule ONE snapshot
    (per-group snapshots are coalesced), and peers still converge."""
    c = _cluster()
    c.deploy(get_function("wf_mix"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    # two DIFFERENT caller groups (distinct clients) writing the same
    # keygroup at the same store node in one cycle
    for i in range(2):
        c.engine.submit("wf_mix", "edge", _x(float(i)), t_send=float(i))
    for i in range(2):
        c.engine.submit("wf_mix", "edge", _x(10.0 + i), t_send=2.0 + i,
                        client="client2")
    c.engine.flush()
    # ONE replication event for the whole cycle, not one per group
    assert len(c.pending_replication()) == 1
    assert c.engine.stats.replication_coalesced == 1
    c.flush_replication()
    assert (store_contents(c.nodes["edge"].stores["wfkg"])
            == store_contents(c.nodes["edge2"].stores["wfkg"]))


# ---------------------------------------------------------------------------
# replication delivery order (Cluster._deliver_until regression)
# ---------------------------------------------------------------------------

def _heap_ok(events):
    return all(events[i] <= events[j]
               for i in range(len(events))
               for j in (2 * i + 1, 2 * i + 2) if j < len(events))


def test_deliver_until_applies_in_arrival_order(monkeypatch):
    """Three staggered snapshots scrambled in a node's pending queue must
    merge in (arrival, seq) order regardless of raw list layout."""
    import repro.core.cluster as cluster_mod
    c = _cluster()
    c.deploy(get_function("wf_set"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    for i, t in enumerate((0.0, 100.0, 200.0)):
        c.invoke("wf_set", "edge", np.full(4, float(i + 1), np.float32),
                 t_send=t)
    q = c._queues["edge2"]
    assert len(q.heap) == 3
    e1, e2, e3 = sorted(q.heap)
    q.heap = [e3, e1, e2]                    # scrambled raw order

    merged_arrivals = []
    real_fused = cluster_mod.merge_snapshots_fused

    def spying_fused(acc, snaps, *, aligned):
        # delivery now folds ALL due snapshots in one fused dispatch;
        # the order contract moves to the stacking order inside it
        merged_arrivals.extend(next(ev[0] for ev in (e1, e2, e3)
                                    if ev[3] is s) for s in snaps)
        return real_fused(acc, snaps, aligned=aligned)

    monkeypatch.setattr(cluster_mod, "merge_snapshots_fused", spying_fused)
    c._deliver_until("edge2", float("inf"))
    assert merged_arrivals == [e1[0], e2[0], e3[0]]   # network order
    assert q.heap == []
    assert c.pending_replication("edge2") == []
    val = store_contents(c.nodes["edge2"].stores["wfsetkg"]).popitem()[1][2]
    assert val[0] == 3.0                      # latest write wins


def test_deliver_until_reheapifies_keep_list():
    """A time-bounded partial delivery must leave the node's queue a valid
    heap (so later heappushes keep working) and must not touch any OTHER
    node's queue."""
    c = _cluster()
    c.deploy(get_function("wf_set"), ["edge", "edge2", "cloud"],
             policy=ReplicationPolicy.REPLICATED)
    for i, t in enumerate((0.0, 50.0, 100.0, 150.0)):
        c.invoke("wf_set", "edge", np.full(4, float(i), np.float32),
                 t_send=t)
    q = c._queues["edge2"]
    assert len(q.heap) == 4                  # 4 writes, per-node queue
    assert len(c._queues["cloud"].heap) == 4
    q.heap = list(reversed(sorted(q.heap)))  # worst-case scramble
    cutoff = sorted(ev[0] for ev in q.heap)[1]       # two of four due
    c._deliver_until("edge2", cutoff)
    assert len(q.heap) == 2                  # later deliveries kept...
    assert _heap_ok(q.heap)                  # ...as a valid heap
    assert len(c._queues["cloud"].heap) == 4          # other node untouched
    # and the heap keeps absorbing new events correctly
    c.invoke("wf_set", "edge", np.full(4, 9.0, np.float32), t_send=200.0)
    assert _heap_ok(q.heap)
    c.flush_replication()
    assert c.pending_replication() == []
    assert (store_contents(c.nodes["edge2"].stores["wfsetkg"])
            == store_contents(c.nodes["edge"].stores["wfsetkg"]))
