"""Device-resident merge path: slot-aligned elementwise merge and the
coalesced multi-way delivery merge must be BIT-IDENTICAL to the O(S^2)
``merge_stores`` baseline on aligned arenas — versions, lengths, keys and
version vectors included — and ``_deliver_until`` must fold K pending
snapshots in ONE fused dispatch.

Arenas are generated under the deploy contract ``store_assign_slots``
establishes: every registered key occupies the same canonical slot on
every replica (as a version-0 pre-assigned tombstone until written), so a
slot is either empty everywhere or stamped with the same key everywhere.
Per-replica slot states then vary freely: pre-assigned, live, or deleted
(tombstone with a real version), with adversarially small version ranges
so ties and both win directions occur.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.store import (arena_clone, merge_snapshots_fused,
                              merge_stores, merge_stores_aligned,
                              merge_stores_jit, store_assign_slots,
                              store_new, stores_equal)

jax.config.update("jax_platform_name", "cpu")

S, V, N = 8, 4, 4
SETTINGS = dict(max_examples=10, deadline=None)

# one replica's state for a stamped slot: kind, version, value row, length
_slot = st.tuples(st.sampled_from(["pre", "live", "dead"]),
                  st.integers(1, 8),
                  st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                           min_size=V, max_size=V),
                  st.integers(0, V))


def _arena_strategy(replicas):
    """(layout, per-slot states for each replica, per-replica vv)."""
    return st.tuples(
        st.lists(st.sampled_from([0, 1]), min_size=S, max_size=S),
        st.lists(st.tuples(*[_slot] * replicas), min_size=S, max_size=S),
        st.lists(st.lists(st.integers(0, 50), min_size=N, max_size=N),
                 min_size=replicas, max_size=replicas))


def _build(layout, states, vvs):
    """Materialise one aligned arena per replica from the drawn spec."""
    out = []
    for r, vv in enumerate(vvs):
        keys = np.zeros(S, np.int32)
        values = np.zeros((S, V), np.float32)
        lengths = np.zeros(S, np.int32)
        versions = np.zeros(S, np.int32)
        for i in range(S):
            if not layout[i]:
                continue            # empty on EVERY replica (shared layout)
            kind, ver, row, length = states[i][r]
            keys[i] = 1000 + i      # canonical key for slot i
            if kind == "pre":       # deploy-time pre-assignment
                lengths[i] = -1
            elif kind == "live":
                versions[i] = ver
                values[i] = row
                lengths[i] = length
            else:                   # deleted: tombstone with real version
                versions[i] = ver
                lengths[i] = -1
        out.append(store_new(S, V, N)._replace(
            keys=jnp.asarray(keys), values=jnp.asarray(values),
            lengths=jnp.asarray(lengths), versions=jnp.asarray(versions),
            vv=jnp.asarray(vv, jnp.int32)))
    return out


@pytest.mark.tier0
@given(_arena_strategy(2))
@settings(**SETTINGS)
def test_aligned_merge_matches_fallback(spec):
    """merge_stores_aligned == merge_stores, bitwise, on aligned arenas."""
    a, b = _build(*spec)
    assert stores_equal(merge_stores_aligned(a, b), merge_stores(a, b))


@pytest.mark.tier0
@given(_arena_strategy(6), st.integers(1, 5))
@settings(**SETTINGS)
def test_fused_multiway_matches_sequential(spec, k):
    """One fused K-way dispatch == K sequential two-way merges, bitwise,
    on BOTH the aligned and the fallback body (K is padded up to the next
    snapshot bucket internally — padding must not change the result)."""
    arenas = _build(*spec)
    acc, snaps = arenas[0], tuple(arenas[1:1 + k])
    expect = arena_clone(acc)
    for s in snaps:
        expect = merge_stores_jit(expect, s)
    for aligned in (True, False):
        got = merge_snapshots_fused(arena_clone(acc), snaps, aligned=aligned)
        assert stores_equal(got, expect), (aligned, k)


@pytest.mark.tier0
def test_store_assign_slots_contract():
    """Layout stamping: idempotent on a matching arena, refused on a
    conflicting one (the signal that flips a keygroup to the fallback)."""
    arena = store_new(S, V, N)
    layout = {1000: 0, 1001: 1}
    stamped, ok = store_assign_slots(arena, layout)
    assert ok and int(stamped.keys[0]) == 1000 and int(stamped.lengths[1]) == -1
    again, ok2 = store_assign_slots(stamped, layout)
    assert ok2 and stores_equal(again, stamped)   # no-op fast path
    _, ok3 = store_assign_slots(stamped, {1000: 1})    # hash lives elsewhere
    assert not ok3
    _, ok4 = store_assign_slots(stamped, {2000: 0})    # slot already taken
    assert not ok4


@pytest.mark.tier0
def test_delivery_merge_single_dispatch():
    """K>=4 pending snapshots at a replica fold in ONE fused dispatch on
    the slot-aligned path, and the post-merge store is byte-identical
    (version vectors included) to the sequential per-snapshot baseline."""
    from repro.core import Cluster, enoki_function, get_function
    from repro.core.faas import registry

    if "aligned_acc" not in registry():
        @enoki_function(name="aligned_acc", keygroups=["alignedkg"],
                        codec_width=4)
        def aligned_acc(kv, x):
            cur, _ = kv.get("acc")
            kv.set("acc", cur + jnp.atleast_1d(x)[:1])
            return cur[:1] + jnp.atleast_1d(x)[:1]

    c = Cluster({"edge": "edge", "edge2": "edge"}, measure_compute=False)
    c.deploy(get_function("aligned_acc"), ["edge", "edge2"],
             example_input=jnp.ones((1,), jnp.float32))
    assert c._aligned.get("alignedkg") is True     # deploy pre-assigned keys

    K = 5
    for i in range(K):
        c.invoke("aligned_acc", "edge", jnp.ones((1,), jnp.float32),
                 t_send=i * 10.0)

    # sequential baseline from the exact pending snapshots, on a clone
    with c._queues["edge2"].lock:
        pending = sorted(c._queues["edge2"].heap, key=lambda e: (e[0], e[1]))
    assert len(pending) == K
    baseline = arena_clone(c.nodes["edge2"].stores["alignedkg"])
    for _, _, kg, snap, _, _ in pending:
        assert kg == "alignedkg"
        baseline = merge_stores_jit(baseline, snap)

    d0, s0 = c.stats.merge_dispatches, c.stats.merge_snapshots
    a0 = c.stats.merge_aligned
    c.flush_replication(1e12)
    assert c.stats.merge_dispatches - d0 == 1, "K snapshots != one dispatch"
    assert c.stats.merge_snapshots - s0 == K
    assert c.stats.merge_aligned - a0 == 1
    assert stores_equal(c.nodes["edge2"].stores["alignedkg"], baseline)
