"""Render the §Roofline table from dry-run artifacts (artifacts/dryrun)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ART_DIR = os.environ.get("DRYRUN_ARTIFACTS",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "artifacts", "dryrun"))


def load_records(mesh: str = "16x16", art_dir: Optional[str] = None
                 ) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir or ART_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def _fmt_row(r: Dict) -> Optional[Dict]:
    if r.get("skipped"):
        return {"arch": r["arch"], "shape": r["shape"], "compute_s": "—",
                "memory_s": "—", "collective_s": "—", "dominant": "skip",
                "GiB/dev": "—", "useful%": "—", "roofline%": "—",
                "note": r.get("skip_reason", "")[:40]}
    if not r.get("ok"):
        return {"arch": r["arch"], "shape": r["shape"], "compute_s": "—",
                "memory_s": "—", "collective_s": "—", "dominant": "FAIL",
                "GiB/dev": "—", "useful%": "—", "roofline%": "—",
                "note": r.get("error", "")[:40]}
    t = r["roofline"]
    return {
        "arch": r["arch"], "shape": r["shape"],
        "compute_s": f"{t['compute_s']:.3e}",
        "memory_s": f"{t['memory_s']:.3e}",
        "collective_s": f"{t['collective_s']:.3e}",
        "dominant": t["dominant"].replace("_s", ""),
        "GiB/dev": f"{r['memory']['per_device_total']/2**30:.1f}",
        "useful%": f"{100*t['useful_flops_ratio']:.1f}",
        "roofline%": f"{100*t['roofline_fraction']:.2f}",
        "note": "",
    }


def table(mesh: str = "16x16", art_dir: Optional[str] = None) -> str:
    rows = [_fmt_row(r) for r in load_records(mesh, art_dir)]
    rows = [r for r in rows if r]
    if not rows:
        return f"(no artifacts for mesh {mesh} — run repro.launch.dryrun)"
    cols = list(rows[0].keys())
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join(["---"] * len(cols)) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(r[c]) for c in cols) + " |")
    return "\n".join(out)


def serving_costs(buckets=(1, 8, 64), merge_ks=(1, 2, 4, 8)) -> List[Dict]:
    """Cost the DEVICE-RESIDENT serving path straight from the deployed
    entry points (no artifacts needed): the batched scan-fold
    (``bstep.jit_scan``) per batch bucket, and the coalesced K-way
    delivery merge (``store.merge_many_fn``) per snapshot bucket, both
    slot-aligned and fallback.  Each row is the walker's trip-count-aware
    HLO cost of ONE dispatch — the unit the warm serving loop repeats.

    Jax is imported lazily so the module stays import-light for the
    artifact-only path.
    """
    import jax.numpy as jnp

    from repro.core import Cluster, enoki_function
    from repro.core.faas import get_function, registry
    from repro.core.store import merge_many_fn
    from repro.launch.roofline import abstractify, analyze_jit

    if "roofline_acc" not in registry():
        @enoki_function(name="roofline_acc", keygroups=["rooflinekg"],
                        codec_width=8)
        def roofline_acc(kv, x):
            cur, _ = kv.get("acc")
            kv.set("acc", cur + x)
            return cur + x

    c = Cluster({"edge": "edge"}, measure_compute=False)
    c.deploy(get_function("roofline_acc"), ["edge"],
             example_input=jnp.ones((8,), jnp.float32))
    nd = c.nodes["edge"]
    bh = nd.batched_handlers["roofline_acc"]
    store, clock = nd.stores["rooflinekg"], nd.clock

    def row(program, size, a):
        return {"program": program, "size": size,
                "flops": a["flops_per_device"],
                "bytes": a["bytes_per_device"],
                "unknown_trips": a["unknown_trip_counts"]}

    rows = []
    s_store, s_clock = abstractify(store), abstractify(clock)
    for b in buckets:
        xs = abstractify(jnp.zeros((b, 8), jnp.float32))
        valid = abstractify(jnp.zeros((b,), bool))
        rows.append(row("jit_scan", f"bucket={b}",
                        analyze_jit(bh.jit_scan, s_store, s_clock, xs,
                                    valid)))
    for aligned in (True, False):
        name = "merge/aligned" if aligned else "merge/fallback"
        for k in merge_ks:
            snaps = tuple(abstractify(store) for _ in range(k))
            rows.append(row(name, f"K={k}",
                            analyze_jit(merge_many_fn(aligned), s_store,
                                        snaps)))
    return rows


def serving_table(rows: Optional[List[Dict]] = None) -> str:
    rows = serving_costs() if rows is None else rows
    cols = list(rows[0].keys())
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join(["---"] * len(cols)) + "|"]
    for r in rows:
        out.append("| " + " | ".join(
            f"{r[c]:.3e}" if isinstance(r[c], float) else str(r[c])
            for c in cols) + " |")
    return "\n".join(out)


def main():
    print("\n## Roofline baseline — single-pod 16×16 (terms in s/step, "
          "per-chip)")
    print(table("16x16"))
    recs = [r for r in load_records("16x16") if r.get("ok")]
    if recs:
        worst = min(recs, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(recs,
                   key=lambda r: r["roofline"]["collective_s"]
                   / max(r["roofline"]["bound_step_s"], 1e-30))
        print(f"\nworst roofline fraction: {worst['arch']}×{worst['shape']} "
              f"({100*worst['roofline']['roofline_fraction']:.2f}%)")
        print(f"most collective-heavy: {coll['arch']}×{coll['shape']}")
    print("\n## Device-resident serving path — per-dispatch HLO cost "
          "(current backend)")
    try:
        print(serving_table())
    except Exception as exc:        # artifact-only environments (no jax)
        print(f"(serving-path costing unavailable: {exc})")
    return recs


if __name__ == "__main__":
    main()
