"""Render the §Roofline table from dry-run artifacts (artifacts/dryrun)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ART_DIR = os.environ.get("DRYRUN_ARTIFACTS",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "artifacts", "dryrun"))


def load_records(mesh: str = "16x16", art_dir: Optional[str] = None
                 ) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir or ART_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def _fmt_row(r: Dict) -> Optional[Dict]:
    if r.get("skipped"):
        return {"arch": r["arch"], "shape": r["shape"], "compute_s": "—",
                "memory_s": "—", "collective_s": "—", "dominant": "skip",
                "GiB/dev": "—", "useful%": "—", "roofline%": "—",
                "note": r.get("skip_reason", "")[:40]}
    if not r.get("ok"):
        return {"arch": r["arch"], "shape": r["shape"], "compute_s": "—",
                "memory_s": "—", "collective_s": "—", "dominant": "FAIL",
                "GiB/dev": "—", "useful%": "—", "roofline%": "—",
                "note": r.get("error", "")[:40]}
    t = r["roofline"]
    return {
        "arch": r["arch"], "shape": r["shape"],
        "compute_s": f"{t['compute_s']:.3e}",
        "memory_s": f"{t['memory_s']:.3e}",
        "collective_s": f"{t['collective_s']:.3e}",
        "dominant": t["dominant"].replace("_s", ""),
        "GiB/dev": f"{r['memory']['per_device_total']/2**30:.1f}",
        "useful%": f"{100*t['useful_flops_ratio']:.1f}",
        "roofline%": f"{100*t['roofline_fraction']:.2f}",
        "note": "",
    }


def table(mesh: str = "16x16", art_dir: Optional[str] = None) -> str:
    rows = [_fmt_row(r) for r in load_records(mesh, art_dir)]
    rows = [r for r in rows if r]
    if not rows:
        return f"(no artifacts for mesh {mesh} — run repro.launch.dryrun)"
    cols = list(rows[0].keys())
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join(["---"] * len(cols)) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(r[c]) for c in cols) + " |")
    return "\n".join(out)


def main():
    print("\n## Roofline baseline — single-pod 16×16 (terms in s/step, "
          "per-chip)")
    print(table("16x16"))
    recs = [r for r in load_records("16x16") if r.get("ok")]
    if recs:
        worst = min(recs, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(recs,
                   key=lambda r: r["roofline"]["collective_s"]
                   / max(r["roofline"]["bound_step_s"], 1e-30))
        print(f"\nworst roofline fraction: {worst['arch']}×{worst['shape']} "
              f"({100*worst['roofline']['roofline_fraction']:.2f}%)")
        print(f"most collective-heavy: {coll['arch']}×{coll['shape']}")
    return recs


if __name__ == "__main__":
    main()
