"""Plot the fig-4 benchmark JSON into the paper-reproduction figures.

Consumes the JSON written by ``python -m benchmarks.fig4_throughput
--json-out fig4.json`` (or the ``fig4`` section of ``benchmarks.run
--json-out``) and renders:

* **fig4b_batch.png**   — batched-engine ops/s vs explicit batch size
  (``batch_sweep``), read and write series;
* **fig4c_window.png**  — background-flusher ops/s over the window_ms
  grid (``window_sweep``), one panel per op, one series per node count;
* **fig4d_hedge.png**   — straggler-topology latency percentiles,
  unhedged vs hedged (``hedge_sweep``);
* **fig4f_parallel.png** — serial vs parallel pump ops/s
  (``parallel_sweep``), when that sweep is present.

matplotlib is an OPTIONAL dependency: without it the script says what it
would have plotted and exits 0 — benchmark JSON is the source of truth and
stays usable headless (the tables the benchmarks print are the same data).

    PYTHONPATH=src python -m benchmarks.fig4_throughput --json-out fig4.json
    PYTHONPATH=src python -m benchmarks.plot fig4.json --out-dir artifacts/plots
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# fixed categorical order (validated palette: see docs) — color follows the
# entity (read/write, node count, hedged-ness), never its position in a run
C1, C2, C3 = "#2a78d6", "#eb6834", "#1baf7a"     # blue / orange / aqua
INK, INK2, GRID = "#0b0b0b", "#52514e", "#e4e3df"
SURFACE = "#fcfcfb"


def _load_matplotlib():
    try:
        import matplotlib
        matplotlib.use("Agg")               # headless benchmark hosts
        import matplotlib.pyplot as plt
        return plt
    except ImportError:
        return None


def _style(ax, title, xlabel, ylabel):
    ax.set_facecolor(SURFACE)
    ax.set_title(title, color=INK, fontsize=11, loc="left")
    ax.set_xlabel(xlabel, color=INK2, fontsize=9)
    ax.set_ylabel(ylabel, color=INK2, fontsize=9)
    ax.grid(True, color=GRID, linewidth=0.6)
    ax.set_axisbelow(True)
    for side in ("top", "right"):
        ax.spines[side].set_visible(False)
    for side in ("left", "bottom"):
        ax.spines[side].set_color(GRID)
    ax.tick_params(colors=INK2, labelsize=8)


def plot_batch_sweep(plt, rows, path):
    fig, ax = plt.subplots(figsize=(5.2, 3.4), dpi=150)
    fig.patch.set_facecolor(SURFACE)
    for op, color in (("read", C1), ("write", C2)):
        pts = sorted((r["batch"], r["ops_per_s"])
                     for r in rows if r["op"] == op)
        if not pts:
            continue
        ax.plot([p[0] for p in pts], [p[1] for p in pts], color=color,
                linewidth=2, marker="o", markersize=5, label=op)
    ax.set_xscale("log", base=2)
    ax.set_yscale("log")
    _style(ax, "Fig 4b — batched invocation engine throughput",
           "batch size (requests per dispatch)", "ops/s (wall clock)")
    ax.legend(frameon=False, fontsize=8, labelcolor=INK)
    fig.tight_layout()
    fig.savefig(path, facecolor=SURFACE)
    plt.close(fig)


def plot_window_sweep(plt, rows, path):
    ops = [op for op in ("read", "write")
           if any(r["op"] == op for r in rows)]
    fig, axes = plt.subplots(1, max(1, len(ops)), figsize=(8.2, 3.4),
                             dpi=150, sharey=True, squeeze=False)
    fig.patch.set_facecolor(SURFACE)
    node_counts = sorted({r["nodes"] for r in rows})
    colors = {n: c for n, c in zip(node_counts, (C1, C2, C3))}
    for ax, op in zip(axes[0], ops):
        for n in node_counts:
            pts = sorted((r["window_ms"], r["ops_per_s"]) for r in rows
                         if r["op"] == op and r["nodes"] == n)
            if not pts:
                continue
            ax.plot([p[0] for p in pts], [p[1] for p in pts],
                    color=colors[n], linewidth=2, marker="o", markersize=5,
                    label=f"{n} node{'s' if n > 1 else ''}")
        ax.set_xscale("log", base=2)
        _style(ax, f"Fig 4c — background flusher ({op})",
               "window (ms, virtual)", "ops/s (wall clock)" if op == ops[0]
               else "")
        ax.legend(frameon=False, fontsize=8, labelcolor=INK)
    fig.tight_layout()
    fig.savefig(path, facecolor=SURFACE)
    plt.close(fig)


def plot_hedge_sweep(plt, rows, path):
    fig, ax = plt.subplots(figsize=(5.2, 3.4), dpi=150)
    fig.patch.set_facecolor(SURFACE)
    pcts = ["p50_ms", "p90_ms", "p99_ms"]
    xs = range(len(pcts))
    width = 0.38
    for off, (hedged, color, label) in enumerate(
            ((False, C1, "unhedged"), (True, C2, "hedged"))):
        row = next((r for r in rows if r["hedged"] == hedged), None)
        if row is None:
            continue
        vals = [row[p] for p in pcts]
        bars = ax.bar([x + (off - 0.5) * (width + 0.04) for x in xs], vals,
                      width=width, color=color, label=label, zorder=2)
        for b, v in zip(bars, vals):        # direct labels: few bars
            ax.text(b.get_x() + b.get_width() / 2, v, f"{v:.0f}",
                    ha="center", va="bottom", fontsize=7, color=INK2)
    ax.set_xticks(list(xs), [p.replace("_ms", "") for p in pcts])
    _style(ax, "Fig 4d — windowed hedge on the straggler topology",
           "latency percentile", "latency (ms, virtual)")
    ax.legend(frameon=False, fontsize=8, labelcolor=INK)
    fig.tight_layout()
    fig.savefig(path, facecolor=SURFACE)
    plt.close(fig)


def plot_straggler_sweep(plt, rows, path):
    fig, ax = plt.subplots(figsize=(5.2, 3.4), dpi=150)
    fig.patch.set_facecolor(SURFACE)
    metrics = ["fast_p50_ms", "fast_p99_ms", "slow_p99_ms"]
    xs = range(len(metrics))
    width = 0.38
    for off, (barrier, color, label) in enumerate(
            ((True, C1, "wave barrier"), (False, C2, "per-frame dataflow"))):
        row = next((r for r in rows if r["wave_barrier"] == barrier), None)
        if row is None:
            continue
        vals = [row[m] for m in metrics]
        bars = ax.bar([x + (off - 0.5) * (width + 0.04) for x in xs], vals,
                      width=width, color=color, label=label, zorder=2)
        for b, v in zip(bars, vals):        # direct labels: few bars
            ax.text(b.get_x() + b.get_width() / 2, v, f"{v:.1f}",
                    ha="center", va="bottom", fontsize=7, color=INK2)
    ax.set_xticks(list(xs),
                  [m.replace("_ms", "").replace("_", " ") for m in metrics])
    _style(ax, "Fig 4g — frame completion vs a straggling store node",
           "node class / percentile", "latency (ms, wall clock)")
    ax.legend(frameon=False, fontsize=8, labelcolor=INK)
    fig.tight_layout()
    fig.savefig(path, facecolor=SURFACE)
    plt.close(fig)


def plot_parallel_sweep(plt, rows, path):
    rows = [r for r in rows if "ops_per_s" in r]    # determinism-check
    fig, ax = plt.subplots(figsize=(5.6, 3.4), dpi=150)   # rows carry none
    fig.patch.set_facecolor(SURFACE)
    cases = sorted({(r["kind"], r["op"]) for r in rows})
    workers = sorted({r["workers"] for r in rows})
    width = 0.8 / max(1, len(workers))
    colors = {w: c for w, c in zip(workers, (C1, C2, C3))}
    for wi, w in enumerate(workers):
        vals = []
        for kind, op in cases:
            row = next((r for r in rows if r["kind"] == kind
                        and r["op"] == op and r["workers"] == w), None)
            vals.append(row["ops_per_s"] if row else 0.0)
        ax.bar([i + (wi - (len(workers) - 1) / 2) * (width + 0.02)
                for i in range(len(cases))], vals, width=width,
               color=colors[w], label=f"workers={w}", zorder=2)
    ax.set_xticks(range(len(cases)),
                  [f"{kind}\n{op}" for kind, op in cases])
    _style(ax, "Fig 4f — serial vs parallel dispatch pipeline",
           "workload", "ops/s (wall clock)")
    ax.legend(frameon=False, fontsize=8, labelcolor=INK)
    fig.tight_layout()
    fig.savefig(path, facecolor=SURFACE)
    plt.close(fig)


PLOTS = (
    ("batch_sweep", plot_batch_sweep, "fig4b_batch.png"),
    ("window_sweep", plot_window_sweep, "fig4c_window.png"),
    ("hedge_sweep", plot_hedge_sweep, "fig4d_hedge.png"),
    ("parallel_sweep", plot_parallel_sweep, "fig4f_parallel.png"),
    ("straggler_sweep", plot_straggler_sweep, "fig4g_straggler.png"),
)


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.plot", description=__doc__)
    ap.add_argument("json_in", help="fig4 benchmark JSON (or a run.py "
                    "--json-out file with a fig4 section)")
    ap.add_argument("--out-dir", default="artifacts/plots")
    args = ap.parse_args(argv)

    with open(args.json_in) as f:
        data = json.load(f)
    if "fig4" in data:                      # a benchmarks.run JSON
        data = data["fig4"]

    plt = _load_matplotlib()
    available = [(k, fn, name) for k, fn, name in PLOTS if data.get(k)]
    if not available:
        print("no plottable sweeps in the JSON (expected one of: "
              + ", ".join(k for k, _, _ in PLOTS) + ")")
        return 1
    if plt is None:
        print("matplotlib not installed — would have plotted: "
              + ", ".join(name for _, _, name in available)
              + " (the benchmark JSON/tables carry the same data)")
        return 0
    os.makedirs(args.out_dir, exist_ok=True)
    for key, fn, name in available:
        path = os.path.join(args.out_dir, name)
        fn(plt, data[key], path)
        print(f"wrote {path} ({len(data[key])} rows from {key})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
