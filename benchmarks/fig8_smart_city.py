"""Fig 8 reproduction: the 8-function BeFaaS smart-city app, data store at
the edge (Enoki) vs in the cloud.

Client: 5 rps for the scaled duration, endpoint mix 45% traffic filter /
45% object recognition / 10% weather filter; filters pass 50% of events.
Expected (paper §5): weather endpoint unaffected by store placement
(no sync stateful call in its chain, bimodal by filtering); traffic and
object endpoints pay the store round-trips through movement_plan when the
store is in the cloud.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import latency_stats, paper_cluster, print_table
from repro.configs.base import ReplicationPolicy

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "examples"))
from smart_city_app import deploy_app  # noqa: E402


MIX = [("traffic_sensor_filter", 0.45), ("object_recognition", 0.45),
       ("weather_sensor_filter", 0.10)]


def run(rps: float = 5.0, duration_s: float = 60.0, repeats: int = 3,
        seed: int = 0):
    rows = []
    rng = np.random.default_rng(seed)
    n = int(rps * duration_s)
    for policy, label in [(ReplicationPolicy.REPLICATED, "edge (Enoki)"),
                          (ReplicationPolicy.CLOUD_CENTRAL, "cloud store")]:
        for rep in range(repeats):
            c = paper_cluster(measure_compute=(rep == 0))
            deploy_app(c, policy)
            per_endpoint = {name: [] for name, _ in MIX}
            for i in range(n):
                t = i * (1000.0 / rps)
                u = rng.random()
                name = ("traffic_sensor_filter" if u < 0.45 else
                        "object_recognition" if u < 0.9 else
                        "weather_sensor_filter")
                x = jnp.asarray([rng.random() * 2 - 1.0, 0.0])  # 50% filtered
                res = c.invoke(name, "edge", x, t_send=t)
                per_endpoint[name].append(res)
            for name, results in per_endpoint.items():
                if results:
                    rows.append({"store": label, "repeat": rep,
                                 **latency_stats(results, name)})
    return rows


def main():
    rows = run()
    print_table(rows, "Fig 8 — smart-city request-response latency (ms)")
    for name, _ in MIX:
        edge = [r["p50"] for r in rows
                if r["name"] == name and "edge" in r["store"]]
        cloud = [r["p50"] for r in rows
                 if r["name"] == name and "cloud" in r["store"]]
        if edge and cloud:
            print(f"{name:24s} p50 edge={np.mean(edge):7.1f}ms "
                  f"cloud={np.mean(cloud):7.1f}ms "
                  f"delta={np.mean(cloud)-np.mean(edge):7.1f}ms")
    print("\npaper: weather unaffected (async/stateless chain); traffic & "
          "object chains pay store RTTs via movement_plan")
    return rows


if __name__ == "__main__":
    main()
