"""Fig 4 reproduction: read/write throughput — item-size sweep AND the
batched-invocation sweep.

The paper drives a closed workload (100 client threads, 2 min) against a
read function and a write function with item sizes 1 B … 1 MB.  Two views:

1. **Size sweep** (the paper's figure): per-op local store cost is MEASURED
   (real jitted arena ops on this host); closed-loop throughput then follows
   Little's law with the network model:

       latency(size)   = client_rtt + per-op network (placement) + compute
       tasks/s         = threads / latency,     capped by link bandwidth
       MB/s            = tasks/s × size

   Expected shapes (paper §4.2): cloud reads saturate the 12.5 MB/s
   (100 Mb/s) edge-cloud link for items ≳100 kB; edge reads keep scaling.

2. **Batch sweep** (this repo's §4.2 hot-path work): wall-clock ops/s of a
   REAL Enoki node serving stateful get/set functions, sweeping the batched
   invocation engine over batch sizes {1, 8, 64, 256}.  batch=1 is the
   sequential ``Cluster.invoke`` baseline (one Python round-trip + one
   device dispatch per request); larger batches go through
   ``Cluster.invoke_batch`` (one dispatch per batch).  The speedup is pure
   per-invocation overhead removed — exactly the bottleneck the batching
   engine targets.

3. **Window sweep** (the background-flusher model, §4.2 × §4.3): instead of
   handing the engine pre-formed batches, clients ``submit`` a fixed
   arrival-rate stream and the engine's arrival-time windows coalesce it —
   window_ms × node-count grid.  Batch size is EMERGENT (≈ rate ×
   window_ms per node) and a multi-node run drains all nodes' windows in
   one flush cycle (cross-node fan-out, parallel timelines).  The check the
   acceptance pins: a 2-node windowed run at a 64-deep window sustains at
   least the single-node batch-64 ops/s of the explicit batch sweep.

4. **Hedge sweep** (PR 3): open-loop read arrivals against a STRAGGLER
   topology (the nearest replica serves slowly), windowed hedging off vs
   on, driven pump-by-deadline through the router's batched path.  The
   acceptance check: hedged p99 <= unhedged p99, plus hedge counters.

5. **Serving sweep** (PR 3): the REAL wall-clock serving loop
   (``launch/faas_server.py``), open-loop (fixed wall arrival rate) and
   closed-loop (client threads re-submitting on completion) — virtual
   latency percentiles and wall ops/s.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import interleaved_repeats, median_ops
from repro.core import Cluster, enoki_function, get_function
from repro.core.network import paper_topology
from repro.core.store import kv_get, kv_set, store_new
from repro.core.versioning import MAX_NODES, fnv1a

SIZES = [1, 100, 1_000, 10_000, 100_000, 1_000_000]
THREADS = 100
BATCH_SIZES = [1, 8, 64, 256]
BATCH_ITEM_WIDTH = 64          # float32 payload width for the batch sweep
BATCH_REQUESTS = 512


# ---------------------------------------------------------------------------
# The batch-sweep workload functions (real stateful handlers)
# ---------------------------------------------------------------------------

@enoki_function(name="fig4_read", keygroups=["fig4kg"],
                codec_width=BATCH_ITEM_WIDTH)
def fig4_read(kv, x):
    val, found = kv.get("item")
    return val[:1]


@enoki_function(name="fig4_write", keygroups=["fig4kg"],
                codec_width=BATCH_ITEM_WIDTH)
def fig4_write(kv, x):
    cur, _ = kv.get("item")
    kv.set("item", cur + x)
    return x[:1]


def _measure_local_op_ms(size: int, op: str) -> float:
    """Median wall time of a jitted arena get/set at this payload size."""
    width = max(1, size)
    store = store_new(4, width, MAX_NODES, dtype=jnp.uint8)
    h = fnv1a("k")
    row = jnp.zeros((width,), jnp.uint8)
    clock = jnp.zeros((), jnp.int32)

    if op == "set":
        fn = jax.jit(lambda s, c: kv_set(s, h, row, width, c, 0))
        out = fn(store, clock)
        jax.block_until_ready(out[0])
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            out = fn(store, clock)
            jax.block_until_ready(out[0])
            ts.append((time.perf_counter() - t0) * 1e3)
        return float(np.median(ts))
    store, clock, _ = kv_set(store, h, row, width, clock, 0)
    fn = jax.jit(lambda s: kv_get(s, h))
    out = fn(store)
    jax.block_until_ready(out[0])
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        out = fn(store)
        jax.block_until_ready(out[0])
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def run_size_sweep():
    net = paper_topology()
    rows = []
    for op in ("read", "write"):
        for size in SIZES:
            local_ms = _measure_local_op_ms(size, "get" if op == "read"
                                            else "set")
            for placement in ("edge", "cloud"):
                lan = net.link("client", "edge")
                # client->edge function invocation (tiny request payload)
                lat = lan.rtt_ms + lan.transfer_ms(64)
                if placement == "cloud":
                    link = net.link("edge", "cloud")
                    lat += link.rtt_ms + link.transfer_ms(size)
                    cap_mbs = link.bandwidth_mbps / 8.0
                else:
                    cap_mbs = float("inf")
                lat += local_ms
                tps = THREADS / (lat / 1e3)
                mbs = tps * size / 1e6
                if mbs > cap_mbs:          # link saturation (fig 4a ceiling)
                    mbs = cap_mbs
                    tps = mbs * 1e6 / size
                rows.append({"op": op, "size_B": size, "store": placement,
                             "latency_ms": round(lat, 2),
                             "tasks_per_s": round(tps, 1),
                             "MB_per_s": round(mbs, 2)})
    return rows


# ---------------------------------------------------------------------------
# Batch sweep: the batched invocation engine on a real node
# ---------------------------------------------------------------------------

def _drive(cluster: Cluster, fn_name: str, batch: int,
           n_requests: int) -> float:
    """Wall-clock ops/s for ``n_requests`` invocations at ``batch`` size
    (batch 1 = the sequential invoke path), blocking until the store state
    is actually materialised."""
    x = np.ones((BATCH_ITEM_WIDTH,), np.float32)
    xs = [x] * max(batch, 1)

    def block():
        jax.block_until_ready(cluster.nodes["edge"].stores["fig4kg"])

    # warm the jit caches for every bucket the timed loop will hit
    # (including the ragged tail's smaller bucket)
    if batch == 1:
        cluster.invoke(fn_name, "edge", x)
    else:
        cluster.invoke_batch(fn_name, "edge", xs)
        tail = n_requests % batch
        if tail:
            cluster.invoke_batch(fn_name, "edge", xs[:tail])
    block()

    # every path must MATERIALISE its responses (a serving node replies with
    # bytes, not a lazy device array); invoke_batch already does internally
    t0 = time.perf_counter()
    if batch == 1:
        for i in range(n_requests):
            r = cluster.invoke(fn_name, "edge", x, t_send=float(i))
            np.asarray(r.output)
    else:
        for lo in range(0, n_requests, batch):
            bs = min(batch, n_requests - lo)   # ragged tail: no extra ops
            cluster.invoke_batch(fn_name, "edge", xs[:bs],
                                 t_sends=[float(lo + j)
                                          for j in range(bs)])
    block()
    return n_requests / (time.perf_counter() - t0)


def run_batch_sweep(batch_sizes=tuple(BATCH_SIZES),
                    n_requests: int = BATCH_REQUESTS):
    cluster = Cluster({"edge": "edge", "cloud": "cloud"},
                      net=paper_topology(), measure_compute=False)
    cluster.deploy(get_function("fig4_read"), ["edge"])
    cluster.deploy(get_function("fig4_write"), ["edge"])
    batch_sizes = sorted(set(batch_sizes))   # baseline = smallest batch
    rows = []
    for op, fn_name in (("read", "fig4_read"), ("write", "fig4_write")):
        base = None
        for b in batch_sizes:
            ops_s = _drive(cluster, fn_name, b, n_requests)
            if base is None:
                base = ops_s
            rows.append({"op": op, "batch": b,
                         "ops_per_s": round(ops_s, 1),
                         "base_batch": batch_sizes[0],
                         "speedup_vs_base": round(ops_s / base, 2)})
    return rows


# ---------------------------------------------------------------------------
# Window sweep: the async background flusher across nodes
# ---------------------------------------------------------------------------

WINDOW_SIZES_MS = [4.0, 16.0, 64.0, 256.0]   # at 1 req/ms/node:
                                             # batches of ~4/16/64/256
WINDOW_NODE_COUNTS = [1, 2]
WINDOW_RATE_PER_MS = 1.0                # arrival rate per node


def _drive_windowed(cluster: Cluster, fn_name: str, nodes, window_ms: float,
                    n_requests: int, rate_per_ms: float) -> dict:
    """Submit a fixed-rate arrival stream round-robin across ``nodes`` and
    let the engine's arrival-time windows form the batches; one pump drains
    every window (multi-node windows of a cycle fan out in parallel
    timelines).  Returns wall-clock ops/s plus the emergent batch shape."""
    from repro.core.engine import BatchedInvocationEngine
    x = np.ones((BATCH_ITEM_WIDTH,), np.float32)

    def block():
        for nd in nodes:
            jax.block_until_ready(cluster.nodes[nd].stores["fig4kg"])

    cluster.flush_replication()
    block()
    cluster.engine = BatchedInvocationEngine(cluster, window_ms=window_ms)
    eng = cluster.engine
    spacing = 1.0 / (rate_per_ms * len(nodes))   # global inter-arrival (ms)
    t0 = time.perf_counter()
    for i in range(n_requests):
        eng.submit(fn_name, nodes[i % len(nodes)], x, t_send=i * spacing)
    out = eng.pump()
    block()
    elapsed = time.perf_counter() - t0
    assert len(out) == n_requests
    st = eng.stats
    return {"ops_per_s": n_requests / elapsed,
            "windows": st.windows_flushed,
            "avg_batch": round(n_requests / max(1, st.windows_flushed), 1),
            "dispatches": st.dispatches}


def run_window_sweep(window_sizes=tuple(WINDOW_SIZES_MS),
                     node_counts=tuple(WINDOW_NODE_COUNTS),
                     n_requests: int = BATCH_REQUESTS,
                     rate_per_ms: float = WINDOW_RATE_PER_MS):
    rows = []
    for nodes_n in node_counts:
        cluster = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                          net=paper_topology(), measure_compute=False)
        nodes = ["edge", "edge2"][:nodes_n]
        cluster.deploy(get_function("fig4_read"), nodes)
        cluster.deploy(get_function("fig4_write"), nodes)
        # warm every bucket the emergent window sizes can land in, per node
        # (jit caches live on the deployed handlers, so this is once per
        # cluster, outside the timed loops)
        x = np.ones((BATCH_ITEM_WIDTH,), np.float32)
        from repro.core.engine import DEFAULT_BUCKETS
        for fn_name in ("fig4_read", "fig4_write"):
            for nd in nodes:
                for b in DEFAULT_BUCKETS:
                    cluster.invoke_batch(fn_name, nd, [x] * b)
        for op, fn_name in (("read", "fig4_read"), ("write", "fig4_write")):
            for w in window_sizes:
                m = _drive_windowed(cluster, fn_name, nodes, w, n_requests,
                                    rate_per_ms)
                rows.append({"op": op, "window_ms": w, "nodes": nodes_n,
                             "ops_per_s": round(m["ops_per_s"], 1),
                             "windows": m["windows"],
                             "avg_batch": m["avg_batch"],
                             "dispatches": m["dispatches"]})
    return rows


# ---------------------------------------------------------------------------
# Hedge sweep: windowed hedging on the straggler topology (batched path)
# ---------------------------------------------------------------------------

HEDGE_REQUESTS = 128
HEDGE_WINDOW_MS = 16.0
HEDGE_AFTER_MS = 4.0
HEDGE_STRAGGLER_MS = 60.0       # compute charge at the overloaded nearest
                                # replica (edge); edge2 stays fast
HEDGE_RATE_PER_MS = 0.25        # open-loop arrivals: one every 4 virtual ms


def _seed_and_warm(cluster: Cluster, nodes):
    """Seed the read item and warm every jit bucket outside timed regions."""
    from repro.core.engine import DEFAULT_BUCKETS
    x = np.ones((BATCH_ITEM_WIDTH,), np.float32)
    for nd in nodes:
        cluster.invoke("fig4_write", nd, x)
        for b in DEFAULT_BUCKETS:
            cluster.invoke_batch("fig4_read", nd, [x] * b)
    cluster.flush_replication()
    return x


def run_hedge_sweep(n_requests: int = HEDGE_REQUESTS,
                    window_ms: float = HEDGE_WINDOW_MS,
                    hedge_after_ms: float = HEDGE_AFTER_MS,
                    straggler_ms: float = HEDGE_STRAGGLER_MS,
                    rate_per_ms: float = HEDGE_RATE_PER_MS):
    """Open-loop read arrivals against a STRAGGLER topology: the nearest
    replica (edge) is overloaded (``straggler_ms`` of compute per request)
    while the second-nearest (edge2) is fast.  Two identical runs through
    the router's batched path — windowed hedging off vs on — driven pump-
    by-deadline exactly like the wall-clock serving loop.  The acceptance
    check: hedged p99 <= unhedged p99."""
    import math as _math
    from repro.core import Router, percentiles
    rows = []
    for hedged in (False, True):
        cluster = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                          net=paper_topology(), measure_compute=False)
        cluster.deploy(get_function("fig4_read"), ["edge", "edge2"])
        cluster.deploy(get_function("fig4_write"), ["edge", "edge2"])
        x = _seed_and_warm(cluster, ["edge", "edge2"])
        cluster.set_compute_ms("edge", "fig4_read", straggler_ms)
        cluster.engine.configure(window_ms=window_ms)
        router = Router(cluster,
                        hedge_after_ms=hedge_after_ms if hedged else None)
        for i in range(n_requests):
            router.submit("fig4_read", x, t_send=i / rate_per_ms)
        out = {}
        while len(out) < n_requests:
            nd = router.next_deadline()
            if nd is None:
                out.update(router.pump(_math.inf))
                break
            out.update(router.pump(nd))
        # hedge winners come re-stamped against the primary's send instant,
        # so response_ms is the client-observed latency for every ticket
        pct = percentiles([r.response_ms for r in out.values()])
        rows.append({"hedged": hedged, "window_ms": window_ms,
                     "hedge_after_ms": hedge_after_ms if hedged else None,
                     "straggler_ms": straggler_ms,
                     "p50_ms": round(pct[50], 2), "p90_ms": round(pct[90], 2),
                     "p99_ms": round(pct[99], 2),
                     "hedges_fired": router.stats.hedges_fired,
                     "hedge_wins": router.stats.hedge_wins})
    return rows


# ---------------------------------------------------------------------------
# Straggler sweep: per-frame dataflow scheduler vs the wave barrier (PR 7)
# ---------------------------------------------------------------------------

STRAGGLER_NODES = ["edge", "edge2", "edge3"]
STRAGGLER_ROUNDS = 16
STRAGGLER_PER_NODE = 4
STRAGGLER_SLEEP_MS = 25.0       # WALL-clock stall injected at edge3's
                                # batched handler (set_compute_ms only
                                # charges virtual time — useless here)


@enoki_function(name="fig4_dfs", keygroups=[], codec_width=BATCH_ITEM_WIDTH)
def fig4_dfs(kv, x):
    """Stateless leaf: its store key is the serving node itself, so the
    three nodes' windows ride three independent dispatch lanes."""
    return x[:1]


def run_straggler_sweep(rounds: int = STRAGGLER_ROUNDS,
                        per_node: int = STRAGGLER_PER_NODE,
                        sleep_ms: float = STRAGGLER_SLEEP_MS):
    """WALL-clock frame-completion latency on a 3-store-node topology where
    ONE store node (edge3) is wall-clock slow, wave barrier on vs off.

    Each round submits ``per_node`` requests per node (one window per
    lane) and pumps one flush cycle; a frame's completion instant is its
    ``on_ready`` stamp (dataflow run) or the pump return (barrier run,
    where nothing streams).  With the barrier the fast nodes' frames all
    wait for edge3's sleep; with the per-frame scheduler they deliver as
    soon as their own lane finishes.  The acceptance check: fast-node p99
    improves >= 1.5x with the barrier retired."""
    from repro.core import percentiles
    rows = []
    for barrier in (True, False):
        cluster = Cluster({n: "edge" for n in STRAGGLER_NODES},
                          measure_compute=False)
        cluster.deploy(get_function("fig4_dfs"), STRAGGLER_NODES)
        x = np.ones((BATCH_ITEM_WIDTH,), np.float32)
        for nd in STRAGGLER_NODES:      # warm each lane's jit bucket
            cluster.invoke_batch("fig4_dfs", nd, [x] * per_node)
        eng = cluster.engine
        eng.configure(window_ms=4.0)
        eng.use_workers(4)
        eng.min_parallel_requests = 1
        eng.wave_barrier = barrier
        node_obj = cluster.nodes["edge3"]
        orig = node_obj.batched_handlers["fig4_dfs"]

        def slow(*a, __orig=orig, **kw):
            time.sleep(sleep_ms / 1e3)
            return __orig(*a, **kw)

        node_obj.batched_handlers["fig4_dfs"] = slow
        stamps = {}
        eng.on_ready = lambda res: stamps.update(
            dict.fromkeys(res, time.perf_counter()))
        fast_ms, slow_ms = [], []
        for r in range(rounds):
            base = float(r) * 1_000.0   # one virtual second per round
            tks = {n: [eng.submit("fig4_dfs", n, x, t_send=base + float(i))
                       for i in range(per_node)] for n in STRAGGLER_NODES}
            t0 = time.perf_counter()
            out = eng.pump(base + 999.0)
            t_end = time.perf_counter()
            for n, tickets in tks.items():
                bucket = slow_ms if n == "edge3" else fast_ms
                for t in tickets:
                    assert (t in out) != (t in stamps), (barrier, n)
                    bucket.append((stamps.get(t, t_end) - t0) * 1e3)
        pf, ps = percentiles(fast_ms), percentiles(slow_ms)
        rows.append({"wave_barrier": barrier, "sleep_ms": sleep_ms,
                     "rounds": rounds, "per_node": per_node,
                     "fast_p50_ms": round(pf[50], 2),
                     "fast_p99_ms": round(pf[99], 2),
                     "slow_p99_ms": round(ps[99], 2)})
    rows[1]["p99_improvement_x"] = round(
        rows[0]["fast_p99_ms"] / max(rows[1]["fast_p99_ms"], 1e-9), 2)
    return rows


# ---------------------------------------------------------------------------
# Parallel-pump sweep: the executor-per-store-node dispatch pipeline
# ---------------------------------------------------------------------------

PARALLEL_WORKERS = [1, 4]
PARALLEL_WINDOW_MS = 32.0       # at 2 req/ms split over 2 nodes: 64-deep
                                # windows, bucket-exact
PARALLEL_REQUESTS = 512
PAR_ITEM_WIDTH = 1024           # wide enough that a dispatch is real XLA
                                # work (the pipeline overlaps compute, not
                                # Python bookkeeping)


@enoki_function(name="fig4_par_read", keygroups=["fig4parkg"],
                codec_width=PAR_ITEM_WIDTH)
def fig4_par_read(kv, x):
    val, found = kv.get("item")
    return val[:1] + x[:1]


@enoki_function(name="fig4_par_write", keygroups=["fig4parkg"],
                codec_width=PAR_ITEM_WIDTH)
def fig4_par_write(kv, x):
    cur, _ = kv.get("item")
    kv.set("item", cur + x)
    return x[:1]


def run_parallel_sweep(window_ms: float = PARALLEL_WINDOW_MS,
                       workers=tuple(PARALLEL_WORKERS),
                       n_requests: int = PARALLEL_REQUESTS,
                       rate_per_ms: float = 2.0):
    """Serial vs parallel dispatch pipeline on a 2-STORE-NODE topology,
    measured in ONE process so every row shares the same host load (this
    host's run-to-run noise swamps cross-process comparisons):

    * ``kind=pump`` — a fixed-rate arrival stream round-robin over both
      store nodes, drained cycle-by-cycle, engine ``workers`` 1 vs N.
      For the read op the rows also record ``matches_serial``: the
      parallel pump must return the IDENTICAL ticket→result map as the
      serial one (the determinism contract).
    * ``kind=serve`` — the wall-clock serving loop, closed loop with 8
      client threads split between a read function served at ``edge`` and
      a write function served at ``edge2`` (two store nodes per flush
      cycle), ``FaasServer(workers=...)`` 1 vs N.  The acceptance check
      is the parallel row sustaining >= the serial row's ops/s.
    """
    import threading as _threading
    from repro.core import percentiles
    from repro.core.engine import BatchedInvocationEngine
    from repro.launch.faas_server import FaasServer
    cluster = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                      net=paper_topology(), measure_compute=False)
    nodes = ["edge", "edge2"]
    # read served at edge, write at edge2: every flush cycle spans two
    # store nodes (the replicated keygroup lives at both)
    cluster.deploy(get_function("fig4_par_read"), ["edge", "edge2"])
    cluster.deploy(get_function("fig4_par_write"), ["edge2"])
    x = np.ones((PAR_ITEM_WIDTH,), np.float32)
    for fn_name, nd in (("fig4_par_read", "edge"),
                        ("fig4_par_read", "edge2"),
                        ("fig4_par_write", "edge2")):
        for b in (1, 8, 64, 256):       # warm the buckets the sweep hits
            cluster.invoke_batch(fn_name, nd, [x] * b)
    for i in range(4):                  # warm the merge jit shapes too
        cluster.invoke("fig4_par_write", "edge2", x, t_send=float(i))
    cluster.flush_replication()

    def block():
        for nd in nodes:
            jax.block_until_ready(cluster.nodes[nd].stores["fig4parkg"])

    rows = []
    spacing = 1.0 / (rate_per_ms * len(nodes))   # global inter-arrival (ms)
    stream = [("fig4_par_read", "edge") if i % 2 == 0
              else ("fig4_par_write", "edge2") for i in range(n_requests)]
    # warmup + interleaved repeats + median-of-K (benchmarks.common): the
    # un-recorded warmup round absorbs residual jit/allocator transients,
    # the interleaving makes drifting host load hit serial and parallel
    # equally, and the median shrugs off one descheduled run
    def pump_pass(k):
        def run_once() -> int:
            cluster.flush_replication()
            block()
            eng = BatchedInvocationEngine(cluster, window_ms=window_ms,
                                          workers=k)
            cluster.engine = eng
            for i, (fn_name, nd) in enumerate(stream):
                eng.submit(fn_name, nd, x, t_send=i * spacing)
            out = eng.pump()    # ONE cycle: both store nodes' windows
            block()
            eng.close()
            assert len(out) == n_requests
            return n_requests
        return run_once

    samples = interleaved_repeats({k: pump_pass(k) for k in workers},
                                  repeats=3, warmup=1)
    medians = median_ops(samples)
    for k in workers:
        rows.append({"kind": "pump", "op": "read+write", "workers": k,
                     "window_ms": window_ms,
                     "ops_per_s": round(medians[k], 1),
                     "runs": [round(s, 1) for s in samples[k]]})

    # determinism check on a read-only stream spanning BOTH store nodes
    # (so the workers>1 run actually exercises the pool — a single store
    # key would fall back to the inline path and prove nothing); reads
    # leave no state behind, so both runs see identical stores
    ref_map = None
    for k in workers:
        cluster.flush_replication()
        block()
        eng = BatchedInvocationEngine(cluster, window_ms=window_ms,
                                      workers=k)
        cluster.engine = eng
        for i in range(n_requests):
            eng.submit("fig4_par_read", nodes[i % 2], x,
                       t_send=i * spacing,
                       client=("client", "client2")[i % 2])
        out = eng.pump()
        eng.close()
        m = {t: (np.asarray(r.output).tobytes(), r.t_received,
                 r.t_applied, r.node) for t, r in out.items()}
        if ref_map is None:
            ref_map = m
        else:
            rows.append({"kind": "pump", "op": "read", "workers": k,
                         "window_ms": window_ms,
                         "matches_serial": bool(m == ref_map)})

    # the wall-clock serving loop under the same host load: 32 closed-loop
    # clients, half reading (served at edge), half writing (at edge2) —
    # interleaved repeats and medians, like the pump rows
    serve_clients = 32
    serve_n = min(n_requests, 256)
    serve_p99 = {k: [] for k in workers}

    def serve_pass(k):
        def run_once() -> int:
            cluster.engine = BatchedInvocationEngine(cluster)
            errors = []

            def client(cid, srv):
                fn = ("fig4_par_read", "fig4_par_write")[cid % 2]
                try:
                    for _ in range(serve_n // serve_clients):
                        srv.submit(fn, x).result(timeout=60.0)
                except BaseException as e:
                    errors.append(e)

            with FaasServer(cluster, window_ms=8.0, time_scale=50.0,
                            workers=k) as srv:
                threads = [_threading.Thread(target=client,
                                             args=(cid, srv))
                           for cid in range(serve_clients)]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
            assert not errors, errors[0]
            serve_p99[k].append(percentiles(srv.response_ms)[99])
            cluster.engine.close()
            return srv.stats.served
        return run_once

    # p99 side-channel gathers one extra (warmup) sample per variant; slice
    # the recorded tail so the reported p99 matches the recorded rounds
    serve_samples = interleaved_repeats(
        {k: serve_pass(k) for k in workers}, repeats=3, warmup=1)
    serve_medians = median_ops(serve_samples)
    for k in workers:
        rows.append({"kind": "serve", "op": "read+write", "workers": k,
                     "window_ms": 8.0,
                     "ops_per_s": round(serve_medians[k], 1),
                     "runs": [round(s, 1) for s in serve_samples[k]],
                     "p99_ms": round(float(np.median(serve_p99[k][1:])), 2)})
    return rows


# ---------------------------------------------------------------------------
# Serving sweep: the wall-clock server, open- and closed-loop arrivals
# ---------------------------------------------------------------------------

SERVE_REQUESTS = 128
SERVE_TIME_SCALE = 50.0         # 50 virtual ms per wall ms (compresses the
                                # emulated network for benchmark runtime)


def run_serving_sweep(n_requests: int = SERVE_REQUESTS,
                      window_ms: float = 8.0,
                      time_scale: float = SERVE_TIME_SCALE):
    """Drive the REAL wall-clock serving loop (launch/faas_server.py):
    open-loop (fixed wall arrival rate) and closed-loop (4 client threads,
    next request on completion) — virtual-latency percentiles + wall ops/s."""
    from repro.core import percentiles
    from repro.launch.faas_server import (FaasServer, serve_closed_loop,
                                          serve_open_loop)
    rows = []
    for mode in ("open", "closed"):
        cluster = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                          net=paper_topology(), measure_compute=False)
        cluster.deploy(get_function("fig4_read"), ["edge", "edge2"])
        cluster.deploy(get_function("fig4_write"), ["edge", "edge2"])
        x = _seed_and_warm(cluster, ["edge", "edge2"])
        t0 = time.perf_counter()
        with FaasServer(cluster, window_ms=window_ms,
                        time_scale=time_scale) as srv:
            if mode == "open":
                serve_open_loop(srv, "fig4_read", lambda i: x,
                                n_requests=n_requests, rate_per_ms=1.0)
            else:
                serve_closed_loop(srv, "fig4_read", lambda i: x,
                                  n_requests=n_requests, concurrency=4)
            elapsed = time.perf_counter() - t0
            pct = percentiles(srv.response_ms)
            rows.append({"mode": mode, "window_ms": window_ms,
                         "requests": srv.stats.served,
                         "wall_ops_per_s": round(n_requests / elapsed, 1),
                         "p50_ms": round(pct[50], 2),
                         "p90_ms": round(pct[90], 2),
                         "p99_ms": round(pct[99], 2),
                         "pumps": srv.stats.pumps,
                         "wakeups": srv.stats.wakeups})
    return rows


def run():
    return {"size_sweep": run_size_sweep(),
            "batch_sweep": run_batch_sweep(),
            "window_sweep": run_window_sweep(),
            "hedge_sweep": run_hedge_sweep(),
            "straggler_sweep": run_straggler_sweep(),
            "serving_sweep": run_serving_sweep(),
            "parallel_sweep": run_parallel_sweep()}


def main(json_out: str = None):
    from benchmarks.common import print_table
    results = run()
    print_table(results["size_sweep"],
                "Fig 4 — read/write throughput vs item size")
    ceiling = [r for r in results["size_sweep"]
               if r["op"] == "read" and r["store"] == "cloud"
               and r["size_B"] >= 100_000]
    print(f"\ncloud read ceiling at >=100kB: "
          f"{[r['MB_per_s'] for r in ceiling]} MB/s (paper: 12.5 MB/s)")
    print_table(results["batch_sweep"],
                "Fig 4b — batched invocation engine ops/s vs batch size")
    for op in ("read", "write"):
        by_batch = {r["batch"]: r for r in results["batch_sweep"]
                    if r["op"] == op}
        if 64 in by_batch and 1 in by_batch:
            speedup = (by_batch[64]["ops_per_s"]
                       / by_batch[1]["ops_per_s"])
            print(f"{op}: batch-64 speedup vs batch-1 = {speedup:.1f}x")
    print_table(results["window_sweep"],
                "Fig 4c — background flusher ops/s, window_ms × nodes")
    print_table(results["hedge_sweep"],
                "Fig 4d — windowed hedging on the straggler topology")
    hs = {r["hedged"]: r for r in results["hedge_sweep"]}
    if True in hs and False in hs:
        print(f"read p99 straggler topology: unhedged {hs[False]['p99_ms']} ms"
              f" -> hedged {hs[True]['p99_ms']} ms "
              f"({hs[True]['hedge_wins']}/{hs[True]['hedges_fired']} "
              f"hedges won)")
    print_table(results["straggler_sweep"],
                "Fig 4g — wave barrier vs per-frame dataflow scheduler")
    ss = {r["wave_barrier"]: r for r in results["straggler_sweep"]}
    if True in ss and False in ss:
        print(f"fast-node frame p99 (wall): barrier {ss[True]['fast_p99_ms']}"
              f" ms -> dataflow {ss[False]['fast_p99_ms']} ms "
              f"({ss[False]['p99_improvement_x']}x)")
    print_table(results["serving_sweep"],
                "Fig 4e — wall-clock serving loop (open/closed arrivals)")
    print_table(results["parallel_sweep"],
                "Fig 4f — serial vs parallel dispatch pipeline")
    serve_rows = {r["workers"]: r for r in results["parallel_sweep"]
                  if r["kind"] == "serve"}
    if len(serve_rows) > 1:
        lo, hi = min(serve_rows), max(serve_rows)
        ratio = serve_rows[hi]["ops_per_s"] / serve_rows[lo]["ops_per_s"]
        print(f"serving loop: workers={hi} vs workers={lo} = {ratio:.2f}x "
              f"{'(sustained)' if ratio >= 1.0 else ''}")
    det = [r.get("matches_serial") for r in results["parallel_sweep"]
           if "matches_serial" in r]
    if det:
        print(f"parallel pump determinism vs serial: "
              f"{'OK' if all(det) else 'MISMATCH'}")
    for op in ("read", "write"):
        by_batch = {r["batch"]: r for r in results["batch_sweep"]
                    if r["op"] == op}
        # the documented check is at the 64-deep window (emergent batch 64
        # per node at 1 req/ms/node), apples-to-apples with batch-64
        target_w = 64.0 if 64.0 in WINDOW_SIZES_MS else max(WINDOW_SIZES_MS)
        two_node = [r for r in results["window_sweep"]
                    if r["op"] == op and r["nodes"] == 2
                    and r["window_ms"] == target_w]
        if 64 in by_batch and two_node:
            ratio = two_node[0]["ops_per_s"] / by_batch[64]["ops_per_s"]
            print(f"{op}: 2-node windowed (window {target_w:.0f} ms) vs "
                  f"single-node batch-64 = {ratio:.2f}x "
                  f"{'(sustained)' if ratio >= 1.0 else ''}")
    if json_out:
        with open(json_out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {json_out}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default=None)
    main(ap.parse_args().json_out)
