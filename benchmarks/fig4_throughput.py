"""Fig 4 reproduction: read/write throughput vs data item size, store at
edge vs cloud.

The paper drives a closed workload (100 client threads, 2 min) against a
read function and a write function with item sizes 1 B … 1 MB.  Here the
per-op local store cost is MEASURED (real jitted arena ops on this host);
the closed-loop throughput then follows Little's law with the network model:

    latency(size)   = client_rtt + per-op network (placement) + compute
    tasks/s         = threads / latency,     capped by link bandwidth
    MB/s            = tasks/s × size

Expected shapes (paper §4.2): cloud reads saturate the 12.5 MB/s (100 Mb/s)
edge-cloud link for items ≳100 kB; edge reads keep scaling; writes show the
same ordering with a lower ceiling.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.network import paper_topology
from repro.core.store import kv_get, kv_set, store_new
from repro.core.versioning import MAX_NODES, fnv1a

SIZES = [1, 100, 1_000, 10_000, 100_000, 1_000_000]
THREADS = 100


def _measure_local_op_ms(size: int, op: str) -> float:
    """Median wall time of a jitted arena get/set at this payload size."""
    width = max(1, size)
    store = store_new(4, width, MAX_NODES, dtype=jnp.uint8)
    h = fnv1a("k")
    row = jnp.zeros((width,), jnp.uint8)
    clock = jnp.zeros((), jnp.int32)

    if op == "set":
        fn = jax.jit(lambda s, c: kv_set(s, h, row, width, c, 0))
        out = fn(store, clock)
        jax.block_until_ready(out[0])
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            out = fn(store, clock)
            jax.block_until_ready(out[0])
            ts.append((time.perf_counter() - t0) * 1e3)
        return float(np.median(ts))
    store, clock, _ = kv_set(store, h, row, width, clock, 0)
    fn = jax.jit(lambda s: kv_get(s, h))
    out = fn(store)
    jax.block_until_ready(out[0])
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        out = fn(store)
        jax.block_until_ready(out[0])
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def run():
    net = paper_topology()
    rows = []
    for op in ("read", "write"):
        for size in SIZES:
            local_ms = _measure_local_op_ms(size, "get" if op == "read"
                                            else "set")
            for placement in ("edge", "cloud"):
                lan = net.link("client", "edge")
                # client->edge function invocation (tiny request payload)
                lat = lan.rtt_ms + lan.transfer_ms(64)
                if placement == "cloud":
                    link = net.link("edge", "cloud")
                    lat += link.rtt_ms + link.transfer_ms(size)
                    cap_mbs = link.bandwidth_mbps / 8.0
                else:
                    cap_mbs = float("inf")
                lat += local_ms
                tps = THREADS / (lat / 1e3)
                mbs = tps * size / 1e6
                if mbs > cap_mbs:          # link saturation (fig 4a ceiling)
                    mbs = cap_mbs
                    tps = mbs * 1e6 / size
                rows.append({"op": op, "size_B": size, "store": placement,
                             "latency_ms": round(lat, 2),
                             "tasks_per_s": round(tps, 1),
                             "MB_per_s": round(mbs, 2)})
    return rows


def main():
    from benchmarks.common import print_table
    rows = run()
    print_table(rows, "Fig 4 — read/write throughput vs item size")
    ceiling = [r for r in rows if r["op"] == "read" and r["store"] == "cloud"
               and r["size_B"] >= 100_000]
    print(f"\ncloud read ceiling at >=100kB: "
          f"{[r['MB_per_s'] for r in ceiling]} MB/s (paper: 12.5 MB/s)")
    return rows


if __name__ == "__main__":
    main()
