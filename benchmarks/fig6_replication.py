"""Fig 5/6 reproduction: the three data placements under a two-edge
write/read workload — read latency, write latency, and data STALENESS.

Setup (paper §4.3): two edge nodes 20 ms / 100 Mb/s apart; the client
updates a value through the function on edge, reads it through edge2, ten
requests per second.  Placements:

  cloud_central  one store in the cloud — both ops pay 50 ms RTTs, no staleness
  peer_fetch     store on the writing edge — reads fetch over 20 ms (SyncMesh)
  replicated     Enoki — both local; staleness = replication in flight

Staleness is measured exactly as the paper does: a read is stale if its
value had already been overwritten at read time; staleness = read time −
apply time of the overwriting write.  One logical client -> no clock drift.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import paper_cluster
from repro.configs.base import ReplicationPolicy
from repro.core import WriteLog, enoki_function, percentiles
from repro.core.faas import get_function, registry


def _ensure_fns():
    if "kv_write" in registry():
        return

    @enoki_function(name="kv_write", keygroups=["item"], codec_width=4)
    def kv_write(kv, x):
        kv.set("value", jnp.atleast_1d(x)[:1])
        return jnp.atleast_1d(x)[:1]

    @enoki_function(name="kv_read", keygroups=["item"], codec_width=4)
    def kv_read(kv, x):
        val, found = kv.get("value")
        return val[:1]


def run(rps: float = 10.0, duration_s: float = 20.0, repeats: int = 3):
    _ensure_fns()
    rows = []
    for policy in (ReplicationPolicy.CLOUD_CENTRAL,
                   ReplicationPolicy.PEER_FETCH,
                   ReplicationPolicy.REPLICATED):
        for rep in range(repeats):
            c = paper_cluster(measure_compute=(rep == 0))
            # both functions share the "item" keygroup
            c.deploy(get_function("kv_write"), ["edge"], policy=policy,
                     owner="edge" if policy == ReplicationPolicy.PEER_FETCH
                     else "cloud", example_input=jnp.ones((1,)))
            c.deploy(get_function("kv_read"), ["edge2"], policy=policy,
                     owner="edge" if policy == ReplicationPolicy.PEER_FETCH
                     else "cloud", example_input=jnp.ones((1,)))
            log = WriteLog()
            w_lat, r_lat, stale = [], [], []
            n = int(rps * duration_s)
            for i in range(n):
                t = i * (1000.0 / rps)
                w = c.invoke("kv_write", "edge", jnp.ones((1,)) * i, t_send=t)
                log.add(w.t_applied, i)
                w_lat.append(w.response_ms)
                r = c.invoke("kv_read", "edge2", jnp.zeros((1,)),
                             t_send=t + 50.0)
                r_lat.append(r.response_ms)
                seen = int(round(float(np.asarray(r.output)[0])))
                stale.append(log.staleness_of_read(r.t_applied, seen))
            rows.append({
                "policy": policy.value, "repeat": rep,
                "write_p50_ms": percentiles(w_lat)[50],
                "read_p50_ms": percentiles(r_lat)[50],
                "staleness_p50_ms": percentiles(stale)[50],
                "staleness_p99_ms": percentiles(stale)[99],
            })
    return rows


def main():
    from benchmarks.common import print_table
    rows = run()
    print_table(rows, "Fig 6 — placement vs latency and staleness")
    print("\npaper: local writes ≈50ms faster than cloud; local reads "
          "20/50ms faster than peer/cloud; replication staleness ≈2ms "
          "median (≤10ms one-way delay)")
    return rows


if __name__ == "__main__":
    main()
