"""Fig 5/6 reproduction: the three data placements under a two-edge
write/read workload — read latency, write latency, and data STALENESS.

Setup (paper §4.3): two edge nodes 20 ms / 100 Mb/s apart; the client
updates a value through the function on edge, reads it through edge2, ten
requests per second.  Placements:

  cloud_central  one store in the cloud — both ops pay 50 ms RTTs, no staleness
  peer_fetch     store on the writing edge — reads fetch over 20 ms (SyncMesh)
  replicated     Enoki — both local; staleness = replication in flight

Staleness is measured exactly as the paper does: a read is stale if its
value had already been overwritten at read time; staleness = read time −
apply time of the overwriting write.  One logical client -> no clock drift.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import paper_cluster
from repro.configs.base import ReplicationPolicy
from repro.core import WriteLog, enoki_function, percentiles
from repro.core.faas import get_function, registry


def _ensure_fns():
    if "kv_write" in registry():
        return

    @enoki_function(name="kv_write", keygroups=["item"], codec_width=4)
    def kv_write(kv, x):
        kv.set("value", jnp.atleast_1d(x)[:1])
        return jnp.atleast_1d(x)[:1]

    @enoki_function(name="kv_read", keygroups=["item"], codec_width=4)
    def kv_read(kv, x):
        val, found = kv.get("value")
        return val[:1]


def run(rps: float = 10.0, duration_s: float = 20.0, repeats: int = 3):
    _ensure_fns()
    rows = []
    for policy in (ReplicationPolicy.CLOUD_CENTRAL,
                   ReplicationPolicy.PEER_FETCH,
                   ReplicationPolicy.REPLICATED):
        for rep in range(repeats):
            c = paper_cluster(measure_compute=(rep == 0))
            # both functions share the "item" keygroup
            c.deploy(get_function("kv_write"), ["edge"], policy=policy,
                     owner="edge" if policy == ReplicationPolicy.PEER_FETCH
                     else "cloud", example_input=jnp.ones((1,)))
            c.deploy(get_function("kv_read"), ["edge2"], policy=policy,
                     owner="edge" if policy == ReplicationPolicy.PEER_FETCH
                     else "cloud", example_input=jnp.ones((1,)))
            log = WriteLog()
            w_lat, r_lat, stale = [], [], []
            n = int(rps * duration_s)
            for i in range(n):
                t = i * (1000.0 / rps)
                w = c.invoke("kv_write", "edge", jnp.ones((1,)) * i, t_send=t)
                log.add(w.t_applied, i)
                w_lat.append(w.response_ms)
                r = c.invoke("kv_read", "edge2", jnp.zeros((1,)),
                             t_send=t + 50.0)
                r_lat.append(r.response_ms)
                seen = int(round(float(np.asarray(r.output)[0])))
                stale.append(log.staleness_of_read(r.t_applied, seen))
            rows.append({
                "policy": policy.value, "repeat": rep,
                "write_p50_ms": percentiles(w_lat)[50],
                "read_p50_ms": percentiles(r_lat)[50],
                "staleness_p50_ms": percentiles(stale)[50],
                "staleness_p99_ms": percentiles(stale)[99],
            })
    return rows


# ---------------------------------------------------------------------------
# Churn mode: the replicated placement under node kill/restore per epoch.
#
# Two complementary measurements, both pinned by the acceptance criteria:
#
# * ``run_churn`` (virtual time, deterministic): an accumulator keygroup
#   replicated edge<->edge2; each epoch kills edge2, keeps writing (half
#   the writes deliberately target the dead node and must be REROUTED, not
#   lost), probes a function deployed only on the dead node (those must
#   FAIL FAST as at-most-once drops, not hang), then restores edge2
#   through the membership catch-up.  Accounting must balance exactly —
#   submitted == served + failed_fast, zero silently lost — and the final
#   store state must be byte-identical (``stores_equal``: version vectors
#   AND contents) to a churn-free run of the same write sequence.
#
# * ``run_churn_serving`` (wall clock): the same kill/restore cadence
#   against a live ``FaasServer``; clients retry on ``RequestLost`` until
#   served, so the final accumulator value doubles as an at-most-once
#   audit (a "lost" request that had secretly applied would overshoot).
# ---------------------------------------------------------------------------

def _ensure_churn_fns():
    if "churn_acc" in registry():
        return

    @enoki_function(name="churn_acc", keygroups=["churnkg"], codec_width=4)
    def churn_acc(kv, x):
        cur, _ = kv.get("acc")
        kv.set("acc", cur + jnp.atleast_1d(x)[:1])
        return cur[:1] + jnp.atleast_1d(x)[:1]

    @enoki_function(name="churn_probe", keygroups=["churnprobekg"],
                    codec_width=4)
    def churn_probe(kv, x):
        return jnp.atleast_1d(x)[:1]


def _churn_cluster():
    c = paper_cluster(measure_compute=False)
    c.deploy(get_function("churn_acc"), ["edge", "edge2"],
             policy=ReplicationPolicy.REPLICATED)
    c.deploy(get_function("churn_probe"), ["edge2"],
             policy=ReplicationPolicy.REPLICATED)
    return c


_QUIESCE_T = 1e12       # large FINITE horizon: flushes every pending
                        # delivery but NOT the inf-arrival ones a
                        # partition would strand


def run_churn(epochs: int = 5, writes_per_epoch: int = 8):
    """Kill/restore a replica per epoch under a deterministic write stream;
    returns (rows, summary).  See the block comment above for the contract
    each column asserts."""
    from repro.core.engine import BatchedInvocationEngine
    from repro.core.store import stores_equal
    from repro.runtime import ElasticMembership, FailureInjector
    _ensure_fns()
    _ensure_churn_fns()
    one = jnp.ones((1,), jnp.float32)
    total = epochs * writes_per_epoch

    # churn-free reference: the identical write sequence, all applied at
    # the writer edge, replication flushed at the same epoch boundaries
    ref = _churn_cluster()
    ref_eng = BatchedInvocationEngine(ref, window_ms=4.0)
    ref.engine = ref_eng
    for e in range(epochs):
        for i in range(writes_per_epoch):
            g = e * writes_per_epoch + i
            ref_eng.submit("churn_acc", "edge", one, t_send=g * 10.0)
        ref_eng.flush()
        ref.flush_replication(_QUIESCE_T)
    ref_eng.close()

    # churn run: same sequence, but edge2 is DEAD for every epoch's writes
    # (half of them aimed straight at it) and restored afterwards
    c = _churn_cluster()
    eng = BatchedInvocationEngine(c, window_ms=4.0)
    c.engine = eng
    m = ElasticMembership(c, min_replicas=2)
    inj = FailureInjector(c, membership=m)
    rows = []
    served = 0
    n_probe = 2
    for e in range(epochs):
        inj.kill_node("edge2")
        prev_re, prev_dd = eng.stats.reroutes, eng.stats.dropped_dead
        for i in range(writes_per_epoch):
            g = e * writes_per_epoch + i
            # odd writes target the DEAD node: the engine must reroute
            # them to the surviving replica, not raise or hang
            node = "edge2" if i % 2 else "edge"
            eng.submit("churn_acc", node, one, t_send=g * 10.0)
        for p in range(n_probe):
            # deployed only on the dead node -> at-most-once fail-fast
            eng.submit("churn_probe", "edge2", one,
                       t_send=(e * writes_per_epoch + writes_per_epoch)
                       * 10.0 + p)
        out = eng.flush()
        assert not eng.pending(), "requests left hanging after flush"
        served += len(out)
        inj.restore_node("edge2", t=_QUIESCE_T)
        c.flush_replication(_QUIESCE_T)
        rows.append({"epoch": e, "submitted": writes_per_epoch + n_probe,
                     "served": len(out),
                     "rerouted": eng.stats.reroutes - prev_re,
                     "failed_fast": eng.stats.dropped_dead - prev_dd})
    eng.close()

    silently_lost = (total + epochs * 2) - served - eng.stats.dropped_dead
    state_ok = all(
        stores_equal(c.store_of("churnkg", nd), ref.store_of("churnkg", nd))
        for nd in ("edge", "edge2"))
    summary = {
        "submitted": total + epochs * 2, "served": served,
        "rerouted": eng.stats.reroutes,
        "failed_fast": eng.stats.dropped_dead,
        "silently_lost": silently_lost,
        "crashes": m.stats.crashes, "restores": m.stats.restores,
        "state_matches_churn_free": state_ok,
    }
    return rows, summary


def run_churn_serving(epochs: int = 3, writes_per_epoch: int = 16,
                      time_scale: float = 50.0):
    """Wall-clock churn: kill/restore a replica while a live FaasServer
    drains retrying clients.  Every drop must surface as ``RequestLost``
    (counted, retried); the final accumulator value audits at-most-once."""
    from repro.core.engine import BatchedInvocationEngine
    from repro.launch.faas_server import FaasServer, RequestLost
    from repro.runtime import ElasticMembership, FailureInjector
    _ensure_fns()
    _ensure_churn_fns()
    one = jnp.ones((1,), jnp.float32)
    c = _churn_cluster()
    c.engine = BatchedInvocationEngine(c, window_ms=4.0)
    m = ElasticMembership(c, min_replicas=2)
    inj = FailureInjector(c, membership=m)
    lost = retried = served = 0
    unexpected = []
    with FaasServer(c, window_ms=4.0, time_scale=time_scale,
                    membership=m) as srv:
        for e in range(epochs):
            for i in range(writes_per_epoch):
                if i == writes_per_epoch // 4:
                    inj.kill_node("edge2")
                elif i == (3 * writes_per_epoch) // 4:
                    inj.restore_node("edge2", t=_QUIESCE_T)
                while True:     # retry until served: RequestLost is the
                    try:        # at-most-once signal to re-submit
                        srv.submit("churn_acc", one).result(timeout=30.0)
                        served += 1
                        break
                    except RequestLost:
                        lost += 1
                        retried += 1
                    except BaseException as exc:    # anything else is a
                        unexpected.append(exc)      # silent-loss bug
                        break
            if m.state.get("edge2") != "alive":
                inj.restore_node("edge2", t=_QUIESCE_T)
    c.flush_replication(_QUIESCE_T)
    final = float(np.asarray(
        c.invoke("churn_acc", "edge", jnp.zeros((1,), jnp.float32),
                 t_send=1e9).output)[0])
    c.engine.close()
    total = epochs * writes_per_epoch
    return {
        "submitted": total + retried, "served": served,
        "request_lost": lost, "retried": retried,
        "unexpected_errors": len(unexpected),
        # served writes each add 1; the final read sees the accumulated
        # value BEFORE its own (zero) add — equality proves no lost
        # request ever secretly applied (at-most-once held)
        "final_value": final, "expected_value": float(total),
        "at_most_once_held": final == float(total),
    }


# ---------------------------------------------------------------------------
# Partition mode: the seeded chaos harness end to end.
#
# ``run_partition`` drives ``chaos_schedule``/``run_chaos`` (runtime/
# failure.py) over the replicated placement: per-round lossy links
# (drop_p <= 0.2, duplication, jitter), one multi-round partition of the
# victim, one crash+restore after the heal — then replays the identical
# plan with the network faults disabled (the fault-free twin) and asserts
# the pinned invariants: zero silent losses (engine accounting balances,
# every unserved probe is a surfaced drop) and final stores byte-identical
# to the twin, version vectors included.  The artifact also records the
# transport counters (retries/drops/dups/epoch rejections) so a run shows
# the faults were real, not vacuously survived.
# ---------------------------------------------------------------------------

_CHAOS_NODES = ("edge", "edge2", "cloud")


def _ensure_partition_fns():
    if "part_ctr" in registry():
        return

    @enoki_function(name="part_ctr", keygroups=["partkg"], codec_width=4)
    def part_ctr(kv, x):
        cur, _ = kv.get("ctr")
        kv.set("ctr", cur + jnp.atleast_1d(x)[:1])
        return cur[:1] + jnp.atleast_1d(x)[:1]

    @enoki_function(name="part_probe", keygroups=["partprobekg"],
                    codec_width=4)
    def part_probe(kv, x):
        return jnp.atleast_1d(x)[:1]


def _chaos_run(seed: int, rounds: int, apply_faults: bool):
    """One chaos run (faulty, or its fault-free twin when
    ``apply_faults=False``) over the same seeded plan."""
    from repro.core import Cluster
    from repro.runtime import (ElasticMembership, FailureInjector,
                               chaos_schedule, run_chaos)
    c = Cluster({n: ("cloud" if n == "cloud" else "edge")
                 for n in _CHAOS_NODES}, measure_compute=False,
                fault_seed=seed)
    c.deploy(get_function("part_ctr"), list(_CHAOS_NODES),
             policy=ReplicationPolicy.REPLICATED)
    c.deploy(get_function("part_probe"), ["edge2"],
             policy=ReplicationPolicy.REPLICATED)
    m = ElasticMembership(c)
    inj = FailureInjector(c, membership=m)
    plan = chaos_schedule(seed, rounds, _CHAOS_NODES, victim="edge2")

    def write(node, r, t):
        # sequential writers + inter-write drain: every write folds on all
        # prior ones, so the final counter equals the total write count in
        # the faulty run AND the twin (LWW registers, not CRDTs)
        c.invoke("part_ctr", node, jnp.ones((1,)), t_send=t + 1.0)
        c.drain_transport(t + 1.0)

    served, lost = [], []

    def probe(r, t):
        ticket = c.engine.submit("part_probe", "edge2", jnp.ones((1,)),
                                 t_send=t + 2.0)
        out = c.engine.flush()
        (served if ticket in out else lost).append(r)

    run_chaos(c, m, inj, plan, write, probe=probe,
              apply_faults=apply_faults)
    return c, m, plan, served, lost


def run_partition(seed: int = 7, rounds: int = 12):
    """Seeded chaos vs fault-free twin; returns the JSON-ready summary."""
    from repro.core.store import stores_equal
    _ensure_fns()
    _ensure_partition_fns()
    c, m, plan, served, lost = _chaos_run(seed, rounds, apply_faults=True)
    ct, _, _, served_t, lost_t = _chaos_run(seed, rounds,
                                            apply_faults=False)

    st = c.engine.stats
    accounting_ok = st.submitted == st.requests_flushed + st.dropped_dead
    converged = all(
        stores_equal(c.store_of("partkg", _CHAOS_NODES[0]),
                     c.store_of("partkg", n)) for n in _CHAOS_NODES[1:])
    twin_ok = all(
        stores_equal(c.store_of("partkg", n), ct.store_of("partkg", n))
        for n in _CHAOS_NODES)
    writes = sum(len(plan.writers_for(r)) for r in range(rounds))
    final = float(np.asarray(c.store_of("partkg", "edge").values)[0][0])
    return {
        "seed": seed, "rounds": rounds, "victim": "edge2",
        "writes": writes, "final_counter": final,
        "probes_served": len(served), "probes_lost": len(lost),
        "silently_lost": st.submitted - st.requests_flushed
        - st.dropped_dead,
        "accounting_balances": accounting_ok,
        "repl_retries": c.stats.repl_retries,
        "repl_dropped": c.stats.repl_dropped,
        "repl_duped": c.stats.repl_duped,
        "epoch_rejections": c.stats.epoch_rejections,
        "suspects": m.stats.suspects,
        "false_suspects": m.stats.false_suspects,
        "crashes": m.stats.crashes, "restores": m.stats.restores,
        "replicas_converged": converged,
        "matches_fault_free_twin": twin_ok,
        "twin_probe_parity": served == served_t and lost == lost_t,
    }


# ---------------------------------------------------------------------------
# Merge-path mode: the device-resident delivery merge, old vs new.
#
# ``run_merge_path`` pits the retired per-snapshot path (K sequential
# ``merge_stores_jit`` dispatches per delivery batch) against the fused
# multi-way merge (ONE ``merge_snapshots_fused`` dispatch folding all K)
# on slot-aligned arenas — the exact shapes ``_deliver_until`` serves.
# Byte-identical results are asserted before timing; throughput uses the
# stabilized interleaved-repeats + median-of-K methodology.
# ---------------------------------------------------------------------------

def _aligned_replicas(slots: int, width: int, count: int, seed: int = 0):
    """``count`` slot-aligned arenas sharing one canonical layout, with
    per-replica versions/values so both LWW win directions occur."""
    import jax
    from repro.core.store import store_new, store_assign_slots
    from repro.core.versioning import MAX_NODES
    rng = np.random.default_rng(seed)
    layout = {1000 + i: i for i in range(slots)}
    out = []
    for r in range(count):
        base, ok = store_assign_slots(store_new(slots, width, MAX_NODES),
                                      layout)
        assert ok
        out.append(base._replace(
            values=jnp.asarray(rng.normal(size=(slots, width)), jnp.float32),
            lengths=jnp.full((slots,), width, jnp.int32),
            versions=jnp.asarray(rng.integers(1, 1000, slots), jnp.int32),
            vv=jnp.asarray(rng.integers(0, 50, MAX_NODES), jnp.int32)))
    jax.block_until_ready(out)
    return out


def run_merge_path(slots: int = 64, width: int = 8, k: int = 8,
                   iters: int = 100, repeats: int = 3):
    """Delivery-merge throughput, per-snapshot vs fused K-way (ops =
    snapshot merges applied).  Returns the JSON-ready result dict."""
    import jax
    from benchmarks.common import interleaved_repeats, median_ops
    from repro.core.store import (arena_clone, merge_snapshots_fused,
                                  merge_stores_jit, stores_equal)

    arenas = _aligned_replicas(slots, width, k + 1)
    acc, snaps = arenas[0], tuple(arenas[1:])

    # correctness first: one fused dispatch == K sequential merges, bitwise
    ref = arena_clone(acc)
    for s in snaps:
        ref = merge_stores_jit(ref, s)
    fused_out = merge_snapshots_fused(arena_clone(acc), snaps, aligned=True)
    assert stores_equal(fused_out, ref), "fused merge diverged from sequential"

    def per_snapshot() -> int:
        s = arena_clone(acc)
        for _ in range(iters):
            for snap in snaps:
                s = merge_stores_jit(s, snap)
        jax.block_until_ready(s)
        return iters * k

    def fused() -> int:
        s = arena_clone(acc)
        for _ in range(iters):
            s = merge_snapshots_fused(s, snaps, aligned=True)
        jax.block_until_ready(s)
        return iters * k

    med = median_ops(interleaved_repeats(
        {"per_snapshot": per_snapshot, "fused": fused},
        repeats=repeats, warmup=1))
    return {
        "slots": slots, "value_width": width, "k": k, "iters": iters,
        "per_snapshot_merges_per_s": round(med["per_snapshot"], 1),
        "fused_merges_per_s": round(med["fused"], 1),
        "speedup": round(med["fused"] / med["per_snapshot"], 2),
        "bit_identical": True,      # asserted above before timing
    }


def main():
    import sys
    from benchmarks.common import print_table
    if "--merge-path" in sys.argv:
        import json
        import os
        result = run_merge_path()
        print_table([result], "Fig 6 merge path — per-snapshot vs fused")
        out_dir = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "artifacts"))
        os.makedirs(out_dir, exist_ok=True)
        out = os.path.join(out_dir, "fig6_merge_path.json")
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
        assert result["speedup"] >= 2.0, result
        return [result]
    if "--partition" in sys.argv:
        import json
        import os
        result = run_partition()
        print_table([result], "Fig 6 partition — seeded chaos vs twin")
        out_dir = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "artifacts"))
        os.makedirs(out_dir, exist_ok=True)
        out = os.path.join(out_dir, "fig6_partition.json")
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
        assert result["silently_lost"] == 0, result
        assert result["accounting_balances"], result
        assert result["final_counter"] == result["writes"], result
        assert result["replicas_converged"], result
        assert result["matches_fault_free_twin"], result
        assert result["twin_probe_parity"], result
        assert result["repl_retries"] > 0, result
        return [result]
    if "--churn" in sys.argv:
        rows, summary = run_churn()
        print_table(rows, "Fig 6 churn — kill/restore a replica per epoch")
        print_table([summary], "Fig 6 churn — totals")
        serve = run_churn_serving()
        print_table([serve], "Fig 6 churn — wall-clock serving loop")
        assert summary["silently_lost"] == 0, summary
        assert summary["state_matches_churn_free"], summary
        assert serve["unexpected_errors"] == 0 and serve["at_most_once_held"]
        return rows
    rows = run()
    print_table(rows, "Fig 6 — placement vs latency and staleness")
    print("\npaper: local writes ≈50ms faster than cloud; local reads "
          "20/50ms faster than peer/cloud; replication staleness ≈2ms "
          "median (≤10ms one-way delay)")
    return rows


if __name__ == "__main__":
    main()
