"""Fig 3 reproduction: request-response latency of the stateful moving-
average function with the data store at the edge (Enoki) vs in the cloud.

The function performs 4 kv ops per invocation (read pointer, scan window,
write value, write pointer); with the store in the cloud each op pays the
50 ms edge-cloud RTT -> the paper measures ≈ +200 ms.  Compute and local
store times are MEASURED on this host (real jitted handlers); network time
comes from the tc-netem-equivalent model.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import latency_stats, open_workload, paper_cluster
from repro.configs.base import ReplicationPolicy
from repro.core.faas import get_function


def _ensure_movavg():
    from repro.core.faas import registry

    if "movavg_bench" in registry():
        return
    from repro.core import enoki_function

    @enoki_function(name="movavg_bench", keygroups=["avg"], codec_width=16)
    def movavg(kv, x):
        ptr, found = kv.get("ptr")
        idx = jnp.where(found, ptr[0], 0.0)
        kv.set("v", jnp.concatenate([jnp.atleast_1d(x)[:1],
                                     jnp.zeros((15,))]))
        window, _ = kv.scan(["v"])
        kv.set("ptr", jnp.stack([idx + 1.0]))
        return jnp.stack([window[:, 0].mean()])


def run(rps: float = 10.0, duration_s: float = 30.0, repeats: int = 3):
    _ensure_movavg()
    rows = []
    for placement, policy in [("edge (Enoki)", ReplicationPolicy.REPLICATED),
                              ("cloud store", ReplicationPolicy.CLOUD_CENTRAL)]:
        for rep in range(repeats):
            c = paper_cluster(measure_compute=(rep == 0))
            c.deploy(get_function("movavg_bench"), ["edge"], policy=policy,
                     owner="cloud", example_input=jnp.ones((1,)))
            res = open_workload(
                lambda t, i: c.invoke("movavg_bench", "edge",
                                      jnp.ones((1,)) * (i % 10), t_send=t),
                rps, duration_s)
            rows.append({"placement": placement, "repeat": rep,
                         **latency_stats(res, "movavg")})
    return rows


def main():
    from benchmarks.common import print_table
    rows = run()
    print_table(rows, "Fig 3 — moving average request-response latency (ms)")
    edge = [r["p50"] for r in rows if "edge" in r["placement"]]
    cloud = [r["p50"] for r in rows if "cloud" in r["placement"]]
    delta = sum(cloud) / len(cloud) - sum(edge) / len(edge)
    print(f"\nmedian delta cloud-edge: {delta:.1f} ms "
          f"(paper: ≈200 ms from 4 ops × 50 ms RTT)")
    return rows


if __name__ == "__main__":
    main()
