"""Benchmark harness: one reproduction per paper figure + the roofline
table.  ``python -m benchmarks.run [--fast]``

fig3  moving-average latency, store edge vs cloud        (paper Fig 3)
fig4  read/write throughput vs item size                 (paper Fig 4)
fig6  three placements: latency + staleness              (paper Fig 5/6)
fig8  smart-city multi-function app                      (paper Fig 7/8)
roofline  per (arch × shape) terms from the dry-run      (§Roofline)

``python -m benchmarks.run serve`` instead drives the WALL-CLOCK serving
loop (launch/faas_server.py) for a fixed request count — real arrival
times mapped onto the engine's virtual timeline — and emits latency
percentiles (p50/p90/p99) plus hedge counters into the benchmark JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main_serve(argv):
    ap = argparse.ArgumentParser(prog="benchmarks.run serve")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--mode", choices=("open", "closed"), default="open",
                    help="open: fixed arrival rate; closed: N looping clients")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="open-loop arrivals per VIRTUAL ms")
    ap.add_argument("--concurrency", type=int, default=4,
                    help="closed-loop client threads")
    ap.add_argument("--window-ms", type=float, default=8.0)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--hedge-after-ms", type=float, default=None)
    ap.add_argument("--straggler-ms", type=float, default=0.0,
                    help="extra compute at the nearest replica (hedge demo)")
    ap.add_argument("--time-scale", type=float, default=50.0,
                    help="virtual ms per wall ms")
    ap.add_argument("--workers", type=int, default=None,
                    help="parallel-pump width: per-store-node executors "
                         "(default: serial pump)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    from benchmarks.fig4_throughput import _seed_and_warm
    from repro.core import Cluster, get_function, percentiles
    from repro.core.network import paper_topology
    from repro.launch.faas_server import (FaasServer, serve_closed_loop,
                                          serve_open_loop)

    cluster = Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                      net=paper_topology(), measure_compute=False)
    cluster.deploy(get_function("fig4_read"), ["edge", "edge2"])
    cluster.deploy(get_function("fig4_write"), ["edge", "edge2"])
    x = _seed_and_warm(cluster, ["edge", "edge2"])
    if args.straggler_ms:
        cluster.set_compute_ms("edge", "fig4_read", args.straggler_ms)

    t0 = time.perf_counter()
    with FaasServer(cluster, window_ms=args.window_ms,
                    max_batch=args.max_batch,
                    hedge_after_ms=args.hedge_after_ms,
                    time_scale=args.time_scale,
                    workers=args.workers) as srv:
        if args.mode == "closed":
            serve_closed_loop(srv, "fig4_read", lambda i: x,
                              n_requests=args.requests,
                              concurrency=args.concurrency,
                              timeout_s=60.0)
        else:
            serve_open_loop(srv, "fig4_read", lambda i: x,
                            n_requests=args.requests,
                            rate_per_ms=args.rate, timeout_s=60.0)
        elapsed = time.perf_counter() - t0
        pct = percentiles(srv.response_ms)
        rstats = srv.router.stats
        result = {"mode": args.mode, "requests": srv.stats.served,
                  "lost": srv.stats.lost,
                  "workers": args.workers,
                  "window_ms": args.window_ms,
                  "hedge_after_ms": args.hedge_after_ms,
                  "straggler_ms": args.straggler_ms,
                  "time_scale": args.time_scale,
                  "wall_s": round(elapsed, 3),
                  "wall_ops_per_s": round(srv.stats.served / elapsed, 1),
                  "p50_ms": round(pct[50], 2), "p90_ms": round(pct[90], 2),
                  "p99_ms": round(pct[99], 2),
                  "hedges_fired": rstats.hedges_fired,
                  "hedge_wins": rstats.hedge_wins,
                  "pumps": srv.stats.pumps, "wakeups": srv.stats.wakeups,
                  "repl_retries": cluster.stats.repl_retries,
                  "repl_dropped": cluster.stats.repl_dropped,
                  "repl_duped": cluster.stats.repl_duped,
                  "epoch_rejections": cluster.stats.epoch_rejections}
    print(f"serve [{args.mode}]: {result['requests']} requests in "
          f"{result['wall_s']}s ({result['wall_ops_per_s']} ops/s wall)")
    print(f"  latency (virtual ms): p50={result['p50_ms']} "
          f"p90={result['p90_ms']} p99={result['p99_ms']}")
    print(f"  transport: retries={result['repl_retries']} "
          f"dropped={result['repl_dropped']} duped={result['repl_duped']} "
          f"epoch_rejections={result['epoch_rejections']}")
    if args.hedge_after_ms is not None:
        print(f"  hedges: fired={result['hedges_fired']} "
              f"wins={result['hedge_wins']}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"serve": result}, f, indent=1)
        print(f"wrote {args.json_out}")
    return {"serve": result}


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "serve":
        return main_serve(sys.argv[2:])
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,fig6,fig8,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="shorter workloads (CI)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    which = set((args.only or "fig3,fig4,fig6,fig8,roofline").split(","))
    results = {}
    t0 = time.time()

    if "fig3" in which:
        from benchmarks import fig3_moving_average
        dur = 5.0 if args.fast else 30.0
        reps = 1 if args.fast else 3
        rows = fig3_moving_average.run(duration_s=dur, repeats=reps)
        from benchmarks.common import print_table
        print_table(rows, "Fig 3 — moving average latency (ms)")
        edge = [r["p50"] for r in rows if "edge" in r["placement"]]
        cloud = [r["p50"] for r in rows if "cloud" in r["placement"]]
        delta = sum(cloud) / len(cloud) - sum(edge) / len(edge)
        print(f"median delta cloud-edge: {delta:.1f} ms (paper: ≈200 ms)")
        results["fig3"] = {"rows": rows, "delta_ms": delta}

    if "fig4" in which:
        from benchmarks import fig4_throughput
        results["fig4"] = fig4_throughput.main()

    if "fig6" in which:
        from benchmarks import fig6_replication
        dur = 5.0 if args.fast else 20.0
        reps = 1 if args.fast else 3
        rows = fig6_replication.run(duration_s=dur, repeats=reps)
        from benchmarks.common import print_table
        print_table(rows, "Fig 6 — placement vs latency + staleness")
        results["fig6"] = {"rows": rows}

    if "fig8" in which:
        from benchmarks import fig8_smart_city
        dur = 10.0 if args.fast else 60.0
        reps = 1 if args.fast else 3
        rows = fig8_smart_city.run(duration_s=dur, repeats=reps)
        from benchmarks.common import print_table
        print_table(rows, "Fig 8 — smart-city latency (ms)")
        results["fig8"] = {"rows": rows}

    if "roofline" in which:
        from benchmarks import roofline_table
        roofline_table.main()
        results["roofline"] = "see artifacts/dryrun"

    print(f"\nbenchmarks done in {time.time()-t0:.1f}s")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    return results


if __name__ == "__main__":
    main()
