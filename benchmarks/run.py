"""Benchmark harness: one reproduction per paper figure + the roofline
table.  ``python -m benchmarks.run [--fast]``

fig3  moving-average latency, store edge vs cloud        (paper Fig 3)
fig4  read/write throughput vs item size                 (paper Fig 4)
fig6  three placements: latency + staleness              (paper Fig 5/6)
fig8  smart-city multi-function app                      (paper Fig 7/8)
roofline  per (arch × shape) terms from the dry-run      (§Roofline)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,fig6,fig8,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="shorter workloads (CI)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    which = set((args.only or "fig3,fig4,fig6,fig8,roofline").split(","))
    results = {}
    t0 = time.time()

    if "fig3" in which:
        from benchmarks import fig3_moving_average
        dur = 5.0 if args.fast else 30.0
        reps = 1 if args.fast else 3
        rows = fig3_moving_average.run(duration_s=dur, repeats=reps)
        from benchmarks.common import print_table
        print_table(rows, "Fig 3 — moving average latency (ms)")
        edge = [r["p50"] for r in rows if "edge" in r["placement"]]
        cloud = [r["p50"] for r in rows if "cloud" in r["placement"]]
        delta = sum(cloud) / len(cloud) - sum(edge) / len(edge)
        print(f"median delta cloud-edge: {delta:.1f} ms (paper: ≈200 ms)")
        results["fig3"] = {"rows": rows, "delta_ms": delta}

    if "fig4" in which:
        from benchmarks import fig4_throughput
        results["fig4"] = fig4_throughput.main()

    if "fig6" in which:
        from benchmarks import fig6_replication
        dur = 5.0 if args.fast else 20.0
        reps = 1 if args.fast else 3
        rows = fig6_replication.run(duration_s=dur, repeats=reps)
        from benchmarks.common import print_table
        print_table(rows, "Fig 6 — placement vs latency + staleness")
        results["fig6"] = {"rows": rows}

    if "fig8" in which:
        from benchmarks import fig8_smart_city
        dur = 10.0 if args.fast else 60.0
        reps = 1 if args.fast else 3
        rows = fig8_smart_city.run(duration_s=dur, repeats=reps)
        from benchmarks.common import print_table
        print_table(rows, "Fig 8 — smart-city latency (ms)")
        results["fig8"] = {"rows": rows}

    if "roofline" in which:
        from benchmarks import roofline_table
        roofline_table.main()
        results["roofline"] = "see artifacts/dryrun"

    print(f"\nbenchmarks done in {time.time()-t0:.1f}s")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    return results


if __name__ == "__main__":
    main()
