"""Shared benchmark plumbing: cluster builders, workload drivers, tables."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import Cluster, WriteLog, percentiles
from repro.core.network import paper_topology


def paper_cluster(measure_compute: bool = True) -> Cluster:
    """The §4 testbed: client, edge, edge2, cloud with tc-netem-equivalent
    links (50ms/100Mb/s edge-cloud, 20ms/100Mb/s edge-edge)."""
    return Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                   net=paper_topology(), measure_compute=measure_compute)


def open_workload(invoke: Callable[[float, int], object], rps: float,
                  duration_s: float) -> List[object]:
    """Paper's open workload: fixed arrival rate regardless of completions."""
    results = []
    n = int(rps * duration_s)
    for i in range(n):
        t_send = i * (1000.0 / rps)
        results.append(invoke(t_send, i))
    return results


def latency_stats(results, name: str = "") -> Dict[str, float]:
    lat = [r.response_ms for r in results]
    p = percentiles(lat, (50, 90, 99))
    return {"name": name, "n": len(lat), "mean": float(np.mean(lat)),
            "p50": p[50], "p90": p[90], "p99": p[99]}


def print_table(rows: List[Dict], title: str) -> None:
    if not rows:
        return
    cols = list(rows[0].keys())
    print(f"\n## {title}")
    print("| " + " | ".join(cols) + " |")
    print("|" + "|".join(["---"] * len(cols)) + "|")
    for r in rows:
        cells = []
        for c in cols:
            v = r[c]
            cells.append(f"{v:.2f}" if isinstance(v, float) else str(v))
    # markdown row
        print("| " + " | ".join(cells) + " |")
