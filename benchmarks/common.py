"""Shared benchmark plumbing: cluster builders, workload drivers, tables,
and the stabilized measurement methodology (warmup + interleaved repeats +
median-of-K) that makes wall-clock numbers regressable on a noisy host."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import Cluster, WriteLog, percentiles
from repro.core.network import paper_topology


def paper_cluster(measure_compute: bool = True) -> Cluster:
    """The §4 testbed: client, edge, edge2, cloud with tc-netem-equivalent
    links (50ms/100Mb/s edge-cloud, 20ms/100Mb/s edge-edge)."""
    return Cluster({"edge": "edge", "edge2": "edge", "cloud": "cloud"},
                   net=paper_topology(), measure_compute=measure_compute)


def open_workload(invoke: Callable[[float, int], object], rps: float,
                  duration_s: float) -> List[object]:
    """Paper's open workload: fixed arrival rate regardless of completions."""
    results = []
    n = int(rps * duration_s)
    for i in range(n):
        t_send = i * (1000.0 / rps)
        results.append(invoke(t_send, i))
    return results


def interleaved_repeats(variants: Dict[object, Callable[[], int]],
                        repeats: int = 3, warmup: int = 1
                        ) -> Dict[object, List[float]]:
    """Measure competing variants FAIRLY under drifting host load.

    ``variants`` maps a label to a zero-arg callable that runs one full
    measurement pass and returns the number of operations it completed.
    The methodology (the fix for the ~4x run-to-run spread the ROADMAP
    flagged on ``parallel_sweep``):

    * ``warmup`` un-recorded rounds first — jit compiles, allocator and
      cache warm-up land outside the timed region;
    * then ``repeats`` recorded rounds, each visiting EVERY variant once
      (interleaving): slow host-load drift hits all variants equally
      instead of whichever happened to run last;
    * the caller reduces with ``median_ops`` — the median of K is robust
      to one descheduled run, where a mean is not.

    Returns ``{label: [ops_per_s, ...]}`` with ``repeats`` samples each.
    """
    labels = list(variants)
    for _ in range(max(0, warmup)):
        for lb in labels:
            variants[lb]()
    samples: Dict[object, List[float]] = {lb: [] for lb in labels}
    for _ in range(repeats):
        for lb in labels:
            t0 = time.perf_counter()
            ops = variants[lb]()
            elapsed = time.perf_counter() - t0
            samples[lb].append(ops / elapsed)
    return samples


def median_ops(samples: Dict[object, List[float]]) -> Dict[object, float]:
    """Median ops/s per variant (the number a regression asserts on)."""
    return {lb: float(np.median(v)) for lb, v in samples.items()}


def latency_stats(results, name: str = "") -> Dict[str, float]:
    lat = [r.response_ms for r in results]
    p = percentiles(lat, (50, 90, 99))
    return {"name": name, "n": len(lat), "mean": float(np.mean(lat)),
            "p50": p[50], "p90": p[90], "p99": p[99]}


def print_table(rows: List[Dict], title: str) -> None:
    if not rows:
        return
    cols = list(rows[0].keys())
    print(f"\n## {title}")
    print("| " + " | ".join(cols) + " |")
    print("|" + "|".join(["---"] * len(cols)) + "|")
    for r in rows:
        cells = []
        for c in cols:
            v = r[c]
            cells.append(f"{v:.2f}" if isinstance(v, float) else str(v))
    # markdown row
        print("| " + " | ".join(cells) + " |")
